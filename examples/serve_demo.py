"""Batched serving demo with the paper's technique applied to the weights.

Loads a small LM (random-init for the demo), applies subtractor pairing at a
chosen rounding, and serves batched greedy generations from the KV-cache
engine — demonstrating that the paired (folded) weights are a drop-in
replacement at inference time, exactly as the paper deploys them.

Run:  PYTHONPATH=src python examples/serve_demo.py [--rounding 0.01]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.transform import pair_model_params
from repro.models import lm as M
from repro.models.param import unzip
from repro.serving.engine import ServeEngine

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen2-1.5b")
ap.add_argument("--rounding", type=float, default=0.01)
ap.add_argument("--steps", type=int, default=12)
args = ap.parse_args()

cfg = get_smoke_config(args.arch)
params, _ = unzip(M.init_lm(cfg, jax.random.key(0)))

paired, report = pair_model_params(params, args.rounding, min_dim=4)
s = report.savings()
print(f"[serve] paired {report.total_pairs} pairs "
      f"({100 * report.pair_fraction:.1f}% of weights) at rounding {args.rounding} "
      f"→ modeled power saving {100 * s['power_saving']:.1f}%, "
      f"area saving {100 * s['area_saving']:.1f}%")

knobs = M.PerfKnobs(q_chunk=16, k_chunk=16, remat="none")
rng = np.random.default_rng(0)
prompts = {i: rng.integers(0, cfg.vocab, size=(6 + 3 * i,)).astype(np.int32) for i in range(2)}

base = ServeEngine(cfg, params, max_seq=64, batch_size=2, knobs=knobs)
pair = ServeEngine(cfg, paired, max_seq=64, batch_size=2, knobs=knobs)
out_base = base.generate(dict(prompts), args.steps)
out_pair = pair.generate(dict(prompts), args.steps)

for slot in prompts:
    agree = sum(a == b for a, b in zip(out_base[slot], out_pair[slot], strict=True))
    print(f"slot {slot}: original {out_base[slot]}")
    print(f"        paired   {out_pair[slot]}  ({agree}/{args.steps} tokens agree)")
