"""End-to-end paper reproduction: train LeNet-5, pair its weights, and
reproduce the paper's power/area/accuracy trade-off (Table I + Fig. 8).

Run:  PYTHONPATH=src python examples/lenet_mnist.py [--epochs 3]
"""
import argparse

from benchmarks.fig8 import run as run_fig8
from benchmarks.table1 import run as run_table1

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    print("=== Table I: op counts (ours vs paper) ===")
    run_table1(quick=args.quick)
    print("\n=== Fig. 8: power/area/accuracy trade-off ===")
    run_fig8(quick=args.quick)
