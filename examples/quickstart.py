"""Quickstart: the paper's technique in 40 lines.

Takes a weight matrix, runs the paper's Algorithm-1 pairing at a few
rounding sizes, prints the op-count ledger + modeled ASIC savings, and shows
the TPU-native structured variant evaluating through the Pallas kernel.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import AsicCostModel, OpCounts
from repro.core.pairing import fold_columns, pair_columns, pair_rows_structured
from repro.kernels.ops import apply_structured_pairing

rng = np.random.default_rng(0)
W = rng.normal(size=(512, 256)) * 0.08  # a layer's weights (K=512 in, N=256 out)
x = jnp.asarray(rng.normal(size=(4, 512)), jnp.float32)
model = AsicCostModel()
base = OpCounts(mults=W.size, adds=W.size, subs=0)

print("rounding |  pairs | weight-err |  power-saving |  area-saving")
for r in [0.001, 0.005, 0.02, 0.05]:
    cp = pair_columns(W, r)
    Wf = fold_columns(W, cp)
    new = OpCounts(W.size - cp.total_pairs, W.size - cp.total_pairs, cp.total_pairs)
    print(
        f"  {r:6.3f} | {cp.total_pairs:6d} | {np.abs(Wf - W).max():10.5f} | "
        f"{100 * model.power_saving(base, new):12.2f}% | {100 * model.area_saving(base, new):11.2f}%"
    )

# exactness of eq.(1): folded dense matmul == subtractor dataflow
cp = pair_columns(W, 0.02)
y_folded = x @ jnp.asarray(fold_columns(W, cp), jnp.float32)

# TPU-native structured pairing through the fused Pallas kernel.
# Structured pairing needs *row-level* antisymmetry (shared across outputs);
# iid-random weights have none, so build a matrix with that structure the way
# trained networks often do (negated feature detectors + noise).
Ws = np.concatenate([W[:256], -W[:256] + rng.normal(size=(256, 256)) * 0.002])
sp = pair_rows_structured(Ws, rounding=0.01)
y_kernel = apply_structured_pairing(x, sp)
y_exact = x @ jnp.asarray(Ws, jnp.float32)
print(f"\nstructured pairing: {sp.n_pairs} shared pairs "
      f"→ MXU contraction {Ws.shape[0]} → {Ws.shape[0] - sp.n_pairs} lanes "
      f"({100 * sp.n_pairs / Ws.shape[0]:.0f}% fewer)")
print(f"kernel vs exact matmul max err: {float(jnp.abs(y_kernel - y_exact).max()):.5f} "
      f"(bounded by rounding)")
