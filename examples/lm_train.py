"""End-to-end driver: train a ~100M-param qwen2-family model for a few
hundred steps on the deterministic token stream, with checkpoint/resume.

This uses the same pjit step as the production launcher, on a local
(device_count, 1) mesh.  Loss must fall well below log(vocab) — the stream
has learnable bigram structure.

Run:  PYTHONPATH=src python examples/lm_train.py [--steps 300]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.tokens import token_batches
from repro.launch.steps import build_train_step
from repro.models import lm as M
from repro.models.param import unzip
from repro.parallel.rules import rules_for
from repro.parallel.sharding import make_mesh_compat, set_mesh_compat
from repro.train.optimizer import adamw, cosine_schedule


def config_100m() -> ModelConfig:
    """~100M params, qwen2 family (GQA + QKV bias, tied embeddings)."""
    return ModelConfig(
        name="qwen2-100m", family="dense",
        n_layers=8, d_model=512, n_heads=8, n_kv_heads=2,
        d_ff=2048, vocab=8192, qkv_bias=True, tie_embeddings=True,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    args = ap.parse_args()

    cfg = config_100m()
    print(f"[lm_train] {cfg.name}: {cfg.param_count()/1e6:.1f}M params")
    params, _ = unzip(M.init_lm(cfg, jax.random.key(0)))

    mesh = make_mesh_compat((jax.device_count(), 1), ("data", "model"))
    rules = rules_for(cfg, "train", mesh)
    opt = adamw(cosine_schedule(3e-4, args.steps, warmup_steps=20))
    opt_state = opt.init(params)
    knobs = M.PerfKnobs(q_chunk=min(256, args.seq), k_chunk=min(256, args.seq))
    step = jax.jit(build_train_step(cfg, opt, knobs, mesh, rules))

    data = token_batches(args.batch, args.seq, cfg.vocab, seed=7)
    t0, first_loss = time.time(), None
    with set_mesh_compat(mesh):
        for i, (tok, lab) in enumerate(data):
            if i >= args.steps:
                break
            params, opt_state, metrics = step(
                params, opt_state, jnp.int32(i),
                {"tokens": jnp.asarray(tok), "labels": jnp.asarray(lab)},
            )
            loss = float(metrics["loss"])
            first_loss = first_loss or loss
            if (i + 1) % 25 == 0:
                tps = args.batch * args.seq * (i + 1) / (time.time() - t0)
                print(f"step {i+1:4d}  loss {loss:.4f}  ({tps:,.0f} tok/s)")
    import math

    print(f"[lm_train] loss {first_loss:.3f} → {loss:.3f} "
          f"(uniform would be {math.log(cfg.vocab):.3f}); "
          f"{'LEARNED' if loss < first_loss - 0.5 else 'check hyperparams'}")


if __name__ == "__main__":
    main()
