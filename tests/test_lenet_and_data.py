"""LeNet-5 + data pipeline + transform-pass tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.transform import pair_model_params
from repro.data.mnist import load_mnist, pad_to_32, synthetic_mnist, batches
from repro.data.tokens import synthetic_tokens, token_batches
from repro.models.lenet import (
    LENET_CONV_SHAPES,
    init_lenet,
    lenet_apply,
    lenet_loss,
)


def test_lenet_shapes_and_finiteness():
    params = init_lenet(jax.random.key(0))
    x = jnp.zeros((4, 32, 32, 1))
    logits = lenet_apply(params, x)
    assert logits.shape == (4, 10)
    assert bool(jnp.isfinite(logits).all())


def test_lenet_conv_macs_match_paper_baseline():
    """The paper's 405600-mult baseline = sum over conv layers of
    positions × kernel size."""
    total = sum(
        int(np.prod(shape)) * pos for shape, pos in LENET_CONV_SHAPES.values()
    )
    assert total == 405600


def test_lenet_grads_flow():
    params = init_lenet(jax.random.key(0))
    x = jnp.ones((2, 32, 32, 1)) * 0.5
    y = jnp.array([3, 7])
    (loss, acc), grads = jax.value_and_grad(lenet_loss, has_aux=True)(params, x, y)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert gnorm > 0


def test_synthetic_mnist_deterministic_and_labeled():
    x1, y1 = synthetic_mnist(64, seed=5)
    x2, y2 = synthetic_mnist(64, seed=5)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    assert x1.shape == (64, 28, 28, 1)
    assert x1.min() >= 0 and x1.max() <= 1
    assert set(np.unique(y1)) <= set(range(10))


def test_synthetic_digits_are_distinguishable():
    """Mean image per class should differ clearly between e.g. 1 and 8."""
    x, y = synthetic_mnist(600, seed=1)
    m1 = x[y == 1].mean(axis=0)
    m8 = x[y == 8].mean(axis=0)
    assert np.abs(m1 - m8).mean() > 0.05


def test_pad_to_32():
    x, _ = synthetic_mnist(2, seed=0)
    assert pad_to_32(x).shape == (2, 32, 32, 1)


def test_load_mnist_reports_source():
    x, y, src = load_mnist("test", synthetic_n=16)
    assert src in ("real", "synthetic")
    assert x.shape[0] == y.shape[0]


def test_batches_deterministic():
    x, y = synthetic_mnist(100, seed=0)
    b1 = list(batches(x, y, 32, seed=3))
    b2 = list(batches(x, y, 32, seed=3))
    assert len(b1) == 3
    for (xa, ya), (xb, yb) in zip(b1, b2, strict=True):
        np.testing.assert_array_equal(xa, xb)
        np.testing.assert_array_equal(ya, yb)


def test_token_stream_deterministic_and_sharded():
    g1 = token_batches(8, 16, 1000, seed=1)
    g2 = token_batches(8, 16, 1000, seed=1)
    t1, l1 = next(g1)
    t2, l2 = next(g2)
    np.testing.assert_array_equal(t1, t2)
    np.testing.assert_array_equal(l1, l2)
    assert t1.shape == (8, 16)
    # labels are next-token shifted
    full = synthetic_tokens(8, 16, 1000, seed=1, step=0)
    np.testing.assert_array_equal(t1, full[:, :-1])
    np.testing.assert_array_equal(l1, full[:, 1:])
    # shards partition the global batch
    s0 = next(token_batches(8, 16, 1000, seed=1, shard_index=0, shard_count=2))
    s1 = next(token_batches(8, 16, 1000, seed=1, shard_index=1, shard_count=2))
    np.testing.assert_array_equal(np.concatenate([s0[0], s1[0]]), t1)


def test_tokens_have_learnable_structure():
    """Bigram entropy must be far below uniform (the stream is learnable)."""
    t = synthetic_tokens(4, 4096, 50, seed=0, step=0).ravel()
    # distribution of next token given current parity bucket
    pairs = np.stack([t[:-1] % 10, t[1:] % 10])
    joint = np.zeros((10, 10))
    np.add.at(joint, (pairs[0], pairs[1]), 1)
    joint /= joint.sum()
    marg = joint.sum(1, keepdims=True) @ joint.sum(0, keepdims=True)
    # mutual information > 0.1 nats
    mi = np.nansum(joint * np.log((joint + 1e-12) / (marg + 1e-12)))
    assert mi > 0.1


def test_pair_model_params_on_lenet():
    params = init_lenet(jax.random.key(0))
    paired, report = pair_model_params(params, rounding=0.05, min_dim=4)
    assert report.total_pairs > 0
    # biases and small dims untouched; conv + fc leaves eligible
    names = [l.path for l in report.leaves]
    assert any("conv1" in n for n in names)
    assert any("fc1" in n for n in names)
    # same treedef, same shapes
    assert jax.tree.structure(paired) == jax.tree.structure(params)
    for a, b in zip(jax.tree.leaves(paired), jax.tree.leaves(params), strict=True):
        assert a.shape == b.shape and a.dtype == b.dtype
    # error bound
    for la, lb in zip(jax.tree.leaves(paired), jax.tree.leaves(params), strict=True):
        assert float(jnp.max(jnp.abs(jnp.asarray(la, jnp.float64) - jnp.asarray(lb, jnp.float64)))) <= 0.025 + 1e-9
    s = report.savings()
    assert 0 <= s["power_saving"] < 1
    assert 0 <= s["pair_fraction"] <= 1


def test_pair_model_params_structured_mode():
    params = {"w": np.random.default_rng(0).normal(size=(64, 32)).astype(np.float32)}
    paired, report = pair_model_params(params, rounding=0.2, mode="structured", keep_pairings=True)
    assert report.leaves[0].pairing is not None
    assert paired["w"].shape == (64, 32)
