"""The ASIC cost model must reproduce the paper's §IV headline numbers."""
import pytest

from repro.core.cost_model import (
    AsicCostModel,
    OpCounts,
    paper_table1,
    TPU_V5E,
)


BASE = OpCounts(mults=405600, adds=405600, subs=0)


def test_headline_power_saving_at_0p05():
    """Paper: rounding 0.05 → 32.03% power saving."""
    m = AsicCostModel()
    new = OpCounts(mults=242153, adds=242153, subs=163447)
    assert m.power_saving(BASE, new) == pytest.approx(0.3203, abs=2e-4)


def test_headline_area_saving_at_0p05():
    """Paper: rounding 0.05 → 24.59% area saving."""
    m = AsicCostModel()
    new = OpCounts(mults=242153, adds=242153, subs=163447)
    assert m.area_saving(BASE, new) == pytest.approx(0.2459, abs=2e-4)


def test_mult_ratios_physically_plausible():
    """Calibrated ratios should sit near published 45-65nm numbers
    (Horowitz ISSCC'14: energy ratio ≈ 4.1, area ratio ≈ 1.8)."""
    m = AsicCostModel()
    assert 2.5 < m.e_mul < 5.5
    assert 1.2 < m.a_mul < 2.2


def test_savings_monotone_in_rounding():
    """Walking down Table I, power and area savings must both increase."""
    m = AsicCostModel()
    last_p, last_a = -1.0, -1.0
    for row in paper_table1():
        new = OpCounts(row["mults"], row["adds"], row["subs"])
        p = m.power_saving(BASE, new)
        a = m.area_saving(BASE, new)
        assert p >= last_p and a >= last_a
        last_p, last_a = p, a


def test_table1_internal_consistency():
    """In Table I: adds == mults and adds + subs == 405600 for every row
    (each pair converts one mult + one add into one sub)."""
    for row in paper_table1():
        assert row["adds"] == row["mults"]
        assert row["adds"] + row["subs"] == 405600


def test_roofline_terms():
    t = TPU_V5E.terms(hlo_flops=197e12, hlo_bytes=819e9, collective_bytes=0.0)
    assert t["t_compute_s"] == pytest.approx(1.0)
    assert t["t_memory_s"] == pytest.approx(1.0)
    assert t["t_collective_s"] == 0.0
    assert t["bound"] in ("compute", "memory")

    t2 = TPU_V5E.terms(1e12, 1e9, 500e9)
    assert t2["bound"] == "collective"
    assert t2["t_collective_s"] == pytest.approx(10.0)
