"""End-to-end behaviour tests for the paper's system.

The full loop: train LeNet-5 → pair weights at increasing rounding →
accuracy degrades monotonically-ish while modeled power saving grows —
the paper's central trade-off, exercised end to end on a small budget.
Plus: LM training actually learns, and paired LM weights stay functional.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import AsicCostModel, OpCounts
from repro.core.transform import pair_model_params
from repro.data.tokens import token_batches
from repro.models import lm as M
from repro.models.param import unzip
from repro.train.lenet_trainer import get_trained_lenet
from repro.models.lenet import lenet_accuracy
from benchmarks.fig8 import paired_lenet


def test_lenet_pairing_tradeoff_end_to_end():
    params, test_x, test_y, info = get_trained_lenet(
        epochs=2, train_n=8000, test_n=2000, seed=0, cache=True, verbose=False
    )
    base_acc = info["test_acc"]
    assert base_acc > 0.9, f"LeNet must train (got {base_acc})"

    model = AsicCostModel()
    base_ops = OpCounts(405600, 405600, 0)
    accs, savings = [], []
    for r in (0.001, 0.02, 0.3):
        p2, ops = paired_lenet(params, r)
        accs.append(lenet_accuracy(p2, test_x, test_y))
        savings.append(model.power_saving(base_ops, ops))
    # savings grow with rounding; tiny rounding preserves accuracy
    assert savings[0] < savings[1] < savings[2]
    assert accs[0] > base_acc - 0.02
    assert accs[2] <= accs[0] + 1e-9  # aggressive rounding can't beat gentle


def test_lm_training_learns_and_paired_weights_serve():
    """A tiny LM learns the synthetic stream; pairing at small rounding
    leaves its loss nearly unchanged (the paper's deployment story)."""
    from repro.configs.base import ModelConfig
    from repro.train.optimizer import adamw

    cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
                      tie_embeddings=True)
    params, _ = unzip(M.init_lm(cfg, jax.random.key(0)))
    knobs = M.PerfKnobs(q_chunk=32, k_chunk=32, remat="none")
    opt = adamw(3e-3)
    state = opt.init(params)

    @jax.jit
    def step(p, s, i, tok, lab):
        (loss, _), g = jax.value_and_grad(
            lambda pp: M.lm_loss(cfg, pp, {"tokens": tok, "labels": lab}, knobs=knobs),
            has_aux=True,
        )(p)
        p, s = opt.update(g, s, p, i)
        return p, s, loss

    data = token_batches(8, 64, cfg.vocab, seed=2)
    losses = []
    for i, (tok, lab) in enumerate(data):
        if i >= 120:
            break
        params, state, loss = step(params, state, jnp.int32(i), jnp.asarray(tok), jnp.asarray(lab))
        losses.append(float(loss))
    first = np.mean(losses[:10])
    last = np.mean(losses[-10:])
    assert last < first - 0.08, f"no learning: {first:.3f} -> {last:.3f}"

    # pair the trained weights gently; loss must stay close
    paired, report = pair_model_params(params, rounding=0.003, min_dim=4)
    tok, lab = next(token_batches(8, 64, cfg.vocab, seed=99))
    l0, _ = M.lm_loss(cfg, params, {"tokens": jnp.asarray(tok), "labels": jnp.asarray(lab)}, knobs=knobs)
    l1, _ = M.lm_loss(cfg, paired, {"tokens": jnp.asarray(tok), "labels": jnp.asarray(lab)}, knobs=knobs)
    assert report.total_pairs > 0
    assert abs(float(l1) - float(l0)) < 0.05, (float(l0), float(l1))
