"""The static-analysis pass: walker semantics, every rule family, the report.

The walker tests pin down the per-call-site/per-eqn-dedup semantics that the
historical ``benchmarks.common._walk_eqns`` got wrong (a sub-jaxpr referenced
from two params of ONE eqn was walked twice, inflating every count).  The
rule tests feed each family a deliberately broken input — a non-permutation
pairing, a mismatched layer stack, an over-budget tile, a while loop that
copies pairing metadata — and require the error finding to fire.
"""
from __future__ import annotations

import json
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (
    RULE_REGISTRY,
    AnalysisReport,
    Finding,
    RuleContext,
    count_primitives,
    count_shape_adds,
    pallas_calls_by_scan,
    run_rules,
)
from repro.core.pairing import BlockedPairing, StructuredPairing

# ---------------------------------------------------------------------------
# walker semantics
# ---------------------------------------------------------------------------


def _fake_eqn(primitive_name: str, params: dict):
    """Duck-typed eqn: ``.primitive.name``, ``.params``, ``.invars``/``.outvars``."""
    return types.SimpleNamespace(
        primitive=types.SimpleNamespace(name=primitive_name),
        params=params, invars=(), outvars=(),
    )


def _fake_jaxpr(eqns):
    return types.SimpleNamespace(eqns=list(eqns))


def test_shared_subjaxpr_within_one_eqn_walked_once():
    """Regression for the historical double-walk: one eqn carrying the SAME
    sub-jaxpr object under two params counts its eqns once."""
    inner = _fake_jaxpr([_fake_eqn("sin", {})])
    outer = _fake_jaxpr([_fake_eqn("custom_thing", {"fwd": inner, "bwd": inner})])
    assert count_primitives(outer, "sin") == 1


def test_closed_and_raw_jaxpr_dedupe_together():
    """A ClosedJaxpr-like wrapper and its raw ``.jaxpr`` are one computation."""
    raw = _fake_jaxpr([_fake_eqn("sin", {})])
    closed = types.SimpleNamespace(jaxpr=raw)
    outer = _fake_jaxpr([_fake_eqn("call", {"closed": closed, "raw": raw})])
    assert count_primitives(outer, "sin") == 1


def test_distinct_eqns_counted_per_call_site():
    """Two eqns sharing one sub-jaxpr are two call sites — both execute."""
    inner = _fake_jaxpr([_fake_eqn("sin", {})])
    outer = _fake_jaxpr([
        _fake_eqn("call", {"jaxpr": inner}),
        _fake_eqn("call", {"jaxpr": inner}),
    ])
    assert count_primitives(outer, "sin") == 2


def test_jitted_function_called_twice_counts_both_launches():
    """The real-jax shape of the per-call-site rule: ``f(x) + f(x)`` shares
    one traced ClosedJaxpr across two pjit eqns, but runs twice."""

    @jax.jit
    def f(x):
        return jnp.sin(x)

    jaxpr = jax.make_jaxpr(lambda x: f(x) + f(x))(jnp.ones((4,)))
    assert count_primitives(jaxpr, "sin") == 2


def test_walker_descends_into_scan_bodies():
    def body(c, _):
        return jnp.sin(c), jnp.cos(c)

    jaxpr = jax.make_jaxpr(
        lambda x: jax.lax.scan(body, x, None, length=3)
    )(jnp.ones((4,)))
    assert count_primitives(jaxpr, "sin") == 1
    assert count_primitives(jaxpr, "cos") == 1


def test_walker_descends_into_custom_vjp():
    @jax.custom_vjp
    def f(x):
        return jnp.sin(x)

    f.defvjp(lambda x: (jnp.sin(x), x), lambda x, g: (g * jnp.cos(x),))
    jaxpr = jax.make_jaxpr(jax.grad(lambda x: f(x).sum()))(jnp.ones((4,)))
    assert count_primitives(jaxpr, "cos") == 1


def test_count_shape_adds_matches_full_shape_only():
    h = (2, 1, 8)

    def f(a, b, bias):
        y = a + b          # residual-shaped: counts
        y = y + bias       # broadcast from (8,): must not count
        return y + a       # counts

    args = (jnp.ones(h), jnp.ones(h), jnp.ones((8,)))
    assert count_shape_adds(jax.make_jaxpr(f)(*args), h) == 2


def test_pallas_calls_by_scan_attributes_to_innermost_scan():
    inner_kernel = _fake_jaxpr([_fake_eqn("pallas_call", {})])
    scan_eqn = _fake_eqn("scan", {"jaxpr": inner_kernel, "length": 5})
    top = _fake_jaxpr([scan_eqn, _fake_eqn("pallas_call", {})])
    total, per_scan = pallas_calls_by_scan(top)
    assert total == 2
    (rec,) = per_scan.values()
    assert rec == {"per_trip": 1, "length": 5}


# ---------------------------------------------------------------------------
# schedule rules
# ---------------------------------------------------------------------------


def _run(ctx, *rule_ids):
    return run_rules(ctx, rule_ids=rule_ids)


def test_no_standalone_pool_fires_on_fused_expectation():
    jaxpr = jax.make_jaxpr(
        lambda x: jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )
    )(jnp.ones((1, 4, 4, 1)))
    bad = _run(
        RuleContext(target="t", jaxpr=jaxpr, expect={"fused_pool": True}),
        "schedule/no-standalone-pool",
    )
    assert bad.exit_code == 1
    assert bad.errors()[0].measured == 1
    ok = _run(
        RuleContext(target="t", jaxpr=jaxpr, expect={}),
        "schedule/no-standalone-pool",
    )
    assert ok.exit_code == 0
    assert ok.measured("schedule/no-standalone-pool") == 1


def test_writebacks_per_program_gate():
    top = _fake_jaxpr([_fake_eqn("pallas_call", {}) for _ in range(3)])
    bad = _run(
        RuleContext(target="t", jaxpr=top, expect={"pallas_calls": 2}),
        "schedule/writebacks-per-program",
    )
    assert bad.exit_code == 1 and bad.errors()[0].measured == 3
    ok = _run(
        RuleContext(target="t", jaxpr=top, expect={"pallas_calls": 3}),
        "schedule/writebacks-per-program",
    )
    assert ok.exit_code == 0


def test_writebacks_per_decode_layer_budget():
    kernels = _fake_jaxpr([_fake_eqn("pallas_call", {}) for _ in range(9)])
    top = _fake_jaxpr([_fake_eqn("scan", {"jaxpr": kernels, "length": 2})])
    bad = _run(
        RuleContext(target="t", jaxpr=top, expect={"writebacks_per_layer": 7}),
        "schedule/writebacks-per-decode-layer",
    )
    assert bad.exit_code == 1
    assert bad.errors()[0].measured == 9 and bad.errors()[0].expected == 7
    ok = _run(
        RuleContext(target="t", jaxpr=top, expect={"writebacks_per_layer": 9}),
        "schedule/writebacks-per-decode-layer",
    )
    assert ok.exit_code == 0
    # an expectation with NO scan in the trace is an error, not a silent pass
    no_scan = _run(
        RuleContext(target="t", jaxpr=_fake_jaxpr([]),
                    expect={"writebacks_per_layer": 7}),
        "schedule/writebacks-per-decode-layer",
    )
    assert no_scan.exit_code == 1


def test_standalone_residual_adds_gate():
    h = (2, 1, 8)
    jaxpr = jax.make_jaxpr(lambda a, b: a + b)(jnp.ones(h), jnp.ones(h))
    bad = _run(
        RuleContext(target="t", jaxpr=jaxpr, hidden_shape=h,
                    expect={"residual_adds": 0}),
        "schedule/standalone-residual-adds",
    )
    assert bad.exit_code == 1 and bad.errors()[0].measured == 1


# ---------------------------------------------------------------------------
# dtype rules
# ---------------------------------------------------------------------------


def test_no_f64_flags_wide_outvars():
    aval = types.SimpleNamespace(dtype=np.dtype("float64"), shape=(4,))
    eqn = _fake_eqn("add", {})
    eqn.outvars = (types.SimpleNamespace(aval=aval),)
    bad = _run(RuleContext(target="t", jaxpr=_fake_jaxpr([eqn])), "dtype/no-f64")
    assert bad.exit_code == 1
    ok = _run(
        RuleContext(target="t", jaxpr=jax.make_jaxpr(jnp.sin)(jnp.ones((4,)))),
        "dtype/no-f64",
    )
    assert ok.exit_code == 0


def test_reduce_precision_required_on_bf16_paired_kernels():
    def paired_eqn(kernel_eqns):
        e = _fake_eqn("pallas_call", {
            "jaxpr": _fake_jaxpr(kernel_eqns),
            "name_and_src_info": types.SimpleNamespace(name="paired_matmul"),
        })
        aval = types.SimpleNamespace(dtype=jnp.dtype(jnp.bfloat16), shape=(4, 4))
        e.invars = (types.SimpleNamespace(aval=aval),)
        return e

    unpinned = _run(
        RuleContext(target="t", jaxpr=_fake_jaxpr([paired_eqn([])])),
        "dtype/reduce-precision-on-bf16",
    )
    assert unpinned.exit_code == 1
    pinned = _run(
        RuleContext(
            target="t",
            jaxpr=_fake_jaxpr([paired_eqn([_fake_eqn("reduce_precision", {})])]),
        ),
        "dtype/reduce-precision-on-bf16",
    )
    assert pinned.exit_code == 0
    assert pinned.measured("dtype/reduce-precision-on-bf16") == 1


def test_real_bf16_paired_kernel_carries_reduce_precision():
    """The shipped subtractor kernel satisfies its own dtype rule end to end."""
    from repro.kernels.paired_matmul import paired_matmul_pallas

    x = jnp.ones((8, 16), jnp.bfloat16)
    kmat = jnp.ones((4, 8), jnp.bfloat16)
    wres = jnp.ones((8, 8), jnp.bfloat16)
    jaxpr = jax.make_jaxpr(
        lambda x, k, w: paired_matmul_pallas(x, k, w, block_k=16)
    )(x, kmat, wres)
    rep = _run(
        RuleContext(target="t", jaxpr=jaxpr), "dtype/reduce-precision-on-bf16"
    )
    assert rep.exit_code == 0
    assert rep.measured("dtype/reduce-precision-on-bf16") == 1


def test_convert_churn_warns_over_budget():
    def f(x):
        for _ in range(3):
            x = x.astype(jnp.bfloat16).astype(jnp.float32)
        return x

    jaxpr = jax.make_jaxpr(f)(jnp.ones((4,)))
    rep = _run(
        RuleContext(target="t", jaxpr=jaxpr, expect={"max_converts": 2}),
        "dtype/convert-churn",
    )
    assert rep.exit_code == 0  # warning, not error
    assert len(rep.warnings()) == 1 and rep.warnings()[0].measured == 6


# ---------------------------------------------------------------------------
# VMEM rule
# ---------------------------------------------------------------------------


def test_vmem_estimator_double_buffers_inputs():
    from repro.kernels.tuning import estimate_pallas_vmem_bytes

    est = estimate_pallas_vmem_bytes(
        in_blocks=[((8, 4), 4)], out_blocks=[((8, 2), 2)],
        scratch_blocks=[((None, 4), 4)],
    )
    assert est == 2 * 8 * 4 * 4 + 8 * 2 * 2 + 4 * 4


def test_vmem_budget_flags_oversized_blocks():
    from repro.kernels.paired_matmul import dense_matmul_pallas

    x = jnp.ones((1024, 1024), jnp.float32)
    w = jnp.ones((1024, 1024), jnp.float32)

    def over(x, w):
        return dense_matmul_pallas(
            x, w, block_m=1024, block_n=1024, block_k=1024
        )

    def under(x, w):
        return dense_matmul_pallas(x, w, block_m=128, block_n=128, block_k=512)

    bad = _run(
        RuleContext(target="t", jaxpr=jax.make_jaxpr(over)(x, w)),
        "vmem/static-budget",
    )
    assert bad.exit_code == 1
    assert bad.errors()[0].measured > 8 * 1024 * 1024
    ok = _run(
        RuleContext(target="t", jaxpr=jax.make_jaxpr(under)(x, w)),
        "vmem/static-budget",
    )
    assert ok.exit_code == 0


# ---------------------------------------------------------------------------
# pairing-artifact rules
# ---------------------------------------------------------------------------


def _structured(I, J, resid, K, N=2):
    I, J, resid = (np.asarray(a, np.int64) for a in (I, J, resid))
    return StructuredPairing(
        I=I, J=J, Kmat=np.ones((len(I), N)), resid=resid,
        W_res=np.ones((len(resid), N)), shape=(K, N),
    )


def test_valid_permutation_accepts_good_and_flags_bad():
    good = {"conv1": _structured([0, 1], [3, 2], [4, 5], K=6)}
    ok = _run(
        RuleContext(target="t", pairing_artifacts=good),
        "pairing/valid-permutation",
    )
    assert ok.exit_code == 0

    # row 3 appears twice, row 2 never: not a permutation
    bad = {"conv1": _structured([0, 1], [3, 3], [4, 5], K=6)}
    rep = _run(
        RuleContext(target="t", pairing_artifacts=bad),
        "pairing/valid-permutation",
    )
    assert rep.exit_code == 1
    assert "conv1" in rep.errors()[0].location


def test_blocked_pairing_artifacts_validate_through_masks():
    blocks = [
        _structured([0, 1], [3, 2], [4, 5], K=6, N=2),
        _structured([5], [0], [1, 2, 3, 4], K=6, N=2),
    ]
    bp = BlockedPairing(blocks=blocks, block_n=2, shape=(6, 4))
    rep = _run(
        RuleContext(target="t", pairing_artifacts={"conv1": bp}),
        "pairing/valid-permutation", "pairing/padding-consistent",
    )
    assert rep.exit_code == 0
    assert rep.measured("pairing/valid-permutation", location="t") == 2


def test_padding_consistency_flags_nonzero_padded_lanes(monkeypatch):
    rep = _run(
        RuleContext(
            target="t",
            pairing_artifacts={"conv1": BlockedPairing(
                blocks=[_structured([1], [4], [0, 3, 5], K=6)],
                block_n=2, shape=(6, 2),
            )},
        ),
        "pairing/padding-consistent",
    )
    assert rep.exit_code == 0  # the real builder pads correctly

    # hand-corrupt the packed arrays: a padded lane pointing off row 0
    import repro.analysis.rules_pairing as rp

    bad = rp._Artifact(
        location="conv1/block0", K=6,
        I=np.array([1, 2]), J=np.array([4, 2]), resid=np.array([0, 3, 5]),
        pair_mask=np.array([1.0, 0.0]), resid_mask=np.array([1.0, 1.0, 1.0]),
    )
    monkeypatch.setattr(rp, "_all_artifacts", lambda _ctx: [bad])
    rep2 = _run(RuleContext(target="t", pairing_artifacts={}), "pairing/padding-consistent")
    assert rep2.exit_code == 1
    assert "point at row 0" in rep2.errors()[0].message


def _fake_lm_params(L=2, K=8, N=4, *, stack_layers=None, bad_index=False):
    stack_layers = L if stack_layers is None else stack_layers
    P, R = 2, K - 4
    meta = {
        "I": np.zeros((stack_layers, P), np.int32),
        "J": np.ones((stack_layers, P), np.int32),
        "resid": np.tile(np.arange(4, K, dtype=np.int32), (stack_layers, 1)),
        "pair_mask": np.ones((stack_layers, P)),
        "resid_mask": np.ones((stack_layers, R)),
    }
    meta["I"][:, 1] = 2
    meta["J"][:, 1] = 3
    if bad_index:
        meta["J"][:, 0] = K + 3  # out of the weight's contraction range
    return {"segments": [{
        "attn": {"wq": np.zeros((L, K, N)), "wq_pairing": meta},
    }]}


def test_stacked_shapes_accepts_consistent_metadata():
    rep = _run(
        RuleContext(target="t", params=_fake_lm_params()),
        "pairing/stacked-shapes", "pairing/valid-permutation",
    )
    assert rep.exit_code == 0
    assert rep.measured("pairing/stacked-shapes", location="t") == 1


def test_stacked_shapes_flags_layer_mismatch_and_bad_index():
    mismatched = _run(
        RuleContext(target="t", params=_fake_lm_params(L=2, stack_layers=3)),
        "pairing/stacked-shapes",
    )
    assert mismatched.exit_code == 1
    assert "3 layer(s), weight stacks 2" in mismatched.errors()[0].message

    oob = _run(
        RuleContext(target="t", params=_fake_lm_params(bad_index=True)),
        "pairing/stacked-shapes",
    )
    assert oob.exit_code == 1
    assert "outside the weight's K=8" in oob.errors()[0].message


def test_real_paired_lm_params_pass_all_pairing_rules():
    from repro.configs import get_smoke_config
    from repro.core.transform import pair_lm_params
    from repro.models import lm as M
    from repro.models.param import unzip

    cfg = get_smoke_config("qwen2-1.5b")
    params, _ = unzip(M.init_lm(cfg, jax.random.key(0)))
    pm, _ = pair_lm_params(params, 0.05, mode="per_column")
    rep = _run(
        RuleContext(target="t", params=pm),
        "pairing/valid-permutation", "pairing/padding-consistent",
        "pairing/stacked-shapes",
    )
    assert rep.exit_code == 0, [f.as_dict() for f in rep.errors()]


# ---------------------------------------------------------------------------
# HLO rule
# ---------------------------------------------------------------------------

_HLO_CLEAN = """
HloModule decode

%body (p0: (s32[], f32[2,8])) -> (s32[], f32[2,8]) {
  %p0 = (s32[], f32[2,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p0), index=0
  %h = f32[2,8]{1,0} get-tuple-element(%p0), index=1
  %one = s32[] constant(1)
  %i2 = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[2,8]{1,0}) tuple(%i2, %h)
}

%cond (p0: (s32[], f32[2,8])) -> pred[] {
  %p0 = (s32[], f32[2,8]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p0), index=0
  %n = s32[] constant(4)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (meta: s32[2,4,3], h0: f32[2,8]) -> f32[2,8] {
  %meta = s32[2,4,3]{2,1,0} parameter(0), metadata={op_name="p['segments'][0]['attn']['wq_pairing']['I']"}
  %h0 = f32[2,8]{1,0} parameter(1)
  %z = s32[] constant(0)
  %init = (s32[], f32[2,8]{1,0}) tuple(%z, %h0)
  %w = (s32[], f32[2,8]{1,0}) while(%init), condition=%cond, body=%body
  ROOT %out = f32[2,8]{1,0} get-tuple-element(%w), index=1
}
"""

# same module, but the while body copies a buffer of the pairing-metadata
# type (s32[2,4,3]) every trip — the rule must flag it
_HLO_DIRTY = _HLO_CLEAN.replace(
    "  %one = s32[] constant(1)",
    "  %bad = s32[2,4,3]{2,1,0} copy(%meta)\n  %one = s32[] constant(1)",
)


def test_hlo_rule_clean_loop_passes():
    rep = _run(
        RuleContext(target="t", hlo_text=_HLO_CLEAN),
        "hlo/pairing-resharding-in-loop",
    )
    assert rep.exit_code == 0
    assert rep.measured("hlo/pairing-resharding-in-loop", location="t") == 0


def test_hlo_rule_flags_copy_of_pairing_buffer_in_loop():
    rep = _run(
        RuleContext(target="t", hlo_text=_HLO_DIRTY),
        "hlo/pairing-resharding-in-loop",
    )
    assert rep.exit_code == 1
    err = rep.errors()[0]
    assert err.measured == "copy" and "body" in err.location


def test_hlo_rule_copy_outside_loop_is_fine():
    hlo = _HLO_CLEAN.replace(
        "  %z = s32[] constant(0)",
        "  %c = s32[2,4,3]{2,1,0} copy(%meta)\n  %z = s32[] constant(0)",
    )
    rep = _run(
        RuleContext(target="t", hlo_text=hlo), "hlo/pairing-resharding-in-loop"
    )
    assert rep.exit_code == 0


# ---------------------------------------------------------------------------
# registry / report plumbing
# ---------------------------------------------------------------------------


def test_registry_contains_all_twelve_rules():
    run_rules(RuleContext(target="t"))  # force registration
    assert sorted(RULE_REGISTRY) == [
        "dtype/convert-churn",
        "dtype/no-f64",
        "dtype/reduce-precision-on-bf16",
        "hlo/pairing-resharding-in-loop",
        "pairing/padding-consistent",
        "pairing/stacked-shapes",
        "pairing/valid-permutation",
        "schedule/no-standalone-pool",
        "schedule/standalone-residual-adds",
        "schedule/writebacks-per-decode-layer",
        "schedule/writebacks-per-program",
        "vmem/static-budget",
    ]


def test_unmet_needs_are_recorded_not_dropped():
    rep = run_rules(RuleContext(target="t"))  # context provides nothing
    assert rep.rules_run == []
    assert set(rep.rules_skipped) == set(RULE_REGISTRY)
    assert rep.rules_skipped["hlo/pairing-resharding-in-loop"] == "hlo"
    assert rep.exit_code == 0


def test_unknown_rule_id_is_an_assertion():
    with pytest.raises(AssertionError):
        run_rules(RuleContext(target="t"), rule_ids=["schedule/no-such-rule"])


def test_report_json_round_trip_and_measured_lookup():
    rep = AnalysisReport(
        target="t",
        findings=[
            Finding("r/a", "info", "t", "m", measured=7, expected=7),
            Finding("r/b", "error", "t/x", "boom", measured=9, expected=7),
        ],
        rules_run=["r/a", "r/b"],
        rules_skipped={"r/c": "hlo"},
    )
    assert rep.exit_code == 1
    assert rep.measured("r/a") == 7
    assert rep.measured("r/b", location="t/x") == 9
    with pytest.raises(KeyError):
        rep.measured("r/absent")
    d = json.loads(rep.to_json())
    assert d["errors"] == 1 and d["rules_skipped"] == {"r/c": "hlo"}
    assert d["findings"][1]["severity"] == "error"
    assert any("ERROR r/b" in line for line in rep.summary_lines())


def test_benchmarks_common_reexports_the_analysis_walker():
    from benchmarks import common
    from repro.analysis import jaxpr_walk

    assert common.count_primitives is jaxpr_walk.count_primitives
    assert common.count_shape_adds is jaxpr_walk.count_shape_adds


def test_lenet_fused_target_runs_clean_end_to_end():
    """The CLI's fastest target: every runnable rule fires, none errors, and
    the skipped rules are exactly the facets LeNet doesn't provide."""
    from repro.analysis.targets import build_context

    rep = run_rules(build_context("lenet_fused"))
    assert rep.exit_code == 0, [f.as_dict() for f in rep.errors()]
    assert rep.measured("schedule/writebacks-per-program") == 3
    assert rep.measured("schedule/no-standalone-pool") == 0
    assert set(rep.rules_skipped) == {
        "hlo/pairing-resharding-in-loop",
        "schedule/standalone-residual-adds",
    }
