"""Sharding rules: divisibility guards, mesh-axis dedup, rule tables."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.parallel.rules import rules_for
from repro.parallel.sharding import Rules, make_mesh_compat, spec_for_axes


def _mesh2():
    n = jax.device_count()
    return make_mesh_compat((1, n), ("data", "model"))


RULES = Rules({"batch": ("data",), "ff": "model", "vocab": "model",
               "q_heads": "model", "embed": None})


def test_spec_basic():
    mesh = _mesh2()
    spec = spec_for_axes(("embed", "ff"), mesh=mesh, rules=RULES)
    assert spec == P(None, "model")


@pytest.mark.skipif(jax.device_count() < 2, reason="needs a >1-way mesh axis")
def test_divisibility_guard_replicates():
    mesh = _mesh2()
    n = mesh.shape["model"]
    # dim not divisible by the model axis → replicated
    spec = spec_for_axes(("q_heads",), mesh=mesh, rules=RULES, dim_sizes=(n + 1,))
    assert spec == P(None)
    spec2 = spec_for_axes(("q_heads",), mesh=mesh, rules=RULES, dim_sizes=(n * 3,))
    assert spec2 == P("model")


def test_divisibility_guard_unit_axis():
    """On a size-1 axis everything divides — spec keeps the mapping."""
    mesh = _mesh2()
    spec = spec_for_axes(("q_heads",), mesh=mesh, rules=RULES,
                         dim_sizes=(mesh.shape["model"] * 3,))
    assert spec == P("model")


def test_mesh_axis_used_once():
    """Two logical axes mapping to `model`: priority order wins, later → None."""
    mesh = _mesh2()
    spec = spec_for_axes(("vocab", "ff"), mesh=mesh, rules=RULES)
    assert list(spec).count("model") == 1
    # "vocab" has priority over... both map to model; exactly one survives
    assert spec[0] == "model" or spec[1] == "model"


def test_rules_for_all_archs_and_modes():
    mesh = _mesh2()
    for arch in ("qwen2-1.5b", "deepseek-v2-lite-16b", "mamba2-2.7b", "mistral-large-123b"):
        cfg = get_config(arch)
        for mode in ("train", "prefill", "decode"):
            r = rules_for(cfg, mode, mesh)
            assert r.mesh_axes("layers") is None, "scan dim never shards"
            assert r.mesh_axes("batch") == ("data",)
    big = rules_for(get_config("mistral-large-123b"), "train", mesh)
    assert big.mesh_axes("embed") == "data", "123B trains with FSDP"
    small = rules_for(get_config("qwen2-1.5b"), "train", mesh)
    assert small.mesh_axes("embed") is None


def test_constrain_noop_outside_context():
    import jax.numpy as jnp

    from repro.parallel.sharding import constrain

    x = jnp.ones((4, 4))
    y = constrain(x, "batch", None)  # no mesh/rules active → identity
    assert (y == x).all()
