"""Sharding rules: divisibility guards, mesh-axis dedup, rule tables."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.parallel.rules import rules_for
from repro.parallel.sharding import Rules, make_mesh_compat, spec_for_axes


def _mesh2():
    n = jax.device_count()
    return make_mesh_compat((1, n), ("data", "model"))


RULES = Rules({"batch": ("data",), "ff": "model", "vocab": "model",
               "q_heads": "model", "embed": None})


def test_spec_basic():
    mesh = _mesh2()
    spec = spec_for_axes(("embed", "ff"), mesh=mesh, rules=RULES)
    assert spec == P(None, "model")


@pytest.mark.skipif(jax.device_count() < 2, reason="needs a >1-way mesh axis")
def test_divisibility_guard_replicates():
    mesh = _mesh2()
    n = mesh.shape["model"]
    # dim not divisible by the model axis → replicated
    spec = spec_for_axes(("q_heads",), mesh=mesh, rules=RULES, dim_sizes=(n + 1,))
    assert spec == P(None)
    spec2 = spec_for_axes(("q_heads",), mesh=mesh, rules=RULES, dim_sizes=(n * 3,))
    assert spec2 == P("model")


def test_divisibility_guard_unit_axis():
    """On a size-1 axis everything divides — spec keeps the mapping."""
    mesh = _mesh2()
    spec = spec_for_axes(("q_heads",), mesh=mesh, rules=RULES,
                         dim_sizes=(mesh.shape["model"] * 3,))
    assert spec == P("model")


def test_mesh_axis_used_once():
    """Two logical axes mapping to `model`: priority order wins, later → None."""
    mesh = _mesh2()
    spec = spec_for_axes(("vocab", "ff"), mesh=mesh, rules=RULES)
    assert list(spec).count("model") == 1
    # "vocab" has priority over... both map to model; exactly one survives
    assert spec[0] == "model" or spec[1] == "model"


def test_rules_for_all_archs_and_modes():
    mesh = _mesh2()
    for arch in ("qwen2-1.5b", "deepseek-v2-lite-16b", "mamba2-2.7b", "mistral-large-123b"):
        cfg = get_config(arch)
        for mode in ("train", "prefill", "decode"):
            r = rules_for(cfg, mode, mesh)
            assert r.mesh_axes("layers") is None, "scan dim never shards"
            assert r.mesh_axes("batch") == ("data",)
    big = rules_for(get_config("mistral-large-123b"), "train", mesh)
    assert big.mesh_axes("embed") == "data", "123B trains with FSDP"
    small = rules_for(get_config("qwen2-1.5b"), "train", mesh)
    assert small.mesh_axes("embed") is None


def test_constrain_noop_outside_context():
    import jax.numpy as jnp

    from repro.parallel.sharding import constrain

    x = jnp.ones((4, 4))
    y = constrain(x, "batch", None)  # no mesh/rules active → identity
    assert (y == x).all()


class _FakeMesh:
    """spec_for_axes only reads mesh.shape — enough to exercise multi-way
    guards in a single-device test process."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH24 = _FakeMesh({"data": 2, "model": 4})


def test_priority_order_contention_multiway():
    """Both "vocab" and "ff" want `model`; "vocab" is earlier in PRIORITY, so
    it wins regardless of dim order and the loser replicates."""
    spec = spec_for_axes(("ff", "vocab"), mesh=MESH24, rules=RULES)
    assert spec == P(None, "model")
    spec = spec_for_axes(("vocab", "ff"), mesh=MESH24, rules=RULES)
    assert spec == P("model", None)


def test_divisibility_fallback_multiway():
    spec = spec_for_axes(
        ("ff", "q_heads"), mesh=MESH24, rules=RULES, dim_sizes=(6, 8)
    )
    # ff=6 doesn't divide the 4-way model axis; q_heads=8 then claims it
    assert spec == P(None, "model")


def test_multi_axis_tuple_partial_overlap_drops_whole_candidate():
    """A rule mapping to ("data", "model") with `model` already claimed must
    drop the *whole* tuple (no partial sharding) — and say why."""
    rules = Rules({"ff": "model", "batch": ("data", "model")})
    reasons: list[tuple[str, str]] = []
    spec = spec_for_axes(
        ("batch", "ff"), mesh=MESH24, rules=rules,
        explain=lambda axis, why: reasons.append((axis, why)),
    )
    assert spec == P(None, "model")  # ff (higher priority) holds model
    assert reasons and reasons[0][0] == "batch"
    assert "model" in reasons[0][1]


def test_explain_hook_reports_divisibility():
    reasons = []
    spec_for_axes(
        ("q_heads",), mesh=MESH24, rules=RULES, dim_sizes=(6,),
        explain=lambda axis, why: reasons.append((axis, why)),
    )
    assert reasons == [(
        "q_heads", "dim 6 not divisible by mesh axes ['model'] (size 4)"
    )]


def test_record_spec_fallbacks_collects_and_counts():
    from repro.parallel.sharding import record_spec_fallbacks

    with record_spec_fallbacks() as fb:
        spec_for_axes(("q_heads",), mesh=MESH24, rules=RULES, dim_sizes=(6,))
        spec_for_axes(("q_heads",), mesh=MESH24, rules=RULES, dim_sizes=(6,))
        spec_for_axes(("ff", "vocab"), mesh=MESH24, rules=RULES)
    assert len(fb) == 2  # deduped by (axis, reason), counted
    (ax0, why0), n0 = next(iter(fb.items())), fb[next(iter(fb))]
    assert ax0[0] == "q_heads" and n0 == 2
    # outside the context nothing records
    spec_for_axes(("q_heads",), mesh=MESH24, rules=RULES, dim_sizes=(6,))
    assert sum(fb.values()) == 3


def test_pairing_meta_axis_replicates_by_rule():
    """The base tables map "pairing_meta" to None — replicated lanes — for
    every arch × mode; placement beside the weight shard comes only from
    paired_shardings_for."""
    for mode in ("train", "prefill", "decode"):
        r = rules_for(get_config("qwen2-1.5b"), mode, MESH24)
        assert r.mesh_axes("pairing_meta") is None


class TestPairingMetaSpec:
    """_pairing_meta_spec derives metadata placement from the *weight's*
    resolved spec — never a fresh rule resolution."""

    def _spec(self, *entries):
        return P(*entries)

    def test_column_sharded_blocked_rides_with_weight(self):
        from repro.parallel.sharding import _pairing_meta_spec

        # wq (L, K, H, hd) sharded on its heads dim; 8 blocks, 4 shards
        got = _pairing_meta_spec(
            "wq", ("layers", "embed", "q_heads", "head_dim"),
            self._spec(None, None, "model", None),
            (2, 16, 4, 2), (2, 8, 5), MESH24,
        )
        assert got == P(None, "model", None)

    def test_row_sharded_weight_metadata_replicates(self):
        from repro.parallel.sharding import _pairing_meta_spec

        got = _pairing_meta_spec(
            "wo", ("layers", "q_heads", "head_dim", "embed"),
            self._spec(None, "model", None, None),
            (2, 4, 2, 16), (2, 16, 5), MESH24,
        )
        assert got == P(None, None, None)

    def test_block_misalignment_replicates(self):
        from repro.parallel.sharding import _pairing_meta_spec

        # 6 blocks over a 4-way shard: boundaries don't align → replicate
        got = _pairing_meta_spec(
            "wq", ("layers", "embed", "ff"),
            self._spec(None, None, "model"),
            (2, 16, 12), (2, 6, 5), MESH24,
        )
        assert got == P(None, None, None)

    def test_structured_metadata_replicates(self):
        from repro.parallel.sharding import _pairing_meta_spec

        got = _pairing_meta_spec(
            "wq", ("layers", "embed", "ff"),
            self._spec(None, None, "model"),
            (2, 16, 8), (2, 7), MESH24,
        )
        assert got == P(None, None)

    def test_expert_axis_copies_weight_spec(self):
        from repro.parallel.sharding import _pairing_meta_spec

        got = _pairing_meta_spec(
            "w_up", ("layers", "experts", "embed", "expert_ff"),
            self._spec(None, "model", None, None),
            (2, 4, 8, 8), (2, 4, 8, 5), MESH24,
        )
        assert got == P(None, "model", None, None)


def test_paired_shardings_for_places_metadata_with_weight():
    """End to end on a real (1, n) mesh: the `_pairing` sibling dict gets
    NamedShardings whose block axis copies the weight's resolved spec."""
    import numpy as np

    from repro.core.transform import pair_params
    from repro.models.param import pairing_axes
    from repro.parallel.sharding import paired_shardings_for

    mesh = _mesh2()
    rng = np.random.default_rng(0)
    wq = rng.normal(size=(2, 16, 8)).astype(np.float32)
    tree = {"segments": [{"attn": {"wq": wq}}]}
    pm, _ = pair_params(
        tree, 0.05, mode="per_column", leaves=(("attn", "wq"),)
    )
    axes = {"segments": [{"attn": {"wq": ("layers", "embed", "q_heads")}}]}
    paxes = pairing_axes(pm, axes)
    rules = Rules({"q_heads": "model", "embed": None, "pairing_meta": None})
    sh = paired_shardings_for(paxes, mesh, rules, pm)
    seg = sh["segments"][0]["attn"]
    assert seg["wq"].spec == P(None, None, "model")
    meta = seg["wq_pairing"]
    assert set(meta) == {"I", "J", "resid", "pair_mask", "resid_mask"}
    for leaf in meta.values():
        # 8 blocks divide the model axis → block dim rides with the weight
        assert leaf.spec == P(None, "model", None)
