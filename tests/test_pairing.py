"""Tests for the paper's Algorithm 1 and its vectorised / structured variants."""
import numpy as np
import pytest

from _proptest import cases, floats, integers, sampled_from, seeds

from repro.core.pairing import (
    pair_list_twopointer,
    pair_columns,
    fold_columns,
    pair_rows_blocked,
    pair_rows_structured,
    pairing_op_counts,
    column_pairing_for_conv,
)


def test_rounding_zero_finds_no_pairs():
    """Table I row 0: rounding size 0 → zero subtractions."""
    rng = np.random.default_rng(0)
    w = rng.normal(size=100)
    res = pair_list_twopointer(w, 0.0)
    assert res.n_pairs == 0
    assert len(res.uncombined) == 100


def test_exact_opposites_pair_fully():
    w = np.array([0.5, -0.5, 0.25, -0.25, 1.0, -1.0])
    res = pair_list_twopointer(w, 1e-9)
    assert res.n_pairs == 3
    # pair magnitudes are the common |value|
    assert sorted(res.pair_mag.tolist()) == [0.25, 0.5, 1.0]
    # each pair is (positive index, negative index)
    for i, j in zip(res.pair_pos, res.pair_neg, strict=True):
        assert w[i] > 0 and w[j] < 0
        assert abs(w[i] + w[j]) < 1e-12


def test_pairs_within_rounding_only():
    w = np.array([0.50, -0.53, 0.20, -0.35])
    res = pair_list_twopointer(w, 0.05)
    assert res.n_pairs == 1  # only (0.50, -0.53) is within 0.05
    assert res.pair_mag[0] == pytest.approx(0.515)


def test_monotone_in_rounding():
    """Bigger rounding ⇒ at least as many pairs (Table I trend)."""
    rng = np.random.default_rng(1)
    w = rng.normal(size=500)
    last = -1
    for r in [0.0, 0.0001, 0.005, 0.05, 0.1, 0.3]:
        n = pair_list_twopointer(w, r).n_pairs
        assert n >= last
        last = n


def test_every_weight_accounted_once():
    rng = np.random.default_rng(2)
    w = rng.normal(size=301)
    res = pair_list_twopointer(w, 0.02)
    touched = np.concatenate([res.pair_pos, res.pair_neg, res.uncombined])
    assert sorted(touched.tolist()) == list(range(301))


@cases(30, k=integers(1, 60), n=integers(1, 8), rounding=floats(0.0, 0.5), seed=seeds())
def test_pair_columns_matches_twopointer_oracle(k, n, rounding, seed):
    """The vectorised per-column pairing is bit-identical to Algorithm 1."""
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(k, n)) * rng.uniform(0.1, 2.0)
    cp = pair_columns(W, rounding)
    for col in range(n):
        ref = pair_list_twopointer(W[:, col], rounding)
        got = cp.n_pairs[col]
        assert got == ref.n_pairs
        if ref.n_pairs:
            assert cp.pair_pos[: got, col].tolist() == ref.pair_pos.tolist()
            assert cp.pair_neg[: got, col].tolist() == ref.pair_neg.tolist()
            np.testing.assert_allclose(cp.pair_mag[: got, col], ref.pair_mag)


@cases(20, k=integers(2, 40), n=integers(1, 6), rounding=floats(1e-4, 0.3), seed=seeds())
def test_fold_error_bounded_by_half_rounding(k, n, rounding, seed):
    """Snapping both pair members to k=(|a|+|b|)/2 perturbs each weight by
    at most rounding/2 — the accuracy knob the paper advertises."""
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(k, n))
    cp = pair_columns(W, rounding)
    Wf = fold_columns(W, cp)
    assert np.max(np.abs(Wf - W)) <= rounding / 2 + 1e-12


def test_fold_equals_subtractor_dataflow():
    """fold_columns produces the matrix whose plain matmul equals the
    subtractor evaluation k*(x_i - x_j) + residual MACs."""
    rng = np.random.default_rng(3)
    W = rng.normal(size=(32, 4))
    x = rng.normal(size=(5, 32))
    cp = pair_columns(W, 0.1)
    Wf = fold_columns(W, cp)
    # manual subtractor evaluation, per column
    y = np.zeros((5, 4))
    for col in range(4):
        used = np.zeros(32, dtype=bool)
        for p in range(cp.n_pairs[col]):
            i, j = cp.pair_pos[p, col], cp.pair_neg[p, col]
            k = cp.pair_mag[p, col]
            y[:, col] += k * (x[:, i] - x[:, j])  # eq. (1)
            used[[i, j]] = True
        y[:, col] += x[:, ~used] @ W[~used, col]
    np.testing.assert_allclose(y, x @ Wf, rtol=1e-12, atol=1e-12)


def test_op_counts():
    c = pairing_op_counts(total_weights=150, n_pairs=20, positions=100)
    assert c["mults"] == c["adds"] == (150 - 20) * 100
    assert c["subs"] == 20 * 100
    assert c["total"] == c["baseline_total"] - c["subs"]


def test_conv_pairing_is_per_filter():
    """Pairs must never cross output channels (they accumulate separately)."""
    rng = np.random.default_rng(4)
    kern = rng.normal(size=(5, 5, 3, 8))
    cp = column_pairing_for_conv(kern, 0.05)
    assert cp.shape == (75, 8)
    flat = kern.reshape(75, 8)
    for col in range(8):
        for p in range(cp.n_pairs[col]):
            i, j = cp.pair_pos[p, col], cp.pair_neg[p, col]
            assert flat[i, col] > 0 and flat[j, col] < 0


# ---------------------------------------------------------------------------
# structured pairing
# ---------------------------------------------------------------------------


def test_structured_partition_is_exact():
    rng = np.random.default_rng(5)
    W = rng.normal(size=(64, 16))
    sp = pair_rows_structured(W, 0.2)
    perm = sp.perm()
    assert sorted(perm.tolist()) == list(range(64))


@cases(20, k=integers(2, 64), n=integers(1, 8), rounding=floats(1e-3, 0.5), seed=seeds())
def test_structured_fold_error_bound(k, n, rounding, seed):
    """Structured pairing drops only the symmetric part s with rms(s) < r/…
    — elementwise error of the folded matrix is bounded by the criterion."""
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(k, n))
    sp = pair_rows_structured(W, rounding, criterion="max")
    Wf = sp.fold()
    # error only on paired rows, equals |symmetric part| < rounding/2
    err = np.abs(Wf - W)
    assert err.max(initial=0.0) <= rounding / 2 + 1e-12


def test_structured_matmul_equivalence():
    """(x[:,I]-x[:,J]) @ Kmat + x[:,R] @ W_res  ==  x @ fold()."""
    rng = np.random.default_rng(6)
    W = rng.normal(size=(48, 12))
    x = rng.normal(size=(7, 48))
    sp = pair_rows_structured(W, 0.3)
    y_paired = (x[:, sp.I] - x[:, sp.J]) @ sp.Kmat + x[:, sp.resid] @ sp.W_res
    np.testing.assert_allclose(y_paired, x @ sp.fold(), rtol=1e-12, atol=1e-12)


def test_structured_antisymmetric_pairs_everything():
    """A perfectly antisymmetric weight matrix pairs all rows."""
    rng = np.random.default_rng(7)
    half = rng.normal(size=(32, 8)) + 3.0  # keep means positive
    W = np.concatenate([half, -half], axis=0)
    sp = pair_rows_structured(W, 1e-6)
    assert sp.n_pairs == 32
    np.testing.assert_allclose(sp.fold(), W, atol=1e-12)


# ---------------------------------------------------------------------------
# column-blocked pairing (the structured ↔ per-column spectrum)
# ---------------------------------------------------------------------------


def _random_matrix(rng, k, n):
    """Weight matrix with enough opposite-sign structure to pair sometimes."""
    W = rng.normal(size=(k, n)) * rng.uniform(0.1, 2.0)
    return W


@cases(
    30, k=integers(2, 60), n=integers(1, 10), block=integers(1, 12),
    rounding=floats(0.0, 0.5), seed=seeds(),
)
def test_blocked_is_a_valid_permutation_per_block(k, n, block, rounding, seed):
    """Every block partitions the K rows exactly: each row appears exactly
    once in its block's [I | J | resid], and blocks tile the columns."""
    W = _random_matrix(np.random.default_rng(seed), k, n)
    bp = pair_rows_blocked(W, rounding, block)
    assert bp.shape == (k, n)
    covered = 0
    for b, sp in enumerate(bp.blocks):
        lo, hi = bp.block_cols(b)
        assert sp.shape == (k, hi - lo)
        assert sorted(sp.perm().tolist()) == list(range(k))
        covered += hi - lo
    assert covered == n


@cases(
    20, k=integers(2, 50), n=integers(1, 8), block=integers(1, 10),
    seed=seeds(),
)
def test_blocked_rounding_zero_reconstructs_exactly(k, n, block, seed):
    """rounding 0 → no pairs → fold() IS W and x @ fold() == x @ W exactly."""
    rng = np.random.default_rng(seed)
    W = _random_matrix(rng, k, n)
    bp = pair_rows_blocked(W, 0.0, block)
    assert bp.n_pairs == 0 and bp.weighted_pairs == 0
    np.testing.assert_array_equal(bp.fold(), W)
    x = rng.normal(size=(5, k))
    np.testing.assert_array_equal(x @ bp.fold(), x @ W)


@cases(
    25, k=integers(2, 50), n=integers(1, 8), block=integers(1, 10),
    rounding=floats(1e-3, 0.5), seed=seeds(),
    criterion=sampled_from(["rms", "max"]),
)
def test_blocked_symmetric_error_bound(k, n, block, rounding, seed, criterion):
    """Folding drops only the symmetric part of each pair, bounded by the
    criterion: per paired row, max-norm error ≤ r/2 under "max" and
    rms error ≤ r/2 under "rms"."""
    W = _random_matrix(np.random.default_rng(seed), k, n)
    bp = pair_rows_blocked(W, rounding, block, criterion=criterion)
    for b, sp in enumerate(bp.blocks):
        lo, hi = bp.block_cols(b)
        err = np.abs(sp.fold() - W[:, lo:hi])
        if criterion == "max":
            assert err.max(initial=0.0) <= rounding / 2 + 1e-12
        else:
            row_rms = np.sqrt((err**2).mean(axis=1))
            assert row_rms.max(initial=0.0) <= rounding / 2 + 1e-12


@cases(
    25, k=integers(2, 60), n=integers(1, 8), rounding=floats(0.0, 0.5),
    seed=seeds(),
)
def test_blocked_at_block_N_is_structured(k, n, rounding, seed):
    """block_n >= N degenerates to pair_rows_structured, index for index."""
    W = _random_matrix(np.random.default_rng(seed), k, n)
    bp = pair_rows_blocked(W, rounding, n + int(seed) % 3)  # >= N
    sp = pair_rows_structured(W, rounding)
    assert bp.n_blocks == 1
    got = bp.blocks[0]
    np.testing.assert_array_equal(got.I, sp.I)
    np.testing.assert_array_equal(got.J, sp.J)
    np.testing.assert_array_equal(got.resid, sp.resid)
    np.testing.assert_array_equal(bp.fold(), sp.fold())


@cases(
    25, k=integers(1, 60), n=integers(1, 8), rounding=floats(0.0, 0.5),
    seed=seeds(),
)
def test_blocked_at_block_1_is_per_column(k, n, rounding, seed):
    """block_n == 1 reproduces Algorithm 1's per-column ledger exactly:
    same pair count per column, same folded matrix, bit for bit."""
    W = _random_matrix(np.random.default_rng(seed), k, n)
    bp = pair_rows_blocked(W, rounding, 1)
    cp = pair_columns(W, rounding)
    assert bp.n_blocks == n
    for col, sp in enumerate(bp.blocks):
        assert sp.n_pairs == cp.n_pairs[col], col
        got = sorted(zip(sp.I.tolist(), sp.J.tolist(), strict=True))
        want = sorted(
            zip(
                cp.pair_pos[: cp.n_pairs[col], col].tolist(),
                cp.pair_neg[: cp.n_pairs[col], col].tolist(),
                strict=True,
            )
        )
        assert got == want, col
    assert bp.weighted_pairs == cp.total_pairs
    np.testing.assert_array_equal(bp.fold(), fold_columns(W, cp))


@cases(
    20, k=integers(2, 40), n=integers(2, 8), block=integers(1, 8),
    rounding=floats(1e-3, 0.6), seed=seeds(),
)
def test_blocked_packed_layout_roundtrips(k, n, block, rounding, seed):
    """The packed kernel metadata (index_arrays + packed_weights) evaluates
    to the same matrix product as fold(): gather x through the packed perm,
    contract the padded segments, compare against x @ fold()."""
    rng = np.random.default_rng(seed)
    W = _random_matrix(rng, k, n)
    # plant antisymmetric structure so pairs actually exist sometimes
    if k >= 4:
        W[1] = -W[0] + rng.normal(size=n) * rounding * 0.1
    bp = pair_rows_blocked(W, rounding, block)
    idx = bp.index_arrays()
    km, wr = bp.packed_weights()
    P, R = bp.Pmax, bp.Rmax
    x = rng.normal(size=(6, k))
    xg = x[:, idx["perm"]].transpose(1, 0, 2)  # (B, M, 2P+R)
    y = np.einsum("bmp,bpn->bmn", xg[..., :P] - xg[..., P : 2 * P], km)
    y += np.einsum("bmr,brn->bmn", xg[..., 2 * P :], wr)
    got = y.transpose(1, 0, 2).reshape(6, -1)[:, :n]
    np.testing.assert_allclose(got, x @ bp.fold(), rtol=1e-10, atol=1e-10)
