"""Fused conv→pool→activation megakernel + generalized im2col.

Covers the PR-3 acceptance gates: fused conv+pool equals the unfused XLA
``conv → reduce_window`` reference ≤ 1e-5 at rounding 0 on all three LeNet
conv geometries plus strided/padded non-LeNet geometries (max and mean
windows), gradient parity of the custom VJP under ``jax.grad``, the
arbitrary-stride / SAME / explicit-padding im2col with its exact ``col2im``
adjoint, and the LeNet wiring (``fuse_pool`` drops the standalone pooling
ops from the traced program — one kernel writeback per conv layer).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# the same jaxpr walker CI's bench gate uses — test and gate must agree on
# what "no standalone pool op" means (conftest puts the repo root on the path)
from benchmarks.common import count_primitives as _count_prims

from repro.core.pairing import pair_rows_structured
from repro.core.transform import build_conv_pairings
from repro.kernels.im2col import col2im, conv_output_hw, im2col, overlap_counts
from repro.kernels.ops import pallas_conv
from repro.kernels.paired_conv import conv_im2col, paired_conv, pool2_reference
from repro.models.lenet import init_lenet, lenet_apply

# (input NHWC, kernel HWIO, stride, padding) — the three LeNet conv
# geometries (conv3 fed a larger input so its 2×2 pool is nonempty) plus
# strided / SAME / explicitly-padded non-LeNet geometries.
LENET_POOL_CASES = [
    ((2, 32, 32, 1), (5, 5, 1, 6), (1, 1), "VALID"),
    ((2, 14, 14, 6), (5, 5, 6, 16), (1, 1), "VALID"),
    ((2, 12, 12, 16), (5, 5, 16, 120), (1, 1), "VALID"),
]
STRIDED_PADDED_CASES = [
    ((2, 13, 13, 3), (3, 3, 3, 8), (2, 2), "SAME"),
    ((1, 16, 12, 4), (3, 5, 4, 7), (1, 2), ((1, 1), (2, 2))),
]
ALL_CASES = LENET_POOL_CASES + STRIDED_PADDED_CASES


def _xla_conv(x, w, b=None, stride=(1, 1), padding="VALID"):
    pad = padding if isinstance(padding, str) else list(padding)
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=stride, padding=pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y if b is None else y + b


def _xla_pool(y, pool):
    if pool == "max2":
        return jax.lax.reduce_window(
            y, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
        )
    s = jax.lax.reduce_window(
        y, 0.0, jax.lax.add, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )
    return s / 4.0


def _zero_pairing(kshape):
    kh, kw, cin, cout = kshape
    w = np.random.default_rng(sum(kshape)).normal(size=kshape).astype(np.float32)
    sp = pair_rows_structured(
        w.astype(np.float64).reshape(kh * kw * cin, cout), 0.0
    )
    assert sp.n_pairs == 0
    return jnp.asarray(w), sp


# ---------------------------------------------------------------------------
# generalized im2col
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("xshape,kshape,stride,padding", ALL_CASES)
def test_im2col_strided_padded_matches_conv(xshape, kshape, stride, padding):
    rng = np.random.default_rng(xshape[1] + kshape[0])
    x = jnp.asarray(rng.normal(size=xshape), jnp.float32)
    w = jnp.asarray(rng.normal(size=kshape), jnp.float32)
    kh, kw, cin, cout = kshape
    got = conv_im2col(x, w, stride=stride, padding=padding)
    want = _xla_conv(x, w, stride=stride, padding=padding)
    assert got.shape == want.shape
    oh, ow = conv_output_hw(
        xshape[1], xshape[2], kh, kw, stride=stride, padding=padding
    )
    assert want.shape[1:3] == (oh, ow)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("xshape,kshape,stride,padding", STRIDED_PADDED_CASES)
def test_col2im_adjoint_strided_padded(xshape, kshape, stride, padding):
    """<im2col(x), y> == <x, col2im(y)> holds at every stride/padding."""
    rng = np.random.default_rng(7)
    kh, kw = kshape[0], kshape[1]
    x = jnp.asarray(rng.normal(size=xshape), jnp.float32)
    cols = im2col(x, kh, kw, stride=stride, padding=padding)
    y = jnp.asarray(rng.normal(size=cols.shape), jnp.float32)
    lhs = float(jnp.vdot(cols, y))
    rhs = float(jnp.vdot(
        x, col2im(y, xshape, kh, kw, stride=stride, padding=padding)
    ))
    assert abs(lhs - rhs) <= 1e-3 * max(1.0, abs(lhs))


def test_overlap_counts_strided():
    """Stride-2 extraction covers each pixel at most once per kernel tap,
    and the counts identity col2im(im2col(1)) holds."""
    counts = np.asarray(overlap_counts((1, 9, 9, 2), 3, 3, stride=2))
    assert counts.max() <= 9 and counts.min() >= 0
    ones = jnp.ones((1, 9, 9, 2), jnp.float32)
    back = col2im(im2col(ones, 3, 3, stride=2), (1, 9, 9, 2), 3, 3, stride=2)
    np.testing.assert_allclose(np.asarray(back), counts)


def test_im2col_default_args_unchanged():
    """The stride-1/VALID default reproduces the original LeNet extraction."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, 10, 10, 3)), jnp.float32)
    a = im2col(x, 5, 5)
    b = im2col(x, 5, 5, stride=1, padding="VALID")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert conv_output_hw(10, 10, 5, 5) == (6, 6)


# ---------------------------------------------------------------------------
# fused conv→pool vs the unfused XLA reference (acceptance gate)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pool", ["max2", "avg2"])
@pytest.mark.parametrize("xshape,kshape,stride,padding", ALL_CASES)
def test_fused_pool_matches_xla_reference(xshape, kshape, stride, padding, pool):
    """r=0 fused conv+pool == XLA conv → bias → relu → reduce_window ≤1e-5."""
    rng = np.random.default_rng(kshape[3] + xshape[1])
    x = jnp.asarray(rng.normal(size=xshape), jnp.float32)
    w, sp = _zero_pairing(kshape)
    b = jnp.asarray(rng.normal(size=(kshape[3],)), jnp.float32)

    got = paired_conv(
        x, w, b, pairing=sp, activation="relu",
        stride=stride, padding=padding, pool=pool,
    )
    want = _xla_pool(
        jax.nn.relu(_xla_conv(x, w, b, stride=stride, padding=padding)), pool
    )
    assert got.shape == want.shape
    rel = float(
        jnp.abs(got - want).max() / jnp.maximum(jnp.abs(want).max(), 1e-30)
    )
    assert rel <= 1e-5, f"{pool} {xshape}->{kshape}: rel err {rel:.2e}"


def test_pool2_reference_matches_reduce_window():
    """The pure-jnp pooling oracle trims odd edges exactly like VALID
    reduce_window (including an odd-sized map)."""
    rng = np.random.default_rng(11)
    y = jnp.asarray(rng.normal(size=(2, 7, 9, 5)), jnp.float32)
    for pool in ("max2", "avg2"):
        np.testing.assert_allclose(
            np.asarray(pool2_reference(y, pool)),
            np.asarray(_xla_pool(y, pool)),
            rtol=1e-6, atol=1e-6,
        )


@pytest.mark.parametrize("pool", ["max2", "avg2"])
def test_fused_pool_grad_parity(pool):
    """Custom-VJP gradients through the fused kernel match the XLA path."""
    xshape, kshape = (2, 14, 14, 6), (5, 5, 6, 16)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=xshape), jnp.float32)
    w, sp = _zero_pairing(kshape)
    b = jnp.asarray(rng.normal(size=(kshape[3],)), jnp.float32)

    def loss_fused(x, w, b):
        y = paired_conv(x, w, b, pairing=sp, activation="relu", pool=pool)
        return (y ** 2).mean()

    def loss_ref(x, w, b):
        return (_xla_pool(jax.nn.relu(_xla_conv(x, w, b)), pool) ** 2).mean()

    g_fused = jax.grad(loss_fused, argnums=(0, 1, 2))(x, w, b)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, r in zip(g_fused, g_ref, strict=True):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(r), rtol=1e-3, atol=1e-4
        )


def test_fused_pool_positive_rounding_matches_oracle():
    """At r > 0 the fused kernel equals its folded-dense pooled oracle."""
    from repro.kernels.paired_conv import paired_conv_ref

    xshape, kshape = (2, 12, 12, 4), (3, 3, 4, 8)
    rounding = 0.2
    rng = np.random.default_rng(9)
    kh, kw, cin, cout = kshape
    K = kh * kw * cin
    P = K // 4
    half = rng.normal(size=(P, cout)) * 0.3 + 1.0
    rest = rng.normal(size=(K - 2 * P, cout)) * 0.02
    wm = np.concatenate([half, -half, rest]).astype(np.float32)
    sp = pair_rows_structured(wm.astype(np.float64), rounding)
    assert sp.n_pairs >= P
    x = jnp.asarray(rng.normal(size=xshape), jnp.float32)
    w = jnp.asarray(wm.reshape(kshape))
    got = paired_conv(x, w, None, pairing=sp, activation="relu", pool="max2")
    want = paired_conv_ref(x, w, None, sp, activation="relu", pool="max2")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
    )


# ---------------------------------------------------------------------------
# LeNet wiring: fuse_pool drops the standalone pooling ops
# ---------------------------------------------------------------------------


def test_lenet_fused_pool_forward_and_schedule():
    params = init_lenet(jax.random.key(0))
    x = jnp.asarray(
        np.random.default_rng(1).normal(size=(2, 32, 32, 1)), jnp.float32
    )
    arts = build_conv_pairings(params, 0.0)
    y_ref = lenet_apply(params, x)
    y_fused = lenet_apply(
        params, x, conv_impl="pallas_paired", paired=arts, fuse_pool=True
    )
    rel = float(jnp.abs(y_fused - y_ref).max() / jnp.abs(y_ref).max())
    assert rel <= 1e-5

    # policy-driven, under jit: same result, and the traced program has no
    # standalone pooling op — each conv layer is exactly one kernel launch
    # (one HBM writeback)
    with pallas_conv(paired=arts, fuse_pool=True):
        y_pol = jax.jit(lambda p, xb: lenet_apply(p, xb))(params, x)
        jaxpr = jax.make_jaxpr(lambda p, xb: lenet_apply(p, xb))(params, x)
    np.testing.assert_allclose(
        np.asarray(y_pol), np.asarray(y_fused), rtol=1e-6, atol=1e-6
    )
    assert _count_prims(jaxpr, "reduce_window_max") == 0
    assert _count_prims(jaxpr, "pallas_call") == 3

    # unfused paired path keeps its two pooling ops
    with pallas_conv(paired=arts, fuse_pool=False):
        jaxpr_unfused = jax.make_jaxpr(
            lambda p, xb: lenet_apply(p, xb)
        )(params, x)
    assert _count_prims(jaxpr_unfused, "reduce_window_max") == 2


def test_lenet_fused_pool_grad():
    params = init_lenet(jax.random.key(2))
    x = jnp.asarray(
        np.random.default_rng(2).normal(size=(2, 32, 32, 1)), jnp.float32
    )
    arts = build_conv_pairings(params, 0.0)
    g_ref = jax.grad(lambda p: (lenet_apply(p, x) ** 2).mean())(params)
    with pallas_conv(paired=arts, fuse_pool=True):
        g = jax.jit(
            jax.grad(lambda p: (lenet_apply(p, x) ** 2).mean())
        )(params)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g), strict=True):
        np.testing.assert_allclose(
            np.asarray(b), np.asarray(a), rtol=1e-3, atol=1e-4
        )


# ---------------------------------------------------------------------------
# column-blocked pairing through the fused conv→pool megakernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("block_n", [1, 4])
@pytest.mark.parametrize(
    "xshape,kshape,stride,padding",
    LENET_POOL_CASES + STRIDED_PADDED_CASES[:1],
)
def test_blocked_fused_pool_matches_xla(xshape, kshape, stride, padding, block_n):
    """r=0 fused conv+pool through the column-blocked layout == XLA conv →
    bias → relu → reduce_window ≤ 1e-5 (per-n-block metadata must not
    disturb the pooling epilogue)."""
    from repro.core.pairing import pair_rows_blocked

    rng = np.random.default_rng(kshape[3] + xshape[1] + block_n)
    x = jnp.asarray(rng.normal(size=xshape), jnp.float32)
    w = jnp.asarray(rng.normal(size=kshape), jnp.float32)
    b = jnp.asarray(rng.normal(size=(kshape[3],)), jnp.float32)
    kh, kw, cin, cout = kshape
    bp = pair_rows_blocked(
        np.asarray(w, np.float64).reshape(kh * kw * cin, cout), 0.0, block_n
    )
    got = paired_conv(
        x, w, b, pairing=bp, activation="relu",
        stride=stride, padding=padding, pool="max2",
    )
    want = _xla_pool(
        jax.nn.relu(_xla_conv(x, w, b, stride=stride, padding=padding)), "max2"
    )
    assert got.shape == want.shape
    rel = float(
        jnp.abs(got - want).max() / jnp.maximum(jnp.abs(want).max(), 1e-30)
    )
    assert rel <= 1e-5, f"block_n={block_n} {xshape}->{kshape}: rel {rel:.2e}"


def test_blocked_lenet_fused_pool_schedule_and_grad():
    """LeNet through column-blocked artifacts with fuse_pool: identical
    schedule audit (0 standalone pool ops, 3 writebacks), r=0 forward
    parity, and XLA-matching gradients under jit+grad."""
    params = init_lenet(jax.random.key(8))
    x = jnp.asarray(
        np.random.default_rng(8).normal(size=(2, 32, 32, 1)), jnp.float32
    )
    arts = build_conv_pairings(params, 0.0, mode="column_blocked", block_n=4)
    y_ref = lenet_apply(params, x)
    with pallas_conv(paired=arts, fuse_pool=True):
        y_blk = jax.jit(lambda p, xb: lenet_apply(p, xb))(params, x)
        jaxpr = jax.make_jaxpr(lambda p, xb: lenet_apply(p, xb))(params, x)
    rel = float(jnp.abs(y_blk - y_ref).max() / jnp.abs(y_ref).max())
    assert rel <= 1e-5
    assert _count_prims(jaxpr, "reduce_window_max") == 0
    assert _count_prims(jaxpr, "pallas_call") == 3

    g_ref = jax.grad(lambda p: (lenet_apply(p, x) ** 2).mean())(params)
    with pallas_conv(paired=arts, fuse_pool=True):
        g = jax.jit(
            jax.grad(lambda p: (lenet_apply(p, x) ** 2).mean())
        )(params)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g), strict=True):
        np.testing.assert_allclose(
            np.asarray(b), np.asarray(a), rtol=1e-3, atol=1e-4
        )


def test_blocked_fused_pool_positive_rounding_matches_oracle():
    """At r > 0 the blocked megakernel equals its folded pooled oracle, with
    a nontrivial per-block pairing actually engaged."""
    from repro.core.pairing import pair_rows_blocked
    from repro.kernels.paired_conv import paired_conv_ref

    xshape, kshape = (2, 12, 12, 4), (3, 3, 4, 8)
    rounding = 0.2
    rng = np.random.default_rng(19)
    kh, kw, cin, cout = kshape
    K = kh * kw * cin
    P = K // 4
    half = rng.normal(size=(P, cout)) * 0.3 + 1.0
    rest = rng.normal(size=(K - 2 * P, cout)) * 0.02
    wm = np.concatenate([half, -half, rest]).astype(np.float32)
    bp = pair_rows_blocked(wm.astype(np.float64), rounding, 3)
    assert bp.n_pairs >= P  # every block recovers the planted rows
    x = jnp.asarray(rng.normal(size=xshape), jnp.float32)
    w = jnp.asarray(wm.reshape(kshape))
    got = paired_conv(x, w, None, pairing=bp, activation="relu", pool="max2")
    want = paired_conv_ref(x, w, None, bp, activation="relu", pool="max2")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
    )


def test_lenet_fuse_pool_ignored_off_pallas_path():
    """fuse_pool is a no-op for the xla/im2col lowerings (no megakernel)."""
    params = init_lenet(jax.random.key(3))
    x = jnp.asarray(
        np.random.default_rng(3).normal(size=(1, 32, 32, 1)), jnp.float32
    )
    y0 = lenet_apply(params, x, conv_impl="xla")
    y1 = lenet_apply(params, x, conv_impl="xla", fuse_pool=True)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
