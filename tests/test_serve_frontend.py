"""Hardened serving front end: continuous batching, chunked prefill,
fault injection, numeric watchdog, and graceful degradation.

The invariant every test circles: a request either completes with the exact
greedy token stream a fresh reference engine produces for its prompt, or is
shed with a structured reason — never lost, never garbage tokens."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import lm as M
from repro.models.param import unzip
from repro.serving import (
    FaultEvent,
    FaultInjector,
    FrontendConfig,
    GuardConfig,
    Request,
    ServeEngine,
    ServeFrontend,
    check_logits,
    faulted_request_ids,
    poisson_workload,
)

MAX_SEQ = 32
BATCH = 2
_BASE = dict(q_chunk=16, k_chunk=16, remat="none")


@pytest.fixture(scope="module")
def stack():
    """(cfg, params, primary, fallback) — engines are module-scoped so the
    jitted step functions compile once; _reset() clears slot state."""
    cfg = dataclasses.replace(get_smoke_config("qwen2-1.5b"), dtype="float32")
    params, _ = unzip(M.init_lm(cfg, jax.random.key(0)))
    primary = ServeEngine(cfg, params, max_seq=MAX_SEQ, batch_size=BATCH,
                          knobs=M.PerfKnobs(**_BASE))
    fallback = ServeEngine(cfg, params, max_seq=MAX_SEQ, batch_size=BATCH,
                           knobs=M.PerfKnobs(**_BASE))
    return cfg, params, primary, fallback


def _reset(*engines):
    for eng in engines:
        for s in range(eng.batch_size):
            eng.clear_quarantine(s)
            eng.release_slot(s)


def _reference(cfg, params, requests):
    ref = ServeEngine(cfg, params, max_seq=MAX_SEQ, batch_size=1,
                      knobs=M.PerfKnobs(**_BASE))
    out = {}
    for r in requests:
        out[r.rid] = ref.generate({0: r.prompt}, n_steps=r.max_new_tokens)[0]
        ref.release_slot(0)
    return out


def _req(rid, prompt, n, arrival=0.0):
    return Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                   max_new_tokens=n, arrival=arrival)


# ---------------------------------------------------------------------------
# workload generation
# ---------------------------------------------------------------------------

def test_poisson_workload_is_seeded_and_bounded():
    a = poisson_workload(rate_rps=50, horizon_s=1.0, seed=3, vocab=64,
                         prompt_len=(2, 9), new_tokens=(1, 5))
    b = poisson_workload(rate_rps=50, horizon_s=1.0, seed=3, vocab=64,
                         prompt_len=(2, 9), new_tokens=(1, 5))
    assert len(a) == len(b) > 10
    for ra, rb in zip(a, b, strict=True):
        assert ra.arrival == rb.arrival
        assert ra.max_new_tokens == rb.max_new_tokens
        np.testing.assert_array_equal(ra.prompt, rb.prompt)
        assert 2 <= ra.plen < 9 and 1 <= ra.max_new_tokens < 5
        assert 0.0 < ra.arrival <= 1.0
    arrivals = [r.arrival for r in a]
    assert arrivals == sorted(arrivals)


# ---------------------------------------------------------------------------
# clean load: continuous batching + chunked prefill
# ---------------------------------------------------------------------------

def test_clean_load_completes_all_with_reference_parity(stack):
    cfg, params, primary, fallback = stack
    _reset(primary, fallback)
    wl = poisson_workload(rate_rps=25, horizon_s=0.4, seed=1, vocab=cfg.vocab,
                          prompt_len=(3, 18), new_tokens=(2, 5))
    fe = ServeFrontend(primary, fallback, FrontendConfig(prefill_chunk=5))
    report = fe.run(wl, offered_load_rps=25)

    assert report.lost() == []
    summary = report.summary()
    assert summary["completed"] == len(wl) and summary["shed"] == 0
    assert summary["latency_s"]["p50"] is not None
    assert summary["tokens_per_s_virtual"] > 0
    ref = _reference(cfg, params, report.requests)
    for r in report.requests:
        assert r.tokens == ref[r.rid], f"rid {r.rid} diverged"
        assert r.first_token_time is not None
        assert r.finish_time >= r.admit_time >= r.arrival


def test_chunked_prefill_matches_monolithic_prefill(stack):
    """A prompt far longer than prefill_chunk rides the shared decode steps
    one token per step and must still emit exactly the full-prefill stream."""
    cfg, params, primary, fallback = stack
    _reset(primary, fallback)
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab, size=(21,)).astype(np.int32)
    r = _req(0, prompt, 5)
    fe = ServeFrontend(primary, fallback, FrontendConfig(prefill_chunk=4))
    report = fe.run([r])
    assert r.state == "completed"
    assert r.tokens == _reference(cfg, params, [r])[0]


# ---------------------------------------------------------------------------
# faults → watchdog → degradation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["nan_logits", "inf_logits", "kv_poison"])
def test_numeric_fault_degrades_to_exact_fallback(stack, kind):
    cfg, params, primary, fallback = stack
    _reset(primary, fallback)
    rng = np.random.default_rng(13)
    r = _req(0, rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32), 6)
    faults = FaultInjector([FaultEvent(step=1, kind=kind, slot=0)])
    fe = ServeFrontend(primary, fallback, FrontendConfig(prefill_chunk=8),
                       faults=faults)
    report = fe.run([r])

    assert faulted_request_ids(report) == {0}
    assert r.state == "degraded" and r.retries == 1
    assert r.tokens == _reference(cfg, params, [r])[0], \
        "degraded completion must be token-exact vs the reference"
    actions = [i.action for i in report.incidents.for_request(0)]
    assert actions == ["injected", "quarantined", "retried_degraded"]
    # the quarantined slot sat out, then returned to service
    assert not primary.quarantined.any()


def test_kernel_failure_transient_is_retried_without_loss(stack):
    cfg, params, primary, fallback = stack
    _reset(primary, fallback)
    rng = np.random.default_rng(17)
    r = _req(0, rng.integers(0, cfg.vocab, size=(5,)).astype(np.int32), 4)
    faults = FaultInjector([FaultEvent(step=1, kind="kernel_failure",
                                       magnitude=2)])
    cfg_fe = FrontendConfig(prefill_chunk=8, max_kernel_retries=3)
    fe = ServeFrontend(primary, fallback, cfg_fe, faults=faults)
    report = fe.run([r])
    assert r.state == "completed" and not r.degraded
    assert r.tokens == _reference(cfg, params, [r])[0]
    assert report.incidents.counts() == {"injected:kernel_failure": 1}


def test_kernel_failure_exhausted_degrades_active_slots(stack):
    cfg, params, primary, fallback = stack
    _reset(primary, fallback)
    rng = np.random.default_rng(19)
    reqs = [_req(i, rng.integers(0, cfg.vocab, size=(4 + i,)).astype(np.int32), 4)
            for i in range(2)]
    faults = FaultInjector([FaultEvent(step=1, kind="kernel_failure",
                                       magnitude=10)])
    cfg_fe = FrontendConfig(prefill_chunk=8, max_kernel_retries=2)
    fe = ServeFrontend(primary, fallback, cfg_fe, faults=faults)
    report = fe.run(reqs)
    assert report.lost() == []
    ref = _reference(cfg, params, reqs)
    for r in reqs:
        assert r.state == "degraded", "persistent launch failure must degrade"
        assert r.tokens == ref[r.rid]


def test_retries_exhausted_sheds_with_structured_reason(stack):
    cfg, params, primary, fallback = stack
    _reset(primary, fallback)
    rng = np.random.default_rng(23)
    r = _req(0, rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32), 6)
    faults = FaultInjector([FaultEvent(step=1, kind="nan_logits", slot=0)])
    cfg_fe = FrontendConfig(prefill_chunk=8,
                            guard=GuardConfig(max_retries=0))
    fe = ServeFrontend(primary, fallback, cfg_fe, faults=faults)
    report = fe.run([r])
    assert report.lost() == []
    assert r.state == "shed" and r.shed_reason == "retries_exhausted:nan"


# ---------------------------------------------------------------------------
# admission policy: deadlines, queue bounds, length bucketing
# ---------------------------------------------------------------------------

def test_deadline_and_too_long_shed_reasons(stack):
    cfg, params, primary, fallback = stack
    _reset(primary, fallback)
    rng = np.random.default_rng(29)
    too_long = _req(0, rng.integers(0, cfg.vocab, size=(MAX_SEQ - 2,))
                    .astype(np.int32), 8)
    fine = _req(1, rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32), 20,
                arrival=0.0)
    fe = ServeFrontend(primary, fallback,
                       FrontendConfig(prefill_chunk=8, deadline_s=0.05,
                                      step_cost_s=0.01))
    report = fe.run([too_long, fine])
    assert report.lost() == []
    assert too_long.state == "shed" and too_long.shed_reason == "too_long"
    assert fine.state == "shed" and fine.shed_reason == "deadline"
    # the deadline shed freed its slot
    assert not primary.active.any()


def test_queue_full_sheds_overflow_arrivals(stack):
    cfg, params, primary, fallback = stack
    _reset(primary, fallback)
    rng = np.random.default_rng(31)
    reqs = [_req(i, rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32), 3,
                 arrival=0.0) for i in range(6)]
    fe = ServeFrontend(primary, fallback,
                       FrontendConfig(prefill_chunk=8, max_queue=3))
    report = fe.run(reqs)
    assert report.lost() == []
    by = report.by_state()
    assert [r.shed_reason for r in by["shed"]] == ["queue_full"] * len(by["shed"])
    assert len(by["shed"]) >= 1
    assert len(by["completed"]) == len(reqs) - len(by["shed"])


def test_length_bucketed_admission_prefers_lead_bucket(stack):
    cfg, params, primary, fallback = stack
    _reset(primary, fallback)
    fe = ServeFrontend(primary, fallback,
                       FrontendConfig(bucket_width=8, prefill_chunk=8))
    rng = np.random.default_rng(37)
    mk = lambda rid, plen, t: _req(  # noqa: E731
        rid, rng.integers(0, cfg.vocab, size=(plen,)).astype(np.int32),
        2, arrival=t)
    # oldest request is short → its bucket (short prompts) admits first even
    # though a long request arrived in between
    queue = [mk(0, 3, 0.0), mk(1, 20, 0.001), mk(2, 4, 0.002)]
    order = fe._bucket_order(queue, now=1.0)
    assert [r.rid for r in order] == [0, 2, 1]


# ---------------------------------------------------------------------------
# guards unit behavior
# ---------------------------------------------------------------------------

def test_check_logits_flags_only_active_corrupt_slots():
    logits = np.zeros((4, 8), np.float32)
    logits[0, 3] = np.nan
    logits[1, 1] = np.inf
    logits[2, 0] = 1e9  # overflow
    active = np.array([True, True, True, False])
    flagged = check_logits(logits, active, overflow=1e6)
    assert flagged == {0: "nan", 1: "inf", 2: "overflow"}
    # inactive slots never flagged, healthy logits never flagged
    assert check_logits(logits, np.zeros(4, bool)) == {}
    assert check_logits(None, active) == {}


def test_fault_injector_from_rates_is_deterministic():
    a = FaultInjector.from_rates(7, n_steps=200, batch_size=4,
                                 rates={"nan_logits": 0.1, "kv_poison": 0.05})
    b = FaultInjector.from_rates(7, n_steps=200, batch_size=4,
                                 rates={"nan_logits": 0.1, "kv_poison": 0.05})
    assert a.events == b.events and len(a.events) > 5
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultInjector.from_rates(0, 10, 2, rates={"bitrot": 1.0})
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(step=0, kind="bitrot")
