"""Make ``python -m pytest`` work with no PYTHONPATH setup.

The package lives under ``src/`` (src-layout without an installed dist), so
insert it on ``sys.path`` before test collection imports ``repro``.  Also
puts ``tests/`` itself on the path so the vendored ``_proptest`` helper
imports from any working directory, and the repo root so tests can share
the ``benchmarks`` helpers (e.g. the jaxpr audit in ``benchmarks.common``).

Shared fixtures: ``trained_lenet`` loads/trains the cached LeNet exactly
once per pytest session (it is consumed by the Table-I ledger and kernel
parity tests across several modules — without the session scope each module
would redo the load + full-test-set accuracy pass).
"""
import sys
from pathlib import Path

import pytest

_ROOT = Path(__file__).resolve().parent.parent
for p in (str(_ROOT / "src"), str(_ROOT / "tests"), str(_ROOT)):
    if p not in sys.path:
        sys.path.insert(0, p)


@pytest.fixture(scope="session")
def trained_lenet():
    """(params, test_x32, test_y, info) — trained once, shared by the whole
    session (backed by the on-disk ``.cache`` so repeat sessions skip
    training entirely)."""
    from repro.train.lenet_trainer import get_trained_lenet

    return get_trained_lenet(verbose=False)
