"""Make ``python -m pytest`` work with no PYTHONPATH setup.

The package lives under ``src/`` (src-layout without an installed dist), so
insert it on ``sys.path`` before test collection imports ``repro``.  Also
puts ``tests/`` itself on the path so the vendored ``_proptest`` helper
imports from any working directory, and the repo root so tests can share
the ``benchmarks`` helpers (e.g. the jaxpr audit in ``benchmarks.common``).
"""
import sys
from pathlib import Path

_ROOT = Path(__file__).resolve().parent.parent
for p in (str(_ROOT / "src"), str(_ROOT / "tests"), str(_ROOT)):
    if p not in sys.path:
        sys.path.insert(0, p)
