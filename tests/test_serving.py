"""Serving engine: prefill+decode must agree with teacher-forced forward,
and the slot lifecycle (admit / evict / quarantine) must enforce hard
capacity bounds instead of JAX scatter's silent clamping."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import lm as M
from repro.models.param import unzip
from repro.serving.engine import INACTIVE_TOKEN, CapacityError, ServeEngine


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mamba2-2.7b", "hymba-1.5b"])
def test_greedy_generation_matches_teacher_forcing(arch):
    cfg = get_smoke_config(arch)
    params, _ = unzip(M.init_lm(cfg, jax.random.key(0)))
    eng = ServeEngine(cfg, params, max_seq=48, batch_size=2,
                      knobs=M.PerfKnobs(q_chunk=16, k_chunk=16, remat="none"))

    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
    outs = eng.generate({0: prompt}, n_steps=6)
    gen = outs[0]
    assert len(gen) == 6

    # teacher-forced reference: feed prompt+gen through the full forward and
    # check greedy argmax reproduces each generated token
    seq = np.concatenate([prompt, np.asarray(gen[:-1], np.int32)])
    logits, _, _ = M.lm_forward(
        cfg, params, {"tokens": jnp.asarray(seq[None])},
        knobs=M.PerfKnobs(q_chunk=16, k_chunk=16, remat="none"),
    )
    ref = np.asarray(jnp.argmax(logits[0, len(prompt) - 1 :, : cfg.vocab], -1))
    np.testing.assert_array_equal(np.asarray(gen), ref[: len(gen)])


def test_pallas_gemm_knob_matches_xla_path():
    """PerfKnobs(gemm="pallas") must not change greedy decode output — the
    fused K-tiled kernel path and the XLA einsum path are the same math."""
    cfg = get_smoke_config("qwen2-1.5b")
    params, _ = unzip(M.init_lm(cfg, jax.random.key(2)))
    base = dict(q_chunk=16, k_chunk=16, remat="none")
    eng_xla = ServeEngine(cfg, params, max_seq=32, batch_size=1,
                          knobs=M.PerfKnobs(**base))
    eng_pls = ServeEngine(cfg, params, max_seq=32, batch_size=1,
                          knobs=M.PerfKnobs(**base, gemm="pallas",
                                            block_m=16, block_n=32, block_k=32))
    rng = np.random.default_rng(7)
    prompt = rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32)
    out_xla = eng_xla.generate({0: prompt}, n_steps=4)
    out_pls = eng_pls.generate({0: prompt}, n_steps=4)
    assert out_xla[0] == out_pls[0]


def test_pallas_paired_engine_token_parity_and_slot_refill():
    """ServeEngine with gemm="pallas_paired" at rounding 0 must be
    token-identical to the XLA engine on a mixed-length batch — prefill and
    every decode step run the subtractor kernel with the residual adds in
    its epilogue — and slot refill (a finished sequence replaced by a new
    prompt) must keep the parity going."""
    # fp32: the claim is exactness of the kernel path, not bf16 noise
    cfg = dataclasses.replace(get_smoke_config("qwen2-1.5b"), dtype="float32")
    params, _ = unzip(M.init_lm(cfg, jax.random.key(3)))
    base = dict(q_chunk=16, k_chunk=16, remat="none")
    eng_xla = ServeEngine(cfg, params, max_seq=32, batch_size=2,
                          knobs=M.PerfKnobs(**base))
    eng_pls = ServeEngine(cfg, params, max_seq=32, batch_size=2,
                          knobs=M.PerfKnobs(**base, gemm="pallas_paired",
                                            pair_rounding=0.0))
    assert eng_pls.pair_report is not None  # engine built the artifacts

    rng = np.random.default_rng(11)
    prompts = {
        0: rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32),
        1: rng.integers(0, cfg.vocab, size=(9,)).astype(np.int32),
    }
    out_xla = eng_xla.generate(dict(prompts), n_steps=4)
    out_pls = eng_pls.generate(dict(prompts), n_steps=4)
    assert out_xla == out_pls, "paired decode diverged from XLA at rounding 0"

    # slot 0 finishes (explicit release under the slot lifecycle); refill it
    # with a fresh prompt while slot 1 keeps decoding — positions are data,
    # so no recompile, and parity must hold
    refill = rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32)
    eng_xla.release_slot(0)
    eng_pls.release_slot(0)
    first_xla = eng_xla.add_request(0, refill)
    first_pls = eng_pls.add_request(0, refill)
    assert first_xla == first_pls
    for _ in range(3):
        nxt_xla = eng_xla.step()
        nxt_pls = eng_pls.step()
        np.testing.assert_array_equal(nxt_xla, nxt_pls)


def _mini_engine(max_seq=8, batch_size=2, key=5, **knob_kw):
    cfg = dataclasses.replace(get_smoke_config("qwen2-1.5b"), dtype="float32")
    params, _ = unzip(M.init_lm(cfg, jax.random.key(key)))
    knobs = M.PerfKnobs(q_chunk=16, k_chunk=16, remat="none", **knob_kw)
    return cfg, params, ServeEngine(cfg, params, max_seq=max_seq,
                                    batch_size=batch_size, knobs=knobs)


def test_add_request_validates_capacity_not_asserts():
    """Admission bounds are real exceptions (survive `python -O`), typed as
    CapacityError, for every violation class."""
    cfg, _, eng = _mini_engine(max_seq=8)
    rng = np.random.default_rng(0)
    ok = rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32)

    with pytest.raises(CapacityError, match="prompt length 8"):
        eng.add_request(0, rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32))
    with pytest.raises(CapacityError, match="empty prompt"):
        eng.add_request(0, ok[:0])
    with pytest.raises(CapacityError, match="out of range"):
        eng.add_request(2, ok)
    eng.add_request(0, ok)
    with pytest.raises(CapacityError, match="still active"):
        eng.add_request(0, ok)
    assert isinstance(CapacityError("x"), ValueError)  # catchable as ValueError


def test_step_raises_at_max_seq_instead_of_silent_clamp():
    """Decoding past max_seq must raise, not let the scatter clamp the write
    into the last cache row."""
    cfg, _, eng = _mini_engine(max_seq=6)
    rng = np.random.default_rng(1)
    eng.add_request(0, rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32))
    eng.step()  # writes at pos 4 -> pos 5
    eng.step()  # writes at pos 5 (== max_seq - 1) -> pos 6
    with pytest.raises(CapacityError, match="no cache rows left"):
        eng.step()


def test_release_slot_stops_emission_and_scrubs_cache():
    cfg, _, eng = _mini_engine(max_seq=16)
    rng = np.random.default_rng(2)
    pa = rng.integers(0, cfg.vocab, size=(3,)).astype(np.int32)
    pb = rng.integers(0, cfg.vocab, size=(5,)).astype(np.int32)
    eng.add_request(0, pa)
    eng.add_request(1, pb)
    eng.step()
    eng.release_slot(0)

    # scrub: every cache entry's slot-0 rows zeroed at release (later decode
    # steps write one dummy row at pos 0 for inactive slots, but any refill's
    # prefill splice overwrites it — the refill-hygiene test proves that)
    for seg in eng.cache["segments"]:
        for name, arr in seg.items():
            assert not np.asarray(arr)[:, 0].any(), \
                f"cache entry {name!r} kept stale rows after release"

    nxt = eng.step()
    assert nxt[0] == INACTIVE_TOKEN, "released slot must not emit tokens"
    assert 0 <= nxt[1] < cfg.vocab
    assert int(np.asarray(eng.pos)[0]) == 0, "released slot's pos must reset"


def test_quarantined_slot_refuses_admission_until_cleared():
    cfg, _, eng = _mini_engine(max_seq=16)
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32)
    eng.add_request(0, prompt)
    eng.quarantine_slot(0)
    assert eng.free_slots() == [1]
    with pytest.raises(CapacityError, match="quarantined"):
        eng.add_request(0, prompt)
    eng.clear_quarantine(0)
    assert eng.free_slots() == [0, 1]
    eng.add_request(0, prompt)  # admissible again


@pytest.mark.parametrize("gemm", ["xla", "pallas_paired"])
def test_quarantine_then_refill_leaks_no_stale_state(gemm):
    """Slot-refill hygiene: a quarantined-then-refilled slot must produce
    exactly the tokens a fresh engine produces for the new request — no
    stale KV rows, positions, or pairing state from the previous occupant —
    on the XLA and the paired subtractor engines alike."""
    knob_kw = {"gemm": gemm, "pair_rounding": 0.0} if gemm != "xla" else {}
    cfg, params, eng = _mini_engine(max_seq=24, key=7, **knob_kw)
    rng = np.random.default_rng(11)
    victim = rng.integers(0, cfg.vocab, size=(9,)).astype(np.int32)
    bystander = rng.integers(0, cfg.vocab, size=(5,)).astype(np.int32)
    refill = rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32)

    eng.add_request(0, victim)
    eng.add_request(1, bystander)  # keeps decoding across the whole episode
    for _ in range(2):
        eng.step()
    eng.quarantine_slot(0)  # evict + scrub mid-request
    eng.clear_quarantine(0)

    first = eng.add_request(0, refill)
    got = [first] + [int(eng.step()[0]) for _ in range(3)]

    fresh = ServeEngine(cfg, params, max_seq=24, batch_size=2, knobs=eng.knobs)
    want = fresh.generate({0: refill}, n_steps=4)[0]
    assert got == want, "refilled slot diverged — stale state leaked"


def test_two_slot_batch_decodes_independently():
    cfg = get_smoke_config("qwen2-1.5b")
    params, _ = unzip(M.init_lm(cfg, jax.random.key(1)))
    eng = ServeEngine(cfg, params, max_seq=32, batch_size=2,
                      knobs=M.PerfKnobs(q_chunk=16, k_chunk=16, remat="none"))
    rng = np.random.default_rng(1)
    pa = rng.integers(0, cfg.vocab, size=(5,)).astype(np.int32)
    pb = rng.integers(0, cfg.vocab, size=(9,)).astype(np.int32)
    outs = eng.generate({0: pa, 1: pb}, n_steps=4)

    # single-slot reference for slot 0
    eng2 = ServeEngine(cfg, params, max_seq=32, batch_size=2,
                       knobs=M.PerfKnobs(q_chunk=16, k_chunk=16, remat="none"))
    ref = eng2.generate({0: pa}, n_steps=4)
    assert outs[0] == ref[0], "slot 1's presence must not change slot 0's output"
