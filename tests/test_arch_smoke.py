"""Per-architecture smoke tests: reduced config of the same family, one
forward + train step + prefill + decode step on CPU; asserts output shapes
and finiteness (no NaNs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.launch.inputs import make_batch
from repro.models import lm as M
from repro.models.param import unzip

B, S = 2, 32
KNOBS = M.PerfKnobs(q_chunk=16, k_chunk=16, remat="none")


def _setup(arch):
    cfg = get_smoke_config(arch)
    tree = M.init_lm(cfg, jax.random.key(0))
    params, _ = unzip(tree)
    return cfg, params


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg, params = _setup(arch)
    batch = make_batch(cfg, B, S, "train")
    logits, aux, _ = M.lm_forward(cfg, params, batch, knobs=KNOBS)
    assert logits.shape == (B, S, M.padded_vocab(cfg))
    assert bool(jnp.isfinite(logits[..., : cfg.vocab]).all()), "NaN/inf in logits"
    # padded vocab positions are masked to -1e9
    if M.padded_vocab(cfg) > cfg.vocab:
        assert float(logits[..., cfg.vocab :].max()) < -1e8


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step(arch):
    cfg, params = _setup(arch)
    batch = make_batch(cfg, B, S, "train")

    def loss_fn(p):
        return M.lm_loss(cfg, p, batch, knobs=KNOBS)

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert np.isfinite(float(loss)) and float(loss) > 0
    gnorm = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, "gradients must flow"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    """Decode step after prefill must agree with the full forward pass
    evaluated one token later (the cache is a faithful sufficient statistic)."""
    cfg, params = _setup(arch)
    batch = make_batch(cfg, B, S, "prefill")
    tokens = batch["tokens"]

    # full forward over S+0 .. S tokens for reference
    logits_all, _, _ = M.lm_forward(cfg, params, batch, knobs=KNOBS)

    # prefill on the first S-1 tokens, then decode token S-1
    batch_m1 = dict(batch)
    batch_m1["tokens"] = tokens[:, : S - 1]
    if cfg.vision_prefix:
        pass  # patches span the prefix; unchanged
    last_logits, cache = M.prefill(cfg, params, batch_m1, knobs=KNOBS)
    np.testing.assert_allclose(
        np.asarray(last_logits[:, 0, : cfg.vocab], np.float32),
        np.asarray(logits_all[:, S - 2, : cfg.vocab], np.float32),
        rtol=2e-2, atol=2e-2,
    )

    # grow cache to full length then decode the final token
    cache_full = M.init_cache(cfg, B, S + 4)
    cache_vals, _ = unzip(cache_full)

    def splice(dst, src):
        # copy prefill cache (length S-1 in seq dims) into the bigger buffer
        if src.dtype != dst.dtype:
            src = src.astype(dst.dtype)
        sl = tuple(slice(0, s) for s in src.shape)
        return dst.at[sl].set(src)

    cache_segs = jax.tree.map(splice, cache_vals["segments"], cache["segments"])
    pos = jnp.full((B,), S - 1, jnp.int32)
    logits_dec, _ = M.decode_step(
        cfg, params, {"segments": cache_segs}, tokens[:, S - 1 :], pos
    )
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0, : cfg.vocab], np.float32),
        np.asarray(logits_all[:, S - 1, : cfg.vocab], np.float32),
        rtol=5e-2, atol=5e-2,
    )


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_full_config_parses(arch):
    from repro.configs import get_config

    cfg = get_config(arch)
    assert cfg.n_layers >= 1
    segs = cfg.segments()
    assert sum(n for _, n in segs) == cfg.n_layers
    n = cfg.param_count()
    assert n > 0
