"""The HLO cost analyzer must (a) match XLA's cost_analysis on loop-free
graphs and (b) correctly multiply loop-body costs by static trip counts."""
import jax
import jax.numpy as jnp
import pytest

from repro.parallel.hlo import analyze, parse_hlo, xla_cost_analysis


def _compile_text(fn, *args):
    lowered = jax.jit(fn).lower(*args)
    compiled = lowered.compile()
    # xla_cost_analysis normalizes the list-of-dicts return of older JAX
    return compiled.as_text(), xla_cost_analysis(compiled)


def test_matmul_flops_match_xla():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    text, cost = _compile_text(lambda x, y: x @ y, a, b)
    got = analyze(text)
    expected = 2 * 64 * 128 * 32
    assert got.flops == pytest.approx(expected, rel=0.01)
    assert cost["flops"] == pytest.approx(expected, rel=0.01)


def test_scan_flops_scale_with_trip_count():
    """XLA cost_analysis counts a scanned matmul ONCE; we must count it x8."""
    w = jax.ShapeDtypeStruct((8, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 32), jnp.float32)

    def fn(w, x):
        def body(h, wi):
            return jnp.tanh(h @ wi), None

        h, _ = jax.lax.scan(body, x, w)
        return h

    text, cost = _compile_text(fn, w, x)
    got = analyze(text)
    one_layer = 2 * 4 * 32 * 32
    assert got.flops == pytest.approx(8 * one_layer, rel=0.05), (
        f"expected {8*one_layer}, analyzer said {got.flops}, xla said {cost['flops']}"
    )
    # demonstrate the xla undercount this module exists to fix
    assert cost["flops"] < got.flops


def test_nested_scan_multiplies():
    w = jax.ShapeDtypeStruct((3, 5, 16, 16), jnp.float32)
    x = jax.ShapeDtypeStruct((2, 16), jnp.float32)

    def fn(w, x):
        def outer(h, wo):
            def inner(h2, wi):
                return h2 @ wi, None

            h2, _ = jax.lax.scan(inner, h, wo)
            return h2, None

        h, _ = jax.lax.scan(outer, x, w)
        return h

    text, _ = _compile_text(fn, w, x)
    got = analyze(text)
    assert got.flops == pytest.approx(15 * 2 * 2 * 16 * 16, rel=0.05)


def test_parse_computations():
    a = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    text, _ = _compile_text(lambda x: jnp.sum(x @ x), a)
    comps, entry = parse_hlo(text)
    assert entry
    assert entry in comps
    assert any(op.op == "dot" for c in comps.values() for op in c.ops)


def test_hbm_bytes_reasonable():
    """Bytes estimate for a simple matmul ≈ operands + result."""
    a = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    text, _ = _compile_text(lambda x, y: x @ y, a, a)
    got = analyze(text)
    expected = 3 * 256 * 256 * 4
    assert expected * 0.8 <= got.hbm_bytes <= expected * 3


def test_collective_ring_factors():
    # 8 host devices were forced in conftest? no — single device here, so
    # build a fake HLO snippet instead.
    text = """
HloModule test

ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  ROOT %all-reduce.1 = f32[1024]{0} all-reduce(%p0), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""
    got = analyze(text)
    r = 1024 * 4
    assert got.collective["bytes_by_type"]["all-reduce"] == pytest.approx(2 * 3 / 4 * r)
