"""Tiny vendored property-test helper — a zero-dependency stand-in for the
slice of ``hypothesis`` this suite used (``@given`` + integer/float
strategies).

``hypothesis`` is not installable in the hermetic test container (no
network), so tests draw their random cases from a seeded generator instead:

    from _proptest import cases, integers, floats

    @cases(30, k=integers(1, 60), rounding=floats(0.0, 0.5), seed=seeds())
    def test_something(k, rounding, seed): ...

Each ``cases(n, name=strategy, ...)`` decorator expands into a plain
``pytest.mark.parametrize`` with ``n`` tuples drawn up front from a
``numpy`` Generator seeded by a stable hash of the test name — so case sets
are reproducible across runs/processes (no ``PYTHONHASHSEED`` dependence),
failures replay as ordinary parametrized tests, and ``-k`` selection works.
No shrinking: cases are independent draws, and the draw that failed is
printed in the test id.
"""
from __future__ import annotations

import zlib
from collections.abc import Callable

import numpy as np
import pytest

Strategy = Callable[[np.random.Generator], object]


def integers(min_value: int, max_value: int) -> Strategy:
    """Uniform integer in [min_value, max_value] (inclusive, like hypothesis)."""
    return lambda rng: int(rng.integers(min_value, max_value + 1))


def floats(min_value: float, max_value: float) -> Strategy:
    """Uniform float in [min_value, max_value]."""
    return lambda rng: float(rng.uniform(min_value, max_value))


def seeds() -> Strategy:
    """A fresh RNG seed per case (the usual 'seed' argument strategy)."""
    return integers(0, 2**31 - 1)


def sampled_from(options) -> Strategy:
    opts = list(options)
    return lambda rng: opts[int(rng.integers(len(opts)))]


def cases(n_cases: int, /, **strategies: Strategy):
    """Draw ``n_cases`` tuples from keyword strategies; parametrize the test.

    Keyword names must match the test's parameter names (order preserved).
    """

    def deco(fn):
        seed = zlib.crc32(fn.__name__.encode())
        rng = np.random.default_rng(seed)
        names = list(strategies)
        values = [
            tuple(strategies[name](rng) for name in names)
            for _ in range(n_cases)
        ]
        return pytest.mark.parametrize(",".join(names), values)(fn)

    return deco
