"""Optimizer, train loop and fault-tolerant checkpointing tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.train.loop import train
from repro.train.optimizer import adamw, clip_by_global_norm, cosine_schedule, sgd


def quad_loss(params, target):
    err = params["w"] - target
    return jnp.sum(err * err), jnp.sum(jnp.abs(err))


def test_adamw_converges_on_quadratic():
    params = {"w": jnp.zeros((4,))}
    target = jnp.array([1.0, -2.0, 3.0, 0.5])
    opt = adamw(0.1)
    state = opt.init(params)
    for i in range(300):
        grads = jax.grad(lambda p: quad_loss(p, target)[0])(params)
        params, state = opt.update(grads, state, params, i)
    np.testing.assert_allclose(params["w"], target, atol=1e-2)


def test_sgd_converges():
    params = {"w": jnp.zeros((3,))}
    target = jnp.array([0.3, -0.7, 1.1])
    opt = sgd(0.05, momentum=0.5)
    state = opt.init(params)
    for i in range(200):
        grads = jax.grad(lambda p: quad_loss(p, target)[0])(params)
        params, state = opt.update(grads, state, params, i)
    np.testing.assert_allclose(params["w"], target, atol=1e-2)


def test_adamw_bf16_params_fp32_state():
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    opt = adamw(0.01)
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.float32
    grads = {"w": jnp.ones((4,), jnp.bfloat16)}
    new_params, state = opt.update(grads, state, params, 0)
    assert new_params["w"].dtype == jnp.bfloat16
    assert float(jnp.abs(new_params["w"]).sum()) > 0


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(1000), rel=1e-5)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule_endpoints():
    lr = cosine_schedule(1e-3, 100, warmup_steps=10, min_ratio=0.1)
    assert float(lr(0)) == pytest.approx(0.0, abs=1e-9)
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-2)
    assert float(lr(100)) == pytest.approx(1e-4, rel=1e-2)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": np.arange(6, dtype=np.float32).reshape(2, 3), "b": {"c": np.float32(2.5) * np.ones(4)}}
    save_checkpoint(tmp_path, 7, tree, metadata={"hello": 1})
    assert latest_step(tmp_path) == 7
    restored, meta = restore_checkpoint(tmp_path, tree)
    assert meta == {"hello": 1}
    np.testing.assert_array_equal(restored["a"], tree["a"])
    np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])


def test_checkpoint_keep_n(tmp_path):
    tree = {"x": np.zeros(1)}
    for s in range(5):
        save_checkpoint(tmp_path, s, tree, keep=2)
    steps = sorted(p.name for p in tmp_path.iterdir() if p.name.startswith("step_"))
    assert len(steps) == 2
    assert latest_step(tmp_path) == 4


def test_checkpoint_atomicity_no_partial_dirs(tmp_path):
    """Temp dirs are cleaned up even on failure paths; the final dir only
    ever appears complete."""
    tree = {"x": np.zeros(3)}
    save_checkpoint(tmp_path, 1, tree)
    leftovers = [p for p in tmp_path.iterdir() if p.name.startswith(".tmp_")]
    assert leftovers == []
    final = tmp_path / "step_0000000001"
    assert (final / "manifest.json").exists()
    assert (final / "shard_0.npz").exists()


def test_train_loop_resume(tmp_path):
    """Kill-and-restart: resuming from a checkpoint continues the counter."""
    target = jnp.array([1.0, 2.0])

    def loss_fn(params, t):
        err = params["w"] - t
        return jnp.sum(err * err), jnp.float32(0.0)

    def data(n):
        for _ in range(n):
            yield (target,)

    params0 = {"w": jnp.zeros((2,))}
    opt = adamw(0.05)
    # phase 1: 30 steps, checkpoint every 10
    p1, info1 = train(
        params0, loss_fn, opt, data(30),
        ckpt_dir=str(tmp_path), ckpt_every=10, log_every=0, verbose=False,
    )
    assert latest_step(tmp_path) == 30
    # phase 2: "restart" from scratch params; loop must resume from step 30
    p2, info2 = train(
        params0, loss_fn, opt, data(30),
        ckpt_dir=str(tmp_path), ckpt_every=10, log_every=0, verbose=False,
    )
    # resumed params continue improving over phase-1 params
    l1 = float(jnp.sum((p1["w"] - target) ** 2))
    l2 = float(jnp.sum((p2["w"] - target) ** 2))
    assert l2 <= l1 + 1e-9
