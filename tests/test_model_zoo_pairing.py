"""Model-agnostic `pair_params` across the config zoo.

Covers the PR-7 tentpole surface:

* strict no-match behavior — unknown trees / typo'd leaf specs raise with
  the list of unmatched leaves instead of silently pairing nothing;
* leaf-classification round-trip — on every toy config family, every
  pairing the walker emits (decoder, encoder, nested ``moe.shared``, and
  the ``(L, E, …)`` expert-stacked metadata) reconstructs the live weight
  exactly at r=0 and packs a valid lane permutation at r>0;
* the per-expert paired GEMM (`fused_paired_expert_dense`) against its
  folded-weight oracle on random shapes, shared and per-expert activations.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _proptest import cases, floats, integers, seeds

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.core.transform import (
    _lm_weight_matrix_shape,
    pair_lm_params,
    pair_params,
)
from repro.kernels.ops import (
    fold_lm_expert_weight,
    fold_lm_weight,
    fused_paired_expert_dense,
)
from repro.models import lm as M
from repro.models.param import unzip


def _smoke_params(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    params, _ = unzip(M.init_lm(cfg, jax.random.key(0)))
    return cfg, params


# ---------------------------------------------------------------------------
# strict no-match raise (the silent-empty-dict fix)
# ---------------------------------------------------------------------------


def test_no_match_tree_raises():
    """A tree none of whose names match must raise, naming the specs tried."""
    fake = {"segments": [{"attn_rebranded": {"w_qkv": np.zeros((1, 16, 16))}}]}
    with pytest.raises(ValueError, match=r"attn.*wq"):
        pair_lm_params(fake, 0.05)


def test_typod_leaf_spec_raises():
    """An explicit spec list with a typo fails loudly, listing the miss."""
    _, params = _smoke_params("qwen2-1.5b")
    bad = (("attn", "wq"), ("mlp", "w_gaet"))
    with pytest.raises(ValueError, match=r"w_gaet"):
        pair_params(params, 0.05, leaves=bad)


def test_conv_tree_no_match_raises():
    fake = {"conv1": {"bias_only": np.zeros((6,))}}
    with pytest.raises(ValueError):
        pair_params(fake, 0.05)


# ---------------------------------------------------------------------------
# per-family round-trip: exact r=0 fold + valid lane packing at r>0
# ---------------------------------------------------------------------------


def _metadata_entries(pm):
    """(path, meta, weight, is_expert) for every pairing in a paired tree —
    reuses the analysis walker so the test sees exactly what CI lints."""
    from repro.analysis.rules_pairing import _lm_metadata

    return _lm_metadata(pm)


@pytest.mark.parametrize("arch", ALL_ARCHS)
@pytest.mark.parametrize("mode,bn", [("structured", 0), ("per_column", 1)])
def test_r0_fold_round_trips(arch, mode, bn):
    """At rounding 0 every emitted pairing folds back to the live weight
    bit-exactly — all lanes residual, pure permuted gather/scatter."""
    cfg, params = _smoke_params(arch)
    pm, rep = pair_params(
        params, 0.0, mode=mode, leaves=cfg.paired_leaves or None
    )
    entries = _metadata_entries(pm)
    assert len(entries) == len(rep.leaves) > 0
    for path, meta, arr, is_expert in entries:
        w_name = path.rsplit(".", 1)[-1][: -len("_pairing")]
        for layer in range(arr.shape[0]):
            sl = {k: jnp.asarray(v[layer]) for k, v in meta.items()}
            if is_expert:
                w = jnp.asarray(arr[layer])  # (E, K, F)
                got = fold_lm_expert_weight(w, sl, pair_block_n=bn)
            else:
                K, N = _lm_weight_matrix_shape(w_name, arr.shape[1:])
                w = jnp.asarray(arr[layer]).reshape(K, N)
                got = fold_lm_weight(w, sl, pair_block_n=bn)
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(w), err_msg=f"{path}[{layer}]"
            )


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_r005_lanes_pack_a_permutation(arch):
    """At r=0.05 the packed ``[I | J | resid]`` lanes of every block/layer
    (expert axis included) are a permutation of range(K), and nonzero
    pairing shows up on every leaf class the family declares."""
    from repro.analysis.rules_pairing import _lm_artifacts, _valid_lanes

    cfg, params = _smoke_params(arch)
    pm, rep = pair_params(
        params, 0.05, mode="per_column", leaves=cfg.paired_leaves or None
    )
    assert rep.pair_fraction > 0
    arts = _lm_artifacts(pm)
    assert arts
    for a in arts:
        I, J, R = _valid_lanes(a)
        lanes = np.sort(np.concatenate([np.ravel(I), np.ravel(J), np.ravel(R)]))
        assert np.array_equal(lanes, np.arange(a.K)), a.location
    if cfg.moe is not None:
        expert = [
            lf for lf in rep.leaves
            if ".moe." in lf.path and ".moe.shared." not in lf.path
        ]
        assert expert and all(lf.pair_fraction > 0 for lf in expert)


# ---------------------------------------------------------------------------
# per-expert paired GEMM vs folded oracle (random shapes)
# ---------------------------------------------------------------------------


@cases(6, E=integers(2, 5), K=integers(8, 24), F=integers(4, 16),
       Mrows=integers(1, 6), bn=integers(0, 3), rounding=floats(0.0, 0.3),
       per_expert=integers(0, 1), seed=seeds())
def test_fused_paired_expert_dense_matches_fold(
    E, K, F, Mrows, bn, rounding, per_expert, seed
):
    rng = np.random.default_rng(seed)
    W = rng.normal(size=(1, E, K, F)).astype(np.float32)
    fake = {"segments": [{"moe": {"w_gate": W}}]}
    mode = "column_blocked" if bn else "structured"
    pm, _ = pair_params(
        fake, rounding, mode=mode, block_n=bn,
        leaves=(("moe", "w_gate"),), min_dim=1,
    )
    meta = {
        k: jnp.asarray(v[0])
        for k, v in pm["segments"][0]["moe"]["w_gate_pairing"].items()
    }
    w = jnp.asarray(W[0])
    xs = (E, Mrows, K) if per_expert else (Mrows, K)
    x = jnp.asarray(rng.normal(size=xs).astype(np.float32))
    got = fused_paired_expert_dense(
        x, w, meta, activation="silu", x_per_expert=bool(per_expert),
        pair_block_n=bn, interpret=True,
    )
    wf = fold_lm_expert_weight(w, meta, pair_block_n=bn)
    eq = "etk,ekf->tef" if per_expert else "tk,ekf->tef"
    want = jax.nn.silu(jnp.einsum(eq, x, wf))
    assert got.shape == (Mrows, E, F)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
    )


# ---------------------------------------------------------------------------
# forward parity through the newly-routed families
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["olmoe-1b-7b", "deepseek-v2-lite-16b"])
def test_moe_forward_r0_parity(arch):
    """Full MoE-family forward (expert kernel + MLA/shared routing) through
    the paired path at r=0 matches the XLA path ≤ 1e-5."""
    from repro.kernels.ops import perf_context

    cfg, params = _smoke_params(arch)
    pm, _ = pair_params(
        params, 0.0, mode="structured", leaves=cfg.paired_leaves or None
    )
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(7).integers(0, cfg.vocab, (2, 8)), jnp.int32)}
    base = M.PerfKnobs(q_chunk=8, k_chunk=8, remat="none")
    knobs = dataclasses.replace(base, gemm="pallas_paired")
    want, _, _ = M.lm_forward(cfg, params, batch, knobs=base)
    with perf_context(knobs):
        got, _, _ = jax.jit(
            lambda p: M.lm_forward(cfg, p, batch, knobs=knobs)
        )(pm)
    rel = float(jnp.abs(got - want).max() / jnp.abs(want).max())
    assert rel <= 1e-5, f"{arch}: rel err {rel:.2e}"
