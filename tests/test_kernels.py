"""Pallas paired-matmul kernel vs pure-jnp oracle: shape/dtype sweeps +
property-based equivalence with the folded dense matmul."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.pairing import pair_rows_structured
from repro.kernels.ops import apply_structured_pairing, dense_matmul, paired_matmul
from repro.kernels.ref import dense_matmul_ref, paired_matmul_ref


def _tol(dtype):
    # bf16: inputs are rounded to 8-bit mantissas before the fp32-accumulated
    # dot, and the kernel's VPU subtract happens pre-cast — tolerance follows
    # the FlashAttention/Triton convention for half-precision GEMM checks.
    if dtype == jnp.bfloat16:
        return dict(rtol=5e-2, atol=5e-2)
    return dict(rtol=1e-4, atol=1e-4)  # fp32: blocked vs unblocked accum order


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "M,P,R,N",
    [
        (8, 16, 8, 32),
        (128, 128, 128, 128),
        (100, 60, 40, 50),  # non-multiples of the tile → padding path
        (256, 256, 0, 128),  # no residual
        (32, 0, 64, 64),  # no pairs
        (1, 8, 8, 8),  # single row (decode)
        (300, 100, 77, 200),
    ],
)
def test_paired_kernel_matches_ref(M, P, R, N, dtype):
    rng = np.random.default_rng(P * 1000 + R * 10 + N)
    x = jnp.asarray(rng.normal(size=(M, 2 * P + R)), dtype)
    kmat = jnp.asarray(rng.normal(size=(P, N)), dtype)
    w_res = jnp.asarray(rng.normal(size=(R, N)), dtype)
    got = paired_matmul(x, kmat, w_res, block_m=64, block_n=64)
    want = paired_matmul_ref(x, kmat, w_res)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dense_kernel_matches_ref(dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(96, 160)), dtype)
    w = jnp.asarray(rng.normal(size=(160, 112)), dtype)
    got = dense_matmul(x, w, block_m=32, block_n=32)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(dense_matmul_ref(x, w), np.float32),
        **_tol(dtype),
    )


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=1, max_value=40),  # M
    st.integers(min_value=0, max_value=24),  # P
    st.integers(min_value=0, max_value=24),  # R  (P+R >= 1 enforced below)
    st.integers(min_value=1, max_value=32),  # N
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_paired_kernel_property(M, P, R, N, seed):
    if P + R == 0:
        R = 1
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(M, 2 * P + R)), jnp.float32)
    kmat = jnp.asarray(rng.normal(size=(P, N)), jnp.float32)
    w_res = jnp.asarray(rng.normal(size=(R, N)), jnp.float32)
    got = paired_matmul(x, kmat, w_res, block_m=16, block_n=16)
    want = paired_matmul_ref(x, kmat, w_res)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_structured_pairing_end_to_end():
    """paired kernel through a real StructuredPairing == x @ fold()."""
    rng = np.random.default_rng(42)
    # a weight matrix with genuine antisymmetric structure (plus noise small
    # enough for the rms criterion): rows 48.. are ≈ -rows ..48
    half = rng.normal(size=(48, 64)) + 1.5
    W = np.concatenate([half, -half + rng.normal(size=(48, 64)) * 0.05])
    sp = pair_rows_structured(W, rounding=0.5)
    assert sp.n_pairs > 0, "want a nontrivial pairing for this test"
    x = jnp.asarray(rng.normal(size=(10, 96)), jnp.float32)
    y_kernel = apply_structured_pairing(x, sp, block_m=16, block_n=16)
    y_dense = x @ jnp.asarray(sp.fold(), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(y_kernel), np.asarray(y_dense), rtol=1e-4, atol=1e-4
    )


def test_contraction_savings_accounting():
    """The kernel's MXU contraction length is K - P: every pair saves a lane."""
    rng = np.random.default_rng(1)
    W = np.concatenate([rng.normal(size=(32, 16)) + 2, -(rng.normal(size=(32, 16)) + 2)])
    sp = pair_rows_structured(W, rounding=10.0)  # everything pairs
    K = W.shape[0]
    assert sp.n_pairs == 32
    assert sp.Kmat.shape[0] + sp.W_res.shape[0] == K - sp.n_pairs


def test_batched_inputs():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(4, 7, 48)), jnp.float32)  # (B, S, K)
    kmat = jnp.asarray(rng.normal(size=(16, 24)), jnp.float32)
    w_res = jnp.asarray(rng.normal(size=(16, 24)), jnp.float32)
    got = paired_matmul(x, kmat, w_res, block_m=8, block_n=8)
    assert got.shape == (4, 7, 24)
    want = paired_matmul_ref(x.reshape(-1, 48), kmat, w_res).reshape(4, 7, 24)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
