"""Pallas paired-matmul kernel vs pure-jnp oracle: shape/dtype sweeps,
K-tiling (block_k < K) edge cases, epilogue fusion, and property-based
equivalence with the folded dense matmul (seeded cases via _proptest)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _proptest import cases, integers, seeds
from repro.core.pairing import pair_rows_structured
from repro.kernels.ops import (
    apply_structured_pairing,
    dense_matmul,
    paired_matmul,
    pallas_gemm,
)
from repro.kernels.ref import dense_matmul_ref, paired_matmul_ref
from repro.kernels.tuning import choose_blocks


def _tol(dtype):
    # bf16: inputs are rounded to 8-bit mantissas before the fp32-accumulated
    # dot, and the kernel's VPU subtract happens pre-cast — tolerance follows
    # the FlashAttention/Triton convention for half-precision GEMM checks.
    if dtype == jnp.bfloat16:
        return dict(rtol=5e-2, atol=5e-2)
    return dict(rtol=1e-4, atol=1e-4)  # fp32: blocked vs unblocked accum order


def _rand_case(rng, M, P, R, N, dtype):
    x = jnp.asarray(rng.normal(size=(M, 2 * P + R)), dtype)
    kmat = jnp.asarray(rng.normal(size=(P, N)), dtype)
    w_res = jnp.asarray(rng.normal(size=(R, N)), dtype)
    return x, kmat, w_res


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "M,P,R,N",
    [
        (8, 16, 8, 32),
        (128, 128, 128, 128),
        (100, 60, 40, 50),  # non-multiples of the tile → padding path
        (256, 256, 0, 128),  # no residual
        (32, 0, 64, 64),  # no pairs
        (1, 8, 8, 8),  # single row (decode)
        (300, 100, 77, 200),
    ],
)
def test_paired_kernel_matches_ref(M, P, R, N, dtype):
    rng = np.random.default_rng(P * 1000 + R * 10 + N)
    x, kmat, w_res = _rand_case(rng, M, P, R, N, dtype)
    got = paired_matmul(x, kmat, w_res, block_m=64, block_n=64)
    want = paired_matmul_ref(x, kmat, w_res)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dense_kernel_matches_ref(dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(96, 160)), dtype)
    w = jnp.asarray(rng.normal(size=(160, 112)), dtype)
    got = dense_matmul(x, w, block_m=32, block_n=32)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(dense_matmul_ref(x, w), np.float32),
        **_tol(dtype),
    )


@cases(15, M=integers(1, 40), P=integers(0, 24), R=integers(0, 24),
       N=integers(1, 32), seed=seeds())
def test_paired_kernel_property(M, P, R, N, seed):
    if P + R == 0:
        R = 1
    rng = np.random.default_rng(seed)
    x, kmat, w_res = _rand_case(rng, M, P, R, N, jnp.float32)
    got = paired_matmul(x, kmat, w_res, block_m=16, block_n=16)
    want = paired_matmul_ref(x, kmat, w_res)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# K-tiling edge cases (block_k < K, accumulation across k-steps)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "M,P,R,N,bk",
    [
        (32, 100, 56, 48, 16),  # block_k divides neither P nor R
        (17, 64, 0, 33, 16),  # R == 0, tiled pairs only
        (17, 0, 96, 33, 32),  # P == 0, tiled residual only
        (64, 8, 200, 24, 64),  # bk > P but bk < R (per-segment clamping)
        (5, 3, 2, 7, 2),  # tiny everything, nothing tile-aligned
    ],
)
def test_block_k_tiling_matches_ref(M, P, R, N, bk):
    rng = np.random.default_rng(M * 7 + bk)
    x, kmat, w_res = _rand_case(rng, M, P, R, N, jnp.float32)
    got = paired_matmul(x, kmat, w_res, block_m=16, block_n=16, block_k=bk)
    want = paired_matmul_ref(x, kmat, w_res)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_large_k_8192_within_1e5():
    """Acceptance bar: K up to 8192 with block_k < K, ≤1e-5 vs dense ref."""
    M, N, K = 8, 128, 8192
    P, R = 3000, K - 6000
    rng = np.random.default_rng(11)
    x, kmat, w_res = _rand_case(rng, M, P, R, N, jnp.float32)
    got = np.asarray(paired_matmul(x, kmat, w_res, block_m=8, block_n=128, block_k=512))
    want = np.asarray(paired_matmul_ref(x, kmat, w_res))
    rel = np.abs(got - want).max() / np.abs(want).max()
    assert rel <= 1e-5, f"relative error {rel:.2e} > 1e-5"


def test_bf16_inputs_fp32_accumulation():
    """bf16 in, fp32 accumulate: the tiled kernel must not accumulate in
    bf16 — at K=2048 a bf16 accumulator would be off by ~1e-1."""
    M, P, R, N = 16, 768, 512, 64
    rng = np.random.default_rng(21)
    x, kmat, w_res = _rand_case(rng, M, P, R, N, jnp.bfloat16)
    got = np.asarray(
        paired_matmul(x, kmat, w_res, block_m=16, block_n=32, block_k=128), np.float32
    )
    # fp32 oracle on the bf16-rounded inputs (bit-exact input semantics)
    want = np.asarray(paired_matmul_ref(x, kmat, w_res), np.float32)
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)
    # and the kernel is *closer* to the full-fp32 answer than a bf16
    # accumulator could be
    full = np.asarray(x, np.float32)
    want_f32 = (full[:, :P] - full[:, P : 2 * P]) @ np.asarray(kmat, np.float32)
    want_f32 += full[:, 2 * P :] @ np.asarray(w_res, np.float32)
    assert np.abs(got - want_f32).max() / np.abs(want_f32).max() < 2e-2


def test_epilogue_bias_and_activation():
    """Fused bias+activation == reference epilogue applied after the GEMM."""
    M, P, R, N = 40, 32, 32, 24
    rng = np.random.default_rng(31)
    x, kmat, w_res = _rand_case(rng, M, P, R, N, jnp.float32)
    bias = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
    for act, fn in [("none", lambda y: y), ("relu", jax.nn.relu),
                    ("gelu", jax.nn.gelu), ("silu", jax.nn.silu)]:
        got = paired_matmul(
            x, kmat, w_res, bias, block_m=16, block_n=16, block_k=8, activation=act
        )
        want = fn(paired_matmul_ref(x, kmat, w_res) + bias)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4,
            err_msg=f"activation={act}",
        )


# ---------------------------------------------------------------------------
# residual-add epilogue (the fused skip connection)
# ---------------------------------------------------------------------------


def test_residual_epilogue_matches_reference():
    """Fused residual == act(GEMM + bias) + residual, added after the
    activation on the fp32 accumulator."""
    M, P, R, N = 24, 16, 16, 20
    rng = np.random.default_rng(71)
    x, kmat, w_res = _rand_case(rng, M, P, R, N, jnp.float32)
    bias = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
    res = jnp.asarray(rng.normal(size=(M, N)), jnp.float32)
    for act, fn in [("none", lambda y: y), ("relu", jax.nn.relu)]:
        got = paired_matmul(
            x, kmat, w_res, bias, res,
            block_m=16, block_n=16, block_k=8, activation=act,
        )
        want = fn(paired_matmul_ref(x, kmat, w_res) + bias) + res
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4,
            err_msg=f"activation={act}",
        )


def test_residual_none_is_backcompat():
    """residual=None must be byte-identical to omitting the argument."""
    rng = np.random.default_rng(72)
    x, kmat, w_res = _rand_case(rng, 10, 8, 8, 12, jnp.float32)
    b = jnp.asarray(rng.normal(size=(12,)), jnp.float32)
    a = paired_matmul(x, kmat, w_res, b, block_m=8, block_n=8)
    c = paired_matmul(x, kmat, w_res, b, None, block_m=8, block_n=8)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_residual_dtype_promotion_bf16():
    """bf16 residual against fp32 activations (and vice versa): the add
    happens on the fp32 accumulator, then one cast to the output dtype."""
    M, P, R, N = 12, 32, 16, 24
    rng = np.random.default_rng(73)
    # bf16 residual, fp32 GEMM: promoted exactly (bf16 ⊂ fp32)
    x, kmat, w_res = _rand_case(rng, M, P, R, N, jnp.float32)
    res16 = jnp.asarray(rng.normal(size=(M, N)), jnp.bfloat16)
    got = paired_matmul(x, kmat, w_res, None, res16, block_m=8, block_n=8)
    want = paired_matmul_ref(x, kmat, w_res) + res16.astype(jnp.float32)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    # bf16 GEMM, fp32 residual: the accumulator sees the full-precision
    # residual; only the final cast rounds to bf16
    xb, kb, wb = _rand_case(rng, M, P, R, N, jnp.bfloat16)
    res32 = jnp.asarray(rng.normal(size=(M, N)), jnp.float32)
    got_b = paired_matmul(xb, kb, wb, None, res32, block_m=8, block_n=8)
    want_b = (
        np.asarray(paired_matmul_ref(xb, kb, wb), np.float32)
        + np.asarray(res32)
    )
    assert got_b.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(got_b, np.float32), want_b, rtol=5e-2, atol=5e-2
    )


def test_blocked_residual_parity():
    """Column-blocked kernel with a fused residual == x @ fold() + res."""
    from repro.core.pairing import pair_rows_blocked
    from repro.kernels.ops import paired_matmul_blocked

    rng = np.random.default_rng(74)
    half = rng.normal(size=(20, 12)) + 1.5
    W = np.concatenate([half, -half + rng.normal(size=(20, 12)) * 0.05])
    x = jnp.asarray(rng.normal(size=(9, 40)), jnp.float32)
    res = jnp.asarray(rng.normal(size=(9, 12)), jnp.float32)
    for block_n in (1, 4, 12):
        bp = pair_rows_blocked(W, 0.5, block_n)
        idx = bp.index_arrays()
        xg = jnp.moveaxis(jnp.take(x, jnp.asarray(idx["perm"]), axis=-1), 1, 0)
        kmat, w_res = bp.packed_weights()
        got = paired_matmul_blocked(
            xg, jnp.asarray(kmat, jnp.float32), jnp.asarray(w_res, jnp.float32),
            None, res, n_cols=12, block_m=8, block_k=16,
        )
        want = x @ jnp.asarray(bp.fold(), jnp.float32) + res
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4,
            err_msg=f"block_n={block_n}",
        )


@cases(10, M=integers(1, 24), P=integers(0, 16), R=integers(0, 16),
       N=integers(1, 24), seed=seeds())
def test_residual_epilogue_property(M, P, R, N, seed):
    """Property: fused residual == ref + residual across random shapes,
    including the degenerate P == 0 / R == 0 segments."""
    if P + R == 0:
        R = 1
    rng = np.random.default_rng(seed)
    x, kmat, w_res = _rand_case(rng, M, P, R, N, jnp.float32)
    res = jnp.asarray(rng.normal(size=(M, N)), jnp.float32)
    got = paired_matmul(x, kmat, w_res, None, res, block_m=16, block_n=16)
    want = paired_matmul_ref(x, kmat, w_res) + res
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_residual_tuning_key_and_vmem():
    """The residual stream is part of the problem identity: the cache key
    gains a -res suffix (back-compat for existing entries) and the VMEM
    model charges the extra output-shaped stream."""
    from repro.kernels.tuning import cache_key, kernel_vmem_bytes

    plain = cache_key(64, 128, 16, 32)
    withres = cache_key(64, 128, 16, 32, residual=True)
    assert withres == plain + "-res"
    assert kernel_vmem_bytes(64, 64, 128, residual=True) > kernel_vmem_bytes(
        64, 64, 128, residual=False
    )


def test_dense_epilogue_matches_xla():
    rng = np.random.default_rng(41)
    x = jnp.asarray(rng.normal(size=(33, 130)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(130, 70)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(70,)), jnp.float32)
    got = dense_matmul(x, w, b, block_m=16, block_n=32, block_k=64, activation="silu")
    want = jax.nn.silu(x @ w + b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_layers_dense_policy_dispatch():
    """layers.dense under a pallas_gemm policy == its XLA einsum path."""
    from repro.models.layers import dense

    rng = np.random.default_rng(51)
    x = jnp.asarray(rng.normal(size=(3, 9, 64)), jnp.float32)  # (B, S, d)
    w = jnp.asarray(rng.normal(size=(64, 48)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(48,)), jnp.float32)
    want = dense(x, w, b, act="gelu")
    with pallas_gemm(block_m=16, block_n=16, block_k=16):
        got = dense(x, w, b, act="gelu")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_layers_dense_policy_gradients():
    """jax.grad through layers.dense under the policy (the train-step path):
    fused Pallas forward must carry a custom VJP whose grads match XLA."""
    from repro.models.layers import dense

    rng = np.random.default_rng(61)
    x = jnp.asarray(rng.normal(size=(2, 5, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 24)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(24,)), jnp.float32)

    def loss(w, b, use_pallas):
        if use_pallas:
            with pallas_gemm(block_m=8, block_n=8, block_k=16):
                y = dense(x, w, b, act="silu")
        else:
            y = dense(x, w, b, act="silu")
        return (y * y).sum()

    gw_ref, gb_ref = jax.grad(loss, argnums=(0, 1))(w, b, False)
    gw, gb = jax.grad(loss, argnums=(0, 1))(w, b, True)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_ref), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(gb_ref), rtol=1e-4, atol=1e-4)


def test_tuning_heuristic_fits_vmem():
    from repro.kernels.tuning import VMEM_BUDGET_BYTES, kernel_vmem_bytes

    for M, N, P, R in [(1, 128, 0, 400), (4096, 12288, 3000, 6288),
                       (128, 28672, 0, 12288), (256, 128, 6144, 0)]:
        t = choose_blocks(M, N, P, R)
        assert t.block_k >= 1 and t.block_m >= 1 and t.block_n >= 1
        assert (
            kernel_vmem_bytes(
                t.block_m, t.block_n, t.block_k,
                has_pairs=P > 0, has_resid=R > 0,
            )
            <= VMEM_BUDGET_BYTES
        ), f"heuristic overflows VMEM for {(M, N, P, R)}: {t}"


# ---------------------------------------------------------------------------
# structured-pairing integration (unchanged semantics)
# ---------------------------------------------------------------------------


def test_structured_pairing_end_to_end():
    """paired kernel through a real StructuredPairing == x @ fold()."""
    rng = np.random.default_rng(42)
    # a weight matrix with genuine antisymmetric structure (plus noise small
    # enough for the rms criterion): rows 48.. are ≈ -rows ..48
    half = rng.normal(size=(48, 64)) + 1.5
    W = np.concatenate([half, -half + rng.normal(size=(48, 64)) * 0.05])
    sp = pair_rows_structured(W, rounding=0.5)
    assert sp.n_pairs > 0, "want a nontrivial pairing for this test"
    x = jnp.asarray(rng.normal(size=(10, 96)), jnp.float32)
    y_kernel = apply_structured_pairing(x, sp, block_m=16, block_n=16, block_k=16)
    y_dense = x @ jnp.asarray(sp.fold(), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(y_kernel), np.asarray(y_dense), rtol=1e-4, atol=1e-4
    )


def test_blocked_pairing_end_to_end():
    """blocked kernel through a real BlockedPairing == x @ fold(), and at
    block_n = N it agrees with the structured kernel path."""
    from repro.core.pairing import pair_rows_blocked
    from repro.kernels.ops import apply_blocked_pairing

    rng = np.random.default_rng(43)
    half = rng.normal(size=(24, 20)) + 1.5
    W = np.concatenate([half, -half + rng.normal(size=(24, 20)) * 0.05])
    x = jnp.asarray(rng.normal(size=(3, 9, 48)), jnp.float32)  # lead dims
    for block_n in (1, 5, 20):
        bp = pair_rows_blocked(W, 0.5, block_n)
        assert bp.n_pairs > 0, "want a nontrivial pairing for this test"
        y_kernel = apply_blocked_pairing(x, bp, block_m=8, block_k=16)
        y_dense = x @ jnp.asarray(bp.fold(), jnp.float32)
        assert y_kernel.shape == y_dense.shape == (3, 9, 20)
        np.testing.assert_allclose(
            np.asarray(y_kernel), np.asarray(y_dense), rtol=1e-4, atol=1e-4
        )
    # the single-block case is the structured pairing, kernel included
    bpN = pair_rows_blocked(W, 0.5, 20)
    spN = pair_rows_structured(W, 0.5)
    np.testing.assert_allclose(
        np.asarray(apply_blocked_pairing(x, bpN, block_m=8, block_k=16)),
        np.asarray(apply_structured_pairing(
            x, spN, block_m=8, block_n=8, block_k=16
        )),
        rtol=1e-4, atol=1e-4,
    )


def test_contraction_savings_accounting():
    """The kernel's MXU contraction length is K - P: every pair saves a lane."""
    rng = np.random.default_rng(1)
    W = np.concatenate([rng.normal(size=(32, 16)) + 2, -(rng.normal(size=(32, 16)) + 2)])
    sp = pair_rows_structured(W, rounding=10.0)  # everything pairs
    K = W.shape[0]
    assert sp.n_pairs == 32
    assert sp.Kmat.shape[0] + sp.W_res.shape[0] == K - sp.n_pairs


def test_batched_inputs():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(4, 7, 48)), jnp.float32)  # (B, S, K)
    kmat = jnp.asarray(rng.normal(size=(16, 24)), jnp.float32)
    w_res = jnp.asarray(rng.normal(size=(16, 24)), jnp.float32)
    got = paired_matmul(x, kmat, w_res, block_m=8, block_n=8)
    assert got.shape == (4, 7, 24)
    want = paired_matmul_ref(x.reshape(-1, 48), kmat, w_res).reshape(4, 7, 24)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
