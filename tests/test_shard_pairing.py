"""Shard-boundary pairing math: per-shard builds, ledgers, degradation.

The invariant the mesh decode rests on: a shard-aware pairing is *exactly*
the concatenation of standalone pairings of each shard's weight slice — so
every TP device's metadata equals what it would build from its local rows,
and per-shard ledgers sum to the whole.
"""
import numpy as np

from repro.core.pairing import (
    pair_rows_blocked,
    pair_rows_blocked_sharded,
    pair_rows_structured,
    pair_rows_structured_sharded,
)
from repro.core.transform import pair_params, tp_shard_plan
from repro.parallel.sharding import Rules


def _pairable(rng, K, N, noise=0.01):
    """Matrix where row 2i+1 ≈ -row 2i, shuffled so pairs cross slab
    boundaries — unsharded pairing finds ~K/2 pairs, most of which a
    shard-constrained build must reject or re-find locally."""
    base = rng.normal(size=(K // 2, N))
    W = np.empty((K, N))
    W[0::2] = base
    W[1::2] = -base + noise * rng.normal(size=base.shape)
    return W[rng.permutation(K)]


class TestStructuredSharded:
    def test_equals_slab_concat(self):
        rng = np.random.default_rng(0)
        W = _pairable(rng, 64, 32)
        rs = 4
        step = 64 // rs
        got = pair_rows_structured_sharded(W, 0.1, row_shards=rs)
        parts = [
            pair_rows_structured(W[s * step:(s + 1) * step], 0.1)
            for s in range(rs)
        ]
        assert len(got.I) == sum(len(p.I) for p in parts)
        # every pair is slab-local with rebased global indices
        assert np.array_equal(
            np.asarray(got.I) // step, np.asarray(got.J) // step
        )
        exp_resid = np.concatenate(
            [np.asarray(p.resid) + s * step for s, p in enumerate(parts)]
        )
        np.testing.assert_array_equal(
            np.sort(np.asarray(got.resid)), np.sort(exp_resid)
        )

    def test_shard_constraint_costs_pairs(self):
        rng = np.random.default_rng(1)
        W = _pairable(rng, 64, 32)
        full = pair_rows_structured(W, 0.1)
        sharded = pair_rows_structured_sharded(W, 0.1, row_shards=4)
        assert 0 < len(sharded.I) < len(full.I)

    def test_degrades_when_not_dividing(self):
        rng = np.random.default_rng(2)
        W = _pairable(rng, 64, 32)
        a = pair_rows_structured_sharded(W, 0.1, row_shards=3)  # 64 % 3 != 0
        b = pair_rows_structured(W, 0.1)
        np.testing.assert_array_equal(np.asarray(a.I), np.asarray(b.I))
        np.testing.assert_array_equal(np.asarray(a.J), np.asarray(b.J))


class TestBlockedSharded:
    def test_equals_slab_concat_per_block(self):
        rng = np.random.default_rng(3)
        W = _pairable(rng, 32, 16)
        rs, bn, step = 2, 4, 16
        got = pair_rows_blocked_sharded(W, 0.1, bn, row_shards=rs)
        ref = pair_rows_blocked(W, 0.1, bn)
        assert got.n_blocks == ref.n_blocks
        for b, sp in enumerate(got.blocks):
            cols = slice(b * bn, (b + 1) * bn)
            parts = [
                pair_rows_structured(W[s * step:(s + 1) * step, cols], 0.1)
                for s in range(rs)
            ]
            assert sp.n_pairs == sum(p.n_pairs for p in parts)
            if sp.n_pairs:
                assert np.array_equal(
                    np.asarray(sp.I) // step, np.asarray(sp.J) // step
                )

    def test_row_shards_one_is_plain_blocked(self):
        rng = np.random.default_rng(4)
        W = _pairable(rng, 32, 16)
        a = pair_rows_blocked_sharded(W, 0.1, 1, row_shards=1)
        b = pair_rows_blocked(W, 0.1, 1)
        assert a.weighted_pairs == b.weighted_pairs
        for sa, sb in zip(a.blocks, b.blocks, strict=True):
            np.testing.assert_array_equal(np.asarray(sa.I), np.asarray(sb.I))


def _fake_lm(rng, L=2, K=32, N=16):
    """Minimal stacked tree pair_params accepts: one segment, one attn leaf."""
    wq = np.stack([_pairable(rng, K, N) for _ in range(L)]).astype(np.float32)
    return {"segments": [{"attn": {"wq": wq, "wo": np.transpose(wq, (0, 2, 1))}}]}


class TestPairParamsShards:
    def test_shards_none_is_baseline(self):
        rng = np.random.default_rng(5)
        tree = _fake_lm(rng)
        pm0, rep0 = pair_params(tree, 0.05, mode="per_column")
        pm1, rep1 = pair_params(tree, 0.05, mode="per_column", shards=None)
        import jax

        for a, b in zip(
            jax.tree.leaves(pm0), jax.tree.leaves(pm1), strict=True
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for lr in rep0.leaves:
            assert (lr.row_shards, lr.col_shards) == (1, 1)
            assert lr.shard_pairs is None

    def test_ledger_sums_and_col_split_invariance(self):
        rng = np.random.default_rng(6)
        tree = _fake_lm(rng)
        shards = {("attn", "wq"): (1, 4), ("attn", "wo"): (2, 1)}
        pm, rep = pair_params(tree, 0.05, mode="per_column", shards=shards)
        base, rep0 = pair_params(tree, 0.05, mode="per_column")
        by = {lr.path: lr for lr in rep.leaves}
        by0 = {lr.path: lr for lr in rep0.leaves}
        wq = by["segments[0].attn.wq"]
        assert (wq.row_shards, wq.col_shards) == (1, 4)
        assert sum(wq.shard_pairs) == wq.n_pairs
        # a block-aligned column split never constrains per-column pairing:
        # identical metadata and total to the unsharded build
        assert wq.n_pairs == by0["segments[0].attn.wq"].n_pairs
        np.testing.assert_array_equal(
            np.asarray(pm["segments"][0]["attn"]["wq_pairing"]["I"]),
            np.asarray(base["segments"][0]["attn"]["wq_pairing"]["I"]),
        )
        wo = by["segments[0].attn.wo"]
        assert (wo.row_shards, wo.col_shards) == (2, 1)
        assert sum(wo.shard_pairs) == wo.n_pairs
        assert wo.n_pairs <= by0["segments[0].attn.wo"].n_pairs

    def test_misaligned_col_split_degrades(self):
        rng = np.random.default_rng(7)
        tree = _fake_lm(rng)  # N = 16 columns
        pm, rep = pair_params(
            tree, 0.05, mode="column_blocked", block_n=3,
            shards={("attn", "wq"): (1, 4)},  # 16/4 = 4 cols/shard, 4 % 3 != 0
        )
        wq = next(lr for lr in rep.leaves if lr.path.endswith("wq"))
        assert wq.col_shards == 1

    def test_non_dividing_row_shards_degrade(self):
        rng = np.random.default_rng(8)
        tree = _fake_lm(rng)  # wo has K = 16 rows
        _, rep = pair_params(
            tree, 0.05, mode="per_column", shards={("attn", "wo"): (3, 1)}
        )
        wo = next(lr for lr in rep.leaves if lr.path.endswith("wo"))
        assert wo.row_shards == 1 and wo.shard_pairs is None


class _FakeMesh:
    """spec_for_axes/tp_shard_plan/rules_for only read mesh.shape and
    mesh.axis_names — enough to exercise multi-way splits in a
    single-device test process."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


class TestTpShardPlan:
    def _pieces(self):
        import dataclasses as dc

        import jax

        from repro.configs import get_smoke_config
        from repro.models import lm as M
        from repro.models.param import unzip

        cfg = dc.replace(get_smoke_config("qwen2-1.5b"), dtype="float32")
        params, axes = unzip(M.init_lm(cfg, jax.random.key(0)))
        return cfg, params, axes

    def test_plan_matches_decode_rules(self):
        from repro.parallel.rules import rules_for

        cfg, params, axes = self._pieces()
        mesh = _FakeMesh({"data": 2, "model": 4})
        rules = rules_for(cfg, "decode", mesh)
        plan = tp_shard_plan(axes, params, mesh, rules, leaves=cfg.paired_leaves)
        # column-parallel projections split columns; contraction-parallel
        # ones split rows; the smoke config's 2 kv heads don't divide 4
        assert plan[("attn", "wq")] == (1, 4)
        assert plan[("attn", "wk")] == (1, 1)
        assert plan[("attn", "wo")] == (4, 1)
        assert plan[("mlp", "w_gate")] == (1, 4)
        assert plan[("mlp", "w_down")] == (4, 1)

    def test_replicating_rules_give_unit_plan(self):
        cfg, params, axes = self._pieces()
        mesh = _FakeMesh({"data": 2, "model": 4})
        rules = Rules({})
        plan = tp_shard_plan(axes, params, mesh, rules, leaves=cfg.paired_leaves)
        assert all(rc == (1, 1) for rc in plan.values())


def test_swa_cache_keeps_full_length():
    """Regression for the shadowed ``Sc`` in ``init_cache``: hybrid_swa
    segments deliberately allocate the same full-length (max_seq +
    meta_tokens) K/V rows as full-attention segments — the decode scatter
    writes absolute positions, there is no ring buffer.  Pin it so a future
    ring-buffer change has to update this on purpose."""
    from repro.configs import get_smoke_config
    from repro.launch.steps import abstract_cache
    from repro.models import lm as M

    cfg = get_smoke_config("hymba-1.5b")
    assert cfg.sliding_window, "hymba smoke must exercise hybrid_swa"
    max_seq = 24
    S = max_seq + cfg.meta_tokens
    kinds = [k for k, _ in M.segment_kinds(cfg)]
    assert "hybrid_swa" in kinds
    cache, _ = abstract_cache(cfg, 2, max_seq)
    for kind, seg in zip(kinds, cache["segments"], strict=True):
        if "k" in seg:
            assert seg["k"].shape[2] == S, (kind, seg["k"].shape)
            assert seg["v"].shape[2] == S, (kind, seg["v"].shape)
