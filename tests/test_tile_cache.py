"""Tile selection: persisted TileCache, measured autotuning, dim clamping.

PR-3 acceptance gate: a warm TileCache hit is consulted in preference to
the VMEM heuristic, the cache survives a process round-trip (save → fresh
load), version mismatches are ignored rather than trusted, and
``choose_blocks`` clamps ``block_m``/``block_n`` to the actual problem dims
(LeNet conv GEMMs must not budget dead 128×128 tiles).
"""
import json

import numpy as np
import jax.numpy as jnp

from repro.kernels import tuning
from repro.kernels.ops import perf_context
from repro.kernels.paired_matmul import paired_matmul_pallas


def test_choose_blocks_clamps_to_problem_dims():
    """M=100, N=16 (LeNet conv2 GEMM scale) must not pad out to 128×128."""
    t = tuning.choose_blocks(100, 16, 0, 150, dtype_bytes=4)
    assert t.block_m <= 100 and t.block_n <= 16, t
    # the freed VMEM budget goes to the contraction tile
    assert t.block_k >= min(150, 128)
    # power-of-two problems keep their natural tiles
    big = tuning.choose_blocks(4096, 1024, 0, 4096)
    assert big.block_m == 128 and big.block_n == 128


def test_kernel_vmem_bytes_pool_window():
    """Fused pooling scales the activation streams and accumulator ×4,
    never the weight tiles."""
    base = tuning.kernel_vmem_bytes(64, 64, 128, pool_window=1)
    pooled = tuning.kernel_vmem_bytes(64, 64, 128, pool_window=4)
    assert pooled > base
    # weight tiles: 2 segments × (bk·bn) × 2 buffers × dtype_bytes
    w_bytes = 2 * (128 * 64) * 2 * 2
    x_bytes = base - w_bytes - (64 * 64 * 4 + 64 * 64 * 2)
    assert pooled == base + 3 * x_bytes + 3 * 64 * 64 * 4


def test_tile_cache_round_trip_and_version(tmp_path):
    path = tmp_path / "tc.json"
    key = tuning.cache_key(100, 16, 20, 110, dtype="float32", pool="max2")
    assert key == "M100-N16-K150-float32-p20r110-max2"
    c = tuning.TileCache(path)
    assert c.get(key) is None
    c.put(key, tuning.TileConfig(50, 16, 128), time_s=0.01)
    c.save()
    # fresh instance (new process simulation) sees the entry
    c2 = tuning.TileCache(path)
    assert c2.get(key) == tuning.TileConfig(50, 16, 128)
    # version mismatch → load as empty, never trust a stale schema
    raw = json.loads(path.read_text())
    raw["version"] = 99
    path.write_text(json.dumps(raw))
    assert len(tuning.TileCache(path)) == 0
    # corrupt file → load as empty
    path.write_text("{not json")
    assert len(tuning.TileCache(path)) == 0


def test_warm_cache_hit_beats_heuristic(tmp_path):
    """choose_blocks must return the cached (measured) config, not the
    heuristic's, when the active TileCache holds the problem key."""
    path = tmp_path / "tc.json"
    M, N, P, R = 100, 16, 20, 110
    heur = tuning.choose_blocks(M, N, P, R, dtype_bytes=4, dtype="float32")
    cached = tuning.TileConfig(50, 8, 64)
    assert cached != heur
    c = tuning.TileCache(path)
    c.put(
        tuning.cache_key(M, N, P, R, dtype="float32", dtype_bytes=4),
        cached,
    )
    c.save()

    with tuning.use_tile_cache(path):
        assert tuning.choose_blocks(
            M, N, P, R, dtype_bytes=4, dtype="float32"
        ) == cached
        # a different problem (or pool mode) misses → heuristic
        assert tuning.choose_blocks(
            M, N, P, R, dtype_bytes=4, dtype="float32", pool="max2"
        ) == tuning.choose_blocks(
            M, N, P, R, dtype_bytes=4, dtype="float32", pool="max2",
            use_cache=False,
        )
    # outside the context the cache is inactive again
    assert tuning.active_tile_cache() is None
    assert tuning.choose_blocks(M, N, P, R, dtype_bytes=4, dtype="float32") == heur


def test_resolve_blocks_explicit_beats_cache(tmp_path):
    """Explicit block sizes always win over cache and heuristic."""
    path = tmp_path / "tc.json"
    c = tuning.TileCache(path)
    c.put(tuning.cache_key(64, 64, 0, 64, dtype="float32", dtype_bytes=4),
          tuning.TileConfig(8, 8, 8))
    c.save()
    with tuning.use_tile_cache(path):
        t = tuning.resolve_blocks(
            64, 64, 0, 64, block_m=32, block_n=16, block_k=64,
            dtype_bytes=4, dtype="float32",
        )
    assert t == tuning.TileConfig(32, 16, 64)


def test_autotune_persists_winner(tmp_path):
    """autotune_blocks measures real kernel runs and writes the winner
    through to the cache choose_blocks consults."""
    rng = np.random.default_rng(0)
    M, N, P, R = 32, 16, 8, 24
    x = jnp.asarray(rng.normal(size=(M, 2 * P + R)), jnp.float32)
    km = jnp.asarray(rng.normal(size=(P, N)), jnp.float32)
    wr = jnp.asarray(rng.normal(size=(R, N)), jnp.float32)
    calls = []

    def runner(cfg):
        calls.append(cfg)
        return paired_matmul_pallas(
            x, km, wr, block_m=cfg.block_m, block_n=cfg.block_n,
            block_k=cfg.block_k, interpret=True,
        )

    cache = tuning.TileCache(tmp_path / "tc.json")
    best, records = tuning.autotune_blocks(
        runner, M, N, P, R, dtype_bytes=4, dtype="float32",
        cache=cache, reps=1, warmup=0,
    )
    assert calls and len(records) == len(set(calls))
    assert all(r["time_s"] > 0 and r["vmem_bytes"] > 0 for r in records)
    # winner is a measured candidate and now wins tile selection
    with tuning.use_tile_cache(tuning.TileCache(cache.path)):
        assert tuning.choose_blocks(
            M, N, P, R, dtype_bytes=4, dtype="float32"
        ) == best


def test_perf_context_installs_tile_cache(tmp_path):
    """PerfKnobs(tile_cache=path) activates the cache during the trace."""
    path = tmp_path / "tc.json"
    tuning.TileCache(path).save()

    class Knobs:
        gemm = "xla"
        conv = "xla"
        tile_cache = str(path)

    assert tuning.active_tile_cache() is None
    with perf_context(Knobs()):
        active = tuning.active_tile_cache()
        assert active is not None and active.path == path
    assert tuning.active_tile_cache() is None

    class NoCache:
        gemm = "xla"
        conv = "xla"
        tile_cache = ""

    with perf_context(NoCache()):
        assert tuning.active_tile_cache() is None


def test_candidate_configs_fit_vmem():
    for M, N, P, R, pool in [
        (100, 16, 20, 110, "none"),
        (196, 16, 30, 90, "max2"),
        (4096, 12288, 3000, 6288, "none"),
    ]:
        cands = tuning.candidate_configs(M, N, P, R, pool=pool)
        assert cands, (M, N, P, R)
        pw = 4 if pool != "none" else 1
        for c in cands:
            assert c.block_m <= max(M, 8) and c.block_n <= max(N, 8)
            assert tuning.kernel_vmem_bytes(
                c.block_m, c.block_n, min(c.block_k, max(P, R, 1)),
                has_pairs=P > 0, has_resid=R > 0, pool_window=pw,
            ) <= tuning.VMEM_BUDGET_BYTES


def test_measure_returns_positive_time():
    t = tuning.measure(lambda: sum(range(100)), reps=2, warmup=1)
    assert t > 0
