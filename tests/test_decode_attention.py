"""Fused decode attention feeding the paired out-projection.

Covers the op (``kernels.ops.fused_attn_decode``: one Pallas launch for
attention + subtractor out-projection + residual epilogue) against the
unfused XLA schedule at every metadata layout, its custom VJP, and the
``PerfKnobs(attn="pallas_fused")`` serving path end to end: token parity of
a fused-attention ServeEngine vs the XLA engine on dense, sliding-window +
sink (hymba), and enc-dec cross-attention (whisper) families.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.pairing import pair_rows_blocked
from repro.core.transform import _stack_blocked
from repro.kernels.ops import fold_lm_weight, fused_attn_decode
from repro.models import layers as L
from repro.models import lm as M
from repro.models.param import unzip
from repro.serving.engine import ServeEngine


def _blocked_meta(w2: np.ndarray, rounding: float, block_n: int) -> dict:
    """Single-layer column-blocked metadata in the stacked-artifact layout."""
    bp = pair_rows_blocked(np.asarray(w2, np.float64), rounding, block_n)
    stacked = _stack_blocked([bp])
    return {k: jnp.asarray(v[0]) for k, v in stacked.items()}


def _inputs(seed=0, B=2, S=16, H=4, KH=2, D=8):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, 1, H, D)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(B, S, KH, D)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, S, KH, D)), jnp.float32)
    pos = jnp.asarray([3, S - 1], jnp.int32)
    return rng, q, kc, vc, pos


def _unfused(q, kc, vc, pos, wf, res=None, **mask_kw):
    """The schedule the kernel replaces: dense attention, HBM round-trip,
    separate (folded-weight) projection, standalone residual add."""
    out = L.decode_attention(q, kc, vc, pos, **mask_kw)
    y = jnp.einsum("bsk,kn->bsn", out.reshape(*out.shape[:2], -1), wf)
    return y + res if res is not None else y


# ---------------------------------------------------------------------------
# op level
# ---------------------------------------------------------------------------


def test_unpaired_matches_dense_projection():
    """meta=None: the synthesized pure-residual block is the exact dense
    out-projection, residual epilogue included."""
    rng, q, kc, vc, pos = _inputs(0)
    w = jnp.asarray(rng.normal(size=(32, 12)), jnp.float32)  # (H·D, N)
    res = jnp.asarray(rng.normal(size=(2, 1, 12)), jnp.float32)
    got = fused_attn_decode(q, kc, vc, pos, w, residual=res, k_chunk=8)
    want = _unfused(q, kc, vc, pos, w, res)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("block_n", [1, 4])
def test_paired_r0_matches_dense_projection(block_n):
    """Blocked pairing at rounding 0: the subtractor segments reconstruct
    the exact weight, so the fused op == the unfused dense schedule."""
    rng, q, kc, vc, pos = _inputs(1)
    w = jnp.asarray(rng.normal(size=(32, 12)), jnp.float32)
    meta = _blocked_meta(np.asarray(w), 0.0, block_n)
    res = jnp.asarray(rng.normal(size=(2, 1, 12)), jnp.float32)
    got = fused_attn_decode(q, kc, vc, pos, w, meta, residual=res,
                            pair_block_n=block_n, k_chunk=8)
    want = _unfused(q, kc, vc, pos, w, res)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paired_rounded_matches_folded_oracle():
    """r > 0: the kernel executes the snapped pair magnitudes — it must
    match the folded-weight oracle exactly, not the original weight."""
    rng, q, kc, vc, pos = _inputs(2)
    w = jnp.asarray(rng.normal(size=(32, 12)), jnp.float32)
    meta = _blocked_meta(np.asarray(w), 0.3, 1)
    assert float(meta["pair_mask"].sum()) > 0, "rounding 0.3 must pair lanes"
    wf = fold_lm_weight(w, meta, pair_block_n=1)
    assert not np.allclose(np.asarray(wf), np.asarray(w))
    got = fused_attn_decode(q, kc, vc, pos, w, meta, pair_block_n=1, k_chunk=8)
    want = _unfused(q, kc, vc, pos, wf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_window_and_sink_masking():
    """Sliding window + sinks flow through to the in-kernel mask (the
    hybrid_swa decode semantics of ``layers._block_mask``)."""
    rng, q, kc, vc, pos = _inputs(3, S=24)
    w = jnp.asarray(rng.normal(size=(32, 12)), jnp.float32)
    for window, n_sink in ((6, 0), (6, 2)):
        got = fused_attn_decode(q, kc, vc, pos, w, window=window,
                                n_sink=n_sink, k_chunk=8)
        want = _unfused(q, kc, vc, pos, w, window=window, n_sink=n_sink)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


def test_custom_vjp_matches_xla_grads():
    """The Pallas-forward / XLA-backward split: grads wrt q, cache, weight
    and residual match differentiating the unfused reference."""
    rng, q, kc, vc, pos = _inputs(4)
    w = jnp.asarray(rng.normal(size=(32, 12)), jnp.float32)
    res = jnp.asarray(rng.normal(size=(2, 1, 12)), jnp.float32)
    meta = _blocked_meta(np.asarray(w), 0.0, 1)

    def loss(q, w, res, fused):
        if fused:
            y = fused_attn_decode(q, kc, vc, pos, w, meta, residual=res,
                                  pair_block_n=1, k_chunk=8)
        else:
            y = _unfused(q, kc, vc, pos, w, res)
        return (y * y).sum()

    gk = jax.grad(loss, argnums=(0, 1, 2))(q, w, res, True)
    gx = jax.grad(loss, argnums=(0, 1, 2))(q, w, res, False)
    for a, b in zip(gk, gx, strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_blocked_meta_requires_pair_block_n():
    rng, q, kc, vc, pos = _inputs(5)
    w = jnp.asarray(rng.normal(size=(32, 12)), jnp.float32)
    meta = _blocked_meta(np.asarray(w), 0.0, 1)
    with pytest.raises(ValueError, match="pair_block_n"):
        fused_attn_decode(q, kc, vc, pos, w, meta)


# ---------------------------------------------------------------------------
# serving path: PerfKnobs(attn="pallas_fused") end to end
# ---------------------------------------------------------------------------


def _engine_pair(arch, knobs_extra, max_seq=32):
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    params, _ = unzip(M.init_lm(cfg, jax.random.key(0)))
    base = dict(q_chunk=16, k_chunk=16, remat="none")
    eng_x = ServeEngine(cfg, params, max_seq=max_seq, batch_size=2,
                        knobs=M.PerfKnobs(**base))
    eng_f = ServeEngine(cfg, params, max_seq=max_seq, batch_size=2,
                        knobs=M.PerfKnobs(**base, attn="pallas_fused",
                                          **knobs_extra))
    return cfg, eng_x, eng_f


@pytest.mark.parametrize("arch,knobs_extra", [
    # plain dense GQA; fused attention alone (no paired GEMMs)
    ("qwen2-1.5b", {}),
    # the full fused decode schedule: paired QKV + attn→out-proj epilogue
    ("qwen2-1.5b", dict(gemm="pallas_paired", pair_rounding=0.0,
                        pair_block_n=1)),
    # sliding-window + meta-token sinks through the fused mask
    ("hymba-1.5b", {}),
])
def test_fused_engine_token_parity(arch, knobs_extra):
    cfg, eng_x, eng_f = _engine_pair(arch, knobs_extra)
    rng = np.random.default_rng(0)
    prompts = {
        0: rng.integers(0, cfg.vocab, size=(5,)).astype(np.int32),
        1: rng.integers(0, cfg.vocab, size=(11,)).astype(np.int32),
    }
    steps = 3
    out_x = eng_x.generate(dict(prompts), steps)
    out_f = eng_f.generate(dict(prompts), steps)
    assert out_f == out_x, f"fused attn diverged on {arch}: {out_f} vs {out_x}"


def test_fused_engine_token_parity_encdec():
    """Whisper: the cross-attention q/out-proj now ride ``layers.dense``
    (paired path) and self-attention decode rides the fused kernel."""
    cfg, eng_x, eng_f = _engine_pair("whisper-base", {})
    rng = np.random.default_rng(1)
    frames = jnp.asarray(
        rng.normal(size=(1, cfg.encoder.frames, cfg.d_model)), jnp.float32)
    prompts = {0: rng.integers(0, cfg.vocab, size=(4,)).astype(np.int32),
               1: rng.integers(0, cfg.vocab, size=(9,)).astype(np.int32)}
    steps = 3
    out_x = eng_x.generate(dict(prompts), steps, extras={"frames": frames})
    out_f = eng_f.generate(dict(prompts), steps, extras={"frames": frames})
    assert out_f == out_x, f"fused attn diverged on encdec: {out_f} vs {out_x}"


def test_engine_rejects_bad_attn_knob():
    cfg = dataclasses.replace(get_smoke_config("qwen2-1.5b"), dtype="float32")
    params, _ = unzip(M.init_lm(cfg, jax.random.key(0)))
    with pytest.raises(ValueError, match="knobs.attn"):
        ServeEngine(cfg, params, max_seq=16, batch_size=1,
                    knobs=M.PerfKnobs(attn="fused"))
    with pytest.raises(NotImplementedError, match="single-host"):
        ServeEngine(cfg, params, max_seq=16, batch_size=1,
                    knobs=M.PerfKnobs(attn="pallas_fused"), mesh=object())
