"""Table-I structural invariants, promoted from the bench docstring to tier-1.

``benchmarks/table1.py`` asserts these while it runs; this module pins them
as tests on the *trained* LeNet weights (session-cached fixture) so a
pairing-algorithm regression fails the suite, not just the bench job:

* the analytic ledger satisfies ``adds == mults`` and
  ``adds + subs == 405 600`` (the paper's conv MAC baseline) at every
  rounding;
* the subtraction count is monotone in the rounding size (Table I's trend);
* the pairing-mode spectrum is ordered at every rounding —
  ``structured ≤ column_blocked(n) ≤ … ≤ per_column`` in per-column-
  equivalent pair counts — and the executed ``block_n=1`` ledger equals the
  analytic per-column ledger exactly (the kernel runs Algorithm 1's
  pairing, not an approximation of it).
"""
import numpy as np
import pytest

from repro.core.pairing import (
    fold_columns,
    pair_columns,
    pair_rows_blocked,
    pair_rows_structured,
    sweep_rounding,
)
from repro.models.lenet import LENET_CONV_SHAPES

# Small sweep: the Table-I endpoints plus the paper's headline rounding and
# the band where the structured pairing starts to engage on trained weights.
ROUNDINGS = [0.0, 0.0001, 0.01, 0.05, 0.1, 0.3]
BLOCK_NS = [8, 4, 2, 1]  # structured → … → per-column order

BASELINE_MACS = 405600  # 117 600 + 240 000 + 48 000 (paper Table I)


def _conv_mats(params):
    """[(name, (K, N) matrix, positions)] for the three conv layers."""
    out = []
    for name, (shape, pos) in LENET_CONV_SHAPES.items():
        k = np.asarray(params[name]["w"], np.float64)
        H, W, Cin, Cout = k.shape
        out.append((name, k.reshape(H * W * Cin, Cout), pos))
    return out


@pytest.fixture(scope="module")
def ledger_rows(trained_lenet):
    params, _, _, _ = trained_lenet
    mats = _conv_mats(params)
    return sweep_rounding(
        [m for _, m, _ in mats], [p for _, _, p in mats], ROUNDINGS
    )


def test_adds_equal_mults(ledger_rows):
    """Pairing replaces one add + one mult together, never one alone."""
    for row in ledger_rows:
        assert row["adds"] == row["mults"], row


def test_baseline_macs_conserved(ledger_rows):
    """Every MAC is either still an add or became a sub: adds + subs is the
    paper's 405 600 baseline at every rounding."""
    for row in ledger_rows:
        assert row["adds"] + row["subs"] == BASELINE_MACS, row


def test_subs_monotone_in_rounding(ledger_rows):
    """A larger rounding window can only pair more (Table I's trend)."""
    subs = [row["subs"] for row in ledger_rows]
    assert subs == sorted(subs), subs


def test_pairing_mode_spectrum_ordered(trained_lenet):
    """structured ≤ blocked(8) ≤ blocked(4) ≤ blocked(2) ≤ per_column in
    per-column-equivalent pair counts, at every swept rounding."""
    params, _, _, _ = trained_lenet
    mats = _conv_mats(params)
    for r in ROUNDINGS:
        ladder = [
            sum(pair_rows_structured(m, r).weighted_pairs for _, m, _ in mats)
        ]
        for bn in BLOCK_NS:
            ladder.append(
                sum(
                    pair_rows_blocked(m, r, bn).weighted_pairs
                    for _, m, _ in mats
                )
            )
        ladder.append(
            sum(pair_columns(m, r).total_pairs for _, m, _ in mats)
        )
        assert all(a <= b for a, b in zip(ladder, ladder[1:], strict=False)), (r, ladder)


def test_blocked_1_ledger_is_the_analytic_ledger(trained_lenet):
    """The executed per-column pairing (block_n=1) reproduces the analytic
    Algorithm-1 ledger exactly, layer by layer, at every swept rounding."""
    params, _, _, _ = trained_lenet
    for name, m, pos in _conv_mats(params):
        for r in ROUNDINGS:
            bp = pair_rows_blocked(m, r, 1)
            cp = pair_columns(m, r)
            assert bp.weighted_pairs == cp.total_pairs, (name, r)
            # and the folded (deploy-equivalent) matrices are identical
            np.testing.assert_array_equal(bp.fold(), fold_columns(m, cp))
