"""Pallas flash-attention kernel vs the pure-jnp blocked reference."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl

from repro.kernels import decode_attention as da
from repro.kernels import flash_attention as fa
from repro.kernels.flash_attention import flash_attention_fwd
from repro.models import layers as L
from repro.models.layers import flash_attention


def _mha_ref(q, k, v, causal):
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    qf = q.astype(jnp.float32).reshape(B, Sq, KH, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf) / np.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, k.shape[1]), bool))
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return o.reshape(B, Sq, H, D).astype(q.dtype)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize(
    "B,Sq,Sk,H,KH,D,qc,kc",
    [
        (2, 64, 64, 4, 2, 16, 16, 16),
        (1, 128, 128, 2, 2, 32, 32, 64),
        (2, 32, 32, 4, 1, 8, 32, 32),  # single kv head (MQA), one block
    ],
)
def test_flash_kernel_matches_dense_ref(B, Sq, Sk, H, KH, D, qc, kc, causal):
    rng = np.random.default_rng(B * 100 + H)
    q = jnp.asarray(rng.normal(size=(B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sk, KH, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sk, KH, D)), jnp.float32)
    got = flash_attention_fwd(q, k, v, causal=causal, q_chunk=qc, k_chunk=kc)
    want = _mha_ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_flash_kernel_matches_model_flash_path():
    """Kernel == the model's jnp flash path (the thing it replaces on TPU)."""
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(2, 64, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 64, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 64, 2, 16)), jnp.float32)
    got = flash_attention_fwd(q, k, v, causal=True, q_chunk=16, k_chunk=16)
    want = flash_attention(q, k, v, causal=True, q_chunk=16, k_chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("Sq,Sk", [(37, 53), (17, 64), (64, 21)])
def test_flash_kernel_ragged_lengths(Sq, Sk, causal):
    """Lengths the chunk grid does not divide: the kernel pads internally,
    masks the padded key lanes, and slices the output back — no assert on
    ``Sq % q_chunk`` left to vanish under ``python -O``."""
    rng = np.random.default_rng(Sq * 100 + Sk)
    q = jnp.asarray(rng.normal(size=(2, Sq, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, Sk, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, Sk, 2, 16)), jnp.float32)
    got = flash_attention_fwd(q, k, v, causal=causal, q_chunk=16, k_chunk=16)
    want = _mha_ref(q, k, v, causal)
    assert got.shape == want.shape
    assert np.isfinite(np.asarray(got)).all()
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_scratch_fallback_memref(monkeypatch):
    """The backend-neutral ``_SCRATCH`` fallback (taken when the pltpu
    namespace is absent) must actually work as a ``scratch_shapes`` entry —
    the old ``None`` sentinel TypeError'd on first kernel call."""
    fallback = functools.partial(pl.MemoryRef, memory_space=pl.MemorySpace.ANY)
    monkeypatch.setattr(fa, "_SCRATCH", fallback)
    monkeypatch.setattr(da, "_SCRATCH", fallback)
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(1, 32, 2, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 32, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 32, 2, 16)), jnp.float32)
    got = fa.flash_attention_fwd(q, k, v, causal=True, q_chunk=16, k_chunk=16)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(_mha_ref(q, k, v, True)),
                               rtol=2e-4, atol=2e-4)
    qd = jnp.asarray(rng.normal(size=(2, 1, 4, 16)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(2, 16, 2, 16)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(2, 16, 2, 16)), jnp.float32)
    pos = jnp.asarray([5, 11], jnp.int32)
    got_d = da.decode_attention_fwd(qd, kc, vc, pos, k_chunk=8)
    want_d = L.decode_attention(qd, kc, vc, pos)
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               rtol=2e-5, atol=2e-5)


def test_gqa_non_divisible_heads_raise():
    """H % KH != 0 is a loud ValueError, not a silent index-map wraparound."""
    q = jnp.zeros((1, 8, 3, 8), jnp.float32)
    kv = jnp.zeros((1, 8, 2, 8), jnp.float32)
    with pytest.raises(ValueError, match="divide evenly"):
        flash_attention_fwd(q, kv, kv, q_chunk=8, k_chunk=8)
    qd = jnp.zeros((1, 1, 3, 8), jnp.float32)
    with pytest.raises(ValueError, match="divide evenly"):
        da.decode_attention_fwd(qd, kv, kv, jnp.zeros((1,), jnp.int32))


@pytest.mark.parametrize("window,n_sink", [(0, 0), (6, 0), (6, 2)])
@pytest.mark.parametrize("S,k_chunk", [(8, 8), (24, 8), (33, 16)])
def test_decode_kernel_matches_layers_decode(S, k_chunk, window, n_sink):
    """Bare fused decode kernel vs ``layers.decode_attention`` across cache
    lengths (incl. ragged S), slot positions, sliding windows and sinks."""
    rng = np.random.default_rng(S * 10 + window + n_sink)
    B, H, KH, D = 3, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, 1, H, D)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(B, S, KH, D)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(B, S, KH, D)), jnp.float32)
    pos = jnp.asarray([0, S // 2, S - 1], jnp.int32)
    got = da.decode_attention_fwd(q, kc, vc, pos, window=window,
                                  n_sink=n_sink, k_chunk=k_chunk)
    want = L.decode_attention(q, kc, vc, pos, window=window, n_sink=n_sink)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_kernel_fully_masked_slot_is_finite_zero():
    """A slot whose mask admits no keys (pos = -1: a fresh/inactive batch
    lane) must flush exact zeros, not NaN from an all--inf softmax row."""
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.normal(size=(2, 1, 4, 8)), jnp.float32)
    kc = jnp.asarray(rng.normal(size=(2, 16, 2, 8)), jnp.float32)
    vc = jnp.asarray(rng.normal(size=(2, 16, 2, 8)), jnp.float32)
    pos = jnp.asarray([-1, 7], jnp.int32)
    got = np.asarray(da.decode_attention_fwd(q, kc, vc, pos, k_chunk=8))
    assert np.isfinite(got).all()
    np.testing.assert_array_equal(got[0], 0.0)
    want = L.decode_attention(q[1:], kc[1:], vc[1:], pos[1:])
    np.testing.assert_allclose(got[1:], np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_kernel_bf16():
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
    got = flash_attention_fwd(q, k, v, causal=True, q_chunk=32, k_chunk=32)
    want = _mha_ref(q, k, v, True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=5e-2, atol=5e-2
    )
