"""Pallas flash-attention kernel vs the pure-jnp blocked reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_fwd
from repro.models.layers import flash_attention


def _mha_ref(q, k, v, causal):
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    qf = q.astype(jnp.float32).reshape(B, Sq, KH, G, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qf, kf) / np.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, k.shape[1]), bool))
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    return o.reshape(B, Sq, H, D).astype(q.dtype)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize(
    "B,Sq,Sk,H,KH,D,qc,kc",
    [
        (2, 64, 64, 4, 2, 16, 16, 16),
        (1, 128, 128, 2, 2, 32, 32, 64),
        (2, 32, 32, 4, 1, 8, 32, 32),  # single kv head (MQA), one block
    ],
)
def test_flash_kernel_matches_dense_ref(B, Sq, Sk, H, KH, D, qc, kc, causal):
    rng = np.random.default_rng(B * 100 + H)
    q = jnp.asarray(rng.normal(size=(B, Sq, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Sk, KH, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Sk, KH, D)), jnp.float32)
    got = flash_attention_fwd(q, k, v, causal=causal, q_chunk=qc, k_chunk=kc)
    want = _mha_ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_flash_kernel_matches_model_flash_path():
    """Kernel == the model's jnp flash path (the thing it replaces on TPU)."""
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(2, 64, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, 64, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 64, 2, 16)), jnp.float32)
    got = flash_attention_fwd(q, k, v, causal=True, q_chunk=16, k_chunk=16)
    want = flash_attention(q, k, v, causal=True, q_chunk=16, k_chunk=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_flash_kernel_bf16():
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
    got = flash_attention_fwd(q, k, v, causal=True, q_chunk=32, k_chunk=32)
    want = _mha_ref(q, k, v, True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=5e-2, atol=5e-2
    )
