"""Paired convolution on the Pallas GEMM path.

Covers: im2col lowering (conv equivalence + adjoint round-trip), the
paired_conv kernel path vs ``lax.conv_general_dilated`` at rounding 0
(≤ 1e-5) and bounded error at rounding > 0, across all three LeNet-5 conv
shapes, plus the ``conv_impl`` policy dispatch — including under
``jax.grad``.  The column-blocked pairing mode gets the same treatment:
r=0 XLA parity on every LeNet geometry plus a strided+padded one, oracle
parity at r>0, and jit+grad through the per-n-block kernel layout.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pairing import pair_rows_blocked, pair_rows_structured
from repro.core.transform import build_conv_pairings
from repro.kernels.im2col import col2im, im2col, overlap_counts
from repro.kernels.ops import conv_context, pallas_conv
from repro.kernels.paired_conv import (
    conv_im2col,
    folded_conv_weight,
    paired_conv,
    paired_conv_ref,
)
from repro.models.lenet import (
    LENET_CONV_POSITIONS,
    init_lenet,
    lenet_apply,
)

# (input shape NHWC, conv kernel HWIO) — LeNet-5's three conv layers, at the
# spatial sizes they actually see in the network (32→28, 14→10, 5→1).
LENET_CASES = [
    ((2, 32, 32, 1), (5, 5, 1, 6)),
    ((2, 14, 14, 6), (5, 5, 6, 16)),
    ((2, 5, 5, 16), (5, 5, 16, 120)),
]


def _xla_conv(x, w, b=None, stride=(1, 1), padding="VALID"):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=stride, padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y if b is None else y + b


def _pairable_kernel(rng, kshape, rounding, frac=0.4):
    """Conv kernel with planted opposite-sign row structure.

    A fraction of the (kh·kw·cin) patch lanes comes in ±pairs whose symmetric
    part is well inside ``rounding``, so ``pair_rows_structured`` finds a
    nontrivial pairing (trained LeNet weights pair only at large roundings
    under the structured criterion, so tests plant the structure).
    """
    kh, kw, cin, cout = kshape
    K = kh * kw * cin
    P = max(1, int(K * frac / 2))
    half = rng.normal(size=(P, cout)) * 0.3 + 1.0
    noise = rng.normal(size=(P, cout)) * (rounding * 0.1)
    # residual rows sit well below the planted mean band, so the greedy
    # mean-sorted walk retires them without consuming planted partners
    rest = rng.normal(size=(K - 2 * P, cout)) * 0.02
    wm = np.concatenate([half, -half + noise, rest]).astype(np.float32)
    return wm.reshape(kshape), P


# ---------------------------------------------------------------------------
# im2col
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("xshape,kshape", LENET_CASES)
def test_im2col_lowers_conv_exactly(xshape, kshape):
    rng = np.random.default_rng(xshape[1])
    x = jnp.asarray(rng.normal(size=xshape), jnp.float32)
    w = jnp.asarray(rng.normal(size=kshape), jnp.float32)
    kh, kw, cin, cout = kshape
    patches = im2col(x, kh, kw)
    got = jnp.einsum("nhwk,kf->nhwf", patches, w.reshape(kh * kw * cin, cout))
    want = _xla_conv(x, w)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_conv_im2col_bias_activation():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(3, 10, 12, 4)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 8)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    got = conv_im2col(x, w, b, activation="relu")
    want = jax.nn.relu(_xla_conv(x, w, b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_im2col_round_trip():
    """col2im is the exact adjoint of im2col, and the count-normalised
    round-trip reconstructs the image."""
    rng = np.random.default_rng(7)
    xshape, (kh, kw) = (2, 9, 11, 3), (3, 5)
    x = jnp.asarray(rng.normal(size=xshape), jnp.float32)
    cols = im2col(x, kh, kw)
    y = jnp.asarray(rng.normal(size=cols.shape), jnp.float32)
    # adjoint identity: <im2col(x), y> == <x, col2im(y)>
    lhs = float(jnp.vdot(cols, y))
    rhs = float(jnp.vdot(x, col2im(y, xshape, kh, kw)))
    assert abs(lhs - rhs) <= 1e-3 * max(1.0, abs(lhs))
    # overlap-add round-trip: divide by coverage counts to recover x
    counts = overlap_counts(xshape, kh, kw)
    assert float(counts.max()) == kh * kw and float(counts.min()) == 1
    back = col2im(cols, xshape, kh, kw) / counts
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# paired_conv vs lax.conv
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("xshape,kshape", LENET_CASES)
def test_paired_conv_r0_matches_xla(xshape, kshape):
    """Rounding 0 → no pairs → the Pallas path must equal XLA conv ≤ 1e-5."""
    rng = np.random.default_rng(kshape[3])
    x = jnp.asarray(rng.normal(size=xshape), jnp.float32)
    w = jnp.asarray(rng.normal(size=kshape), jnp.float32)
    b = jnp.asarray(rng.normal(size=(kshape[3],)), jnp.float32)
    kh, kw, cin, cout = kshape
    sp = pair_rows_structured(
        np.asarray(w, np.float64).reshape(kh * kw * cin, cout), 0.0
    )
    assert sp.n_pairs == 0
    got = paired_conv(x, w, b, pairing=sp)
    want = _xla_conv(x, w, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("xshape,kshape", LENET_CASES)
def test_paired_conv_bounded_error_at_positive_rounding(xshape, kshape):
    """At r > 0: kernel == folded oracle ≤ 1e-5, and the deviation from the
    exact conv obeys the analytic bound 2·max|x|·P·√N·r (rms criterion)."""
    rounding = 0.1
    rng = np.random.default_rng(sum(kshape))
    x = jnp.asarray(rng.normal(size=xshape), jnp.float32)
    w_np, planted = _pairable_kernel(rng, kshape, rounding)
    w = jnp.asarray(w_np)
    kh, kw, cin, cout = kshape
    sp = pair_rows_structured(
        w_np.astype(np.float64).reshape(kh * kw * cin, cout), rounding
    )
    assert sp.n_pairs >= planted, "planted pairs must be found"

    got = np.asarray(paired_conv(x, w, None, pairing=sp))
    oracle = np.asarray(paired_conv_ref(x, w, None, sp))
    np.testing.assert_allclose(got, oracle, rtol=1e-4, atol=1e-4)

    exact = np.asarray(_xla_conv(x, w))
    err = np.abs(got - exact).max()
    bound = 2 * float(jnp.abs(x).max()) * sp.n_pairs * np.sqrt(cout) * rounding
    assert err <= bound, f"error {err:.3e} exceeds analytic bound {bound:.3e}"


def test_folded_conv_weight_matches_offline_fold():
    """Live-weight folding == StructuredPairing.fold() on the same weights."""
    rng = np.random.default_rng(3)
    kshape = (3, 3, 4, 8)
    w_np, _ = _pairable_kernel(rng, kshape, 0.2)
    wm = w_np.astype(np.float64).reshape(36, 8)
    sp = pair_rows_structured(wm, 0.2)
    live = np.asarray(folded_conv_weight(jnp.asarray(w_np), sp), np.float64)
    np.testing.assert_allclose(live.reshape(36, 8), sp.fold(), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# conv_impl dispatch (explicit arg, policy, and under jax.grad)
# ---------------------------------------------------------------------------


def test_lenet_conv_impl_switch():
    params = init_lenet(jax.random.key(0))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(2, 32, 32, 1)), jnp.float32)
    y_xla = lenet_apply(params, x)
    y_col = lenet_apply(params, x, conv_impl="im2col")
    np.testing.assert_allclose(np.asarray(y_col), np.asarray(y_xla), rtol=1e-5, atol=1e-5)
    arts = build_conv_pairings(params, 0.0)
    y_pal = lenet_apply(params, x, conv_impl="pallas_paired", paired=arts)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_xla), rtol=1e-4, atol=1e-5)
    with pytest.raises(ValueError, match="pairing artifacts"):
        lenet_apply(params, x, conv_impl="pallas_paired")


def test_lenet_conv_policy_dispatch():
    """The thread-local pallas_conv policy (what PerfKnobs(conv=...) installs
    via conv_context) must route lenet_apply without touching call sites."""
    params = init_lenet(jax.random.key(2))
    x = jnp.asarray(np.random.default_rng(2).normal(size=(2, 32, 32, 1)), jnp.float32)
    arts = build_conv_pairings(params, 0.0)
    want = lenet_apply(params, x, conv_impl="pallas_paired", paired=arts)
    with pallas_conv(paired=arts):
        got = lenet_apply(params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)

    class Knobs:
        conv = "im2col"
        block_m = block_n = block_k = 0

    with conv_context(Knobs()):
        got2 = lenet_apply(params, x)
    want2 = lenet_apply(params, x, conv_impl="im2col")
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want2), rtol=1e-6, atol=1e-6)


def test_conv_impl_dispatch_under_grad():
    """All three conv_impl choices are differentiable; at rounding 0 their
    parameter gradients agree with the XLA reference path."""
    params = init_lenet(jax.random.key(4))
    x = jnp.asarray(np.random.default_rng(4).normal(size=(2, 32, 32, 1)), jnp.float32)
    arts0 = build_conv_pairings(params, 0.0)

    def loss(p, impl, paired=None):
        return (lenet_apply(p, x, conv_impl=impl, paired=paired) ** 2).mean()

    g_xla = jax.grad(loss)(params, "xla")
    g_col = jax.grad(loss)(params, "im2col")
    g_pal = jax.grad(loss)(params, "pallas_paired", arts0)
    for ref, got in ((g_xla, g_col), (g_xla, g_pal)):
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got), strict=True):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=1e-3, atol=1e-4)

    # policy form, under jit + grad (the serving/training route)
    with pallas_conv(paired=arts0):
        g_pol = jax.jit(jax.grad(lambda p: (lenet_apply(p, x) ** 2).mean()))(params)
    for a, b in zip(jax.tree.leaves(g_xla), jax.tree.leaves(g_pol), strict=True):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=1e-3, atol=1e-4)

    # rounding > 0: grads flow through the frozen pairing structure
    arts = build_conv_pairings(params, 1.0)
    assert sum(a.n_pairs for a in arts.values()) > 0
    g_r = jax.grad(loss)(params, "pallas_paired", arts)
    leaves = jax.tree.leaves(g_r)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves)
    assert sum(float(jnp.abs(g).sum()) for g in leaves) > 0


def test_build_conv_pairings_artifacts():
    params = init_lenet(jax.random.key(5))
    arts = build_conv_pairings(params, 0.05, positions=LENET_CONV_POSITIONS)
    assert set(arts) == {"conv1", "conv2", "conv3"}
    total = sum(a.measured_op_counts()["baseline_lanes"] for a in arts.values())
    assert total == 405600, "kernel baseline lanes must equal the paper's multiplies"
    for a in arts.values():
        c = a.measured_op_counts()
        assert c["baseline_lanes"] - c["paired_lanes"] == c["lanes_saved"]
        assert c["subs_executed"] == a.n_pairs * a.positions


# ---------------------------------------------------------------------------
# column-blocked pairing through the per-n-block kernel layout
# ---------------------------------------------------------------------------

# one strided + SAME-padded non-LeNet geometry rides along with the three
# LeNet shapes (stride/padding thread through im2col identically, but the
# blocked gather must survive the changed patch-row count)
BLOCKED_CASES = [(*c, (1, 1), "VALID") for c in LENET_CASES] + [
    ((2, 13, 13, 3), (3, 3, 3, 8), (2, 2), "SAME"),
]


@pytest.mark.parametrize("block_n", [1, 4])
@pytest.mark.parametrize("xshape,kshape,stride,padding", BLOCKED_CASES)
def test_blocked_conv_r0_matches_xla(xshape, kshape, stride, padding, block_n):
    """Rounding 0 through the blocked layout (block_n=1 == the paper's
    per-column pairing) must equal the XLA conv ≤ 1e-5."""
    rng = np.random.default_rng(kshape[3] + block_n)
    x = jnp.asarray(rng.normal(size=xshape), jnp.float32)
    w = jnp.asarray(rng.normal(size=kshape), jnp.float32)
    b = jnp.asarray(rng.normal(size=(kshape[3],)), jnp.float32)
    kh, kw, cin, cout = kshape
    bp = pair_rows_blocked(
        np.asarray(w, np.float64).reshape(kh * kw * cin, cout), 0.0, block_n
    )
    assert bp.n_pairs == 0
    got = paired_conv(x, w, b, pairing=bp, stride=stride, padding=padding)
    want = _xla_conv(x, w, b, stride=stride, padding=padding)
    rel = float(
        jnp.abs(got - want).max() / jnp.maximum(jnp.abs(want).max(), 1e-30)
    )
    assert rel <= 1e-5, f"block_n={block_n} {xshape}->{kshape}: rel {rel:.2e}"


@pytest.mark.parametrize("block_n", [1, 3, 16])
def test_blocked_conv_matches_oracle_at_positive_rounding(block_n):
    """With planted pairs the blocked kernel equals its folded oracle, and
    the executed pairing is at least as rich as the structured one."""
    xshape, kshape = (2, 14, 14, 6), (5, 5, 6, 16)
    rounding = 0.1
    rng = np.random.default_rng(block_n)
    x = jnp.asarray(rng.normal(size=xshape), jnp.float32)
    w_np, planted = _pairable_kernel(rng, kshape, rounding)
    w = jnp.asarray(w_np)
    kh, kw, cin, cout = kshape
    wm = w_np.astype(np.float64).reshape(kh * kw * cin, cout)
    bp = pair_rows_blocked(wm, rounding, block_n)
    # every block must at least recover the planted antisymmetric rows
    # (greedy monotonicity vs the structured pairing is a property of real
    # trained weights, pinned in test_table1_ledger; planted adversarial
    # noise can locally re-order the greedy walk)
    assert bp.weighted_pairs >= planted * cout

    got = np.asarray(paired_conv(x, w, None, pairing=bp))
    oracle = np.asarray(paired_conv_ref(x, w, None, bp))
    np.testing.assert_allclose(got, oracle, rtol=1e-4, atol=1e-4)


def test_blocked_folded_weight_matches_offline_fold():
    """Live blocked folding == BlockedPairing.fold() on the same weights."""
    rng = np.random.default_rng(13)
    kshape = (3, 3, 4, 10)
    w_np, _ = _pairable_kernel(rng, kshape, 0.2)
    wm = w_np.astype(np.float64).reshape(36, 10)
    for block_n in (1, 3, 10):
        bp = pair_rows_blocked(wm, 0.2, block_n)
        live = np.asarray(folded_conv_weight(jnp.asarray(w_np), bp), np.float64)
        np.testing.assert_allclose(
            live.reshape(36, 10), bp.fold(), rtol=1e-6, atol=1e-6
        )


def test_blocked_lenet_under_jit_grad():
    """LeNet through column-blocked artifacts: forward parity with XLA at
    r=0 under jit, and parameter gradients matching the XLA reference."""
    params = init_lenet(jax.random.key(6))
    x = jnp.asarray(
        np.random.default_rng(6).normal(size=(2, 32, 32, 1)), jnp.float32
    )
    arts = build_conv_pairings(params, 0.0, mode="column_blocked", block_n=4)
    y_ref = lenet_apply(params, x)
    y_blk = jax.jit(
        lambda p, xb: lenet_apply(
            p, xb, conv_impl="pallas_paired", paired=arts
        )
    )(params, x)
    rel = float(jnp.abs(y_blk - y_ref).max() / jnp.abs(y_ref).max())
    assert rel <= 1e-5

    g_ref = jax.grad(lambda p: (lenet_apply(p, x) ** 2).mean())(params)
    g_blk = jax.jit(
        jax.grad(
            lambda p: (
                lenet_apply(p, x, conv_impl="pallas_paired", paired=arts) ** 2
            ).mean()
        )
    )(params)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_blk), strict=True):
        np.testing.assert_allclose(
            np.asarray(b), np.asarray(a), rtol=1e-3, atol=1e-4
        )

    # rounding > 0: grads flow through the frozen per-block structure
    arts_r = build_conv_pairings(params, 0.3, mode="column_blocked", block_n=2)
    assert sum(a.n_pairs for a in arts_r.values()) > 0
    g_r = jax.grad(
        lambda p: (
            lenet_apply(p, x, conv_impl="pallas_paired", paired=arts_r) ** 2
        ).mean()
    )(params)
    leaves = jax.tree.leaves(g_r)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves)
    assert sum(float(jnp.abs(g).sum()) for g in leaves) > 0


def test_pair_block_n_knob_builds_blocked_artifacts():
    """PerfKnobs-style pair_block_n drives artifact building end to end:
    conv_pairings_from_knobs honours the knob, and the resulting artifacts
    route lenet_apply through the blocked kernel via the conv policy."""
    from repro.core.pairing import BlockedPairing, StructuredPairing
    from repro.kernels.ops import conv_pairings_from_knobs, paired_mode_of

    params = init_lenet(jax.random.key(9))
    x = jnp.asarray(
        np.random.default_rng(9).normal(size=(1, 32, 32, 1)), jnp.float32
    )

    class Knobs:
        conv = "pallas_paired"
        fuse_pool = False
        pair_block_n = 0
        block_m = block_n = block_k = 0

    assert paired_mode_of(Knobs()) == ("structured", 0)
    arts_s = conv_pairings_from_knobs(params, 0.0, Knobs())
    assert all(isinstance(a.pairing, StructuredPairing) for a in arts_s.values())

    Knobs.pair_block_n = 4
    assert paired_mode_of(Knobs()) == ("column_blocked", 4)
    arts_b = conv_pairings_from_knobs(
        params, 0.0, Knobs(), positions=LENET_CONV_POSITIONS
    )
    assert all(isinstance(a.pairing, BlockedPairing) for a in arts_b.values())
    assert all(a.pairing.block_n == min(4, a.kernel_shape[3])
               for a in arts_b.values())

    y_ref = lenet_apply(params, x)
    with conv_context(Knobs(), paired=arts_b):
        y_blk = lenet_apply(params, x)
    rel = float(jnp.abs(y_blk - y_ref).max() / jnp.abs(y_ref).max())
    assert rel <= 1e-5


def test_blocked_mode_validation():
    params = init_lenet(jax.random.key(7))
    with pytest.raises(ValueError, match="block_n"):
        build_conv_pairings(params, 0.05, mode="column_blocked")
    # per_column sugar == column_blocked with block_n=1
    a = build_conv_pairings(params, 0.05, mode="per_column")
    b = build_conv_pairings(params, 0.05, mode="column_blocked", block_n=1)
    for name in a:
        assert a[name].n_pairs == b[name].n_pairs
        assert a[name].pairing.block_n == 1
