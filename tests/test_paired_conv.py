"""Paired convolution on the Pallas GEMM path.

Covers: im2col lowering (conv equivalence + adjoint round-trip), the
paired_conv kernel path vs ``lax.conv_general_dilated`` at rounding 0
(≤ 1e-5) and bounded error at rounding > 0, across all three LeNet-5 conv
shapes, plus the ``conv_impl`` policy dispatch — including under
``jax.grad``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pairing import pair_rows_structured
from repro.core.transform import build_conv_pairings
from repro.kernels.im2col import col2im, im2col, overlap_counts
from repro.kernels.ops import conv_context, pallas_conv
from repro.kernels.paired_conv import (
    conv_im2col,
    folded_conv_weight,
    paired_conv,
    paired_conv_ref,
)
from repro.models.lenet import (
    LENET_CONV_POSITIONS,
    init_lenet,
    lenet_apply,
)

# (input shape NHWC, conv kernel HWIO) — LeNet-5's three conv layers, at the
# spatial sizes they actually see in the network (32→28, 14→10, 5→1).
LENET_CASES = [
    ((2, 32, 32, 1), (5, 5, 1, 6)),
    ((2, 14, 14, 6), (5, 5, 6, 16)),
    ((2, 5, 5, 16), (5, 5, 16, 120)),
]


def _xla_conv(x, w, b=None):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y if b is None else y + b


def _pairable_kernel(rng, kshape, rounding, frac=0.4):
    """Conv kernel with planted opposite-sign row structure.

    A fraction of the (kh·kw·cin) patch lanes comes in ±pairs whose symmetric
    part is well inside ``rounding``, so ``pair_rows_structured`` finds a
    nontrivial pairing (trained LeNet weights pair only at large roundings
    under the structured criterion, so tests plant the structure).
    """
    kh, kw, cin, cout = kshape
    K = kh * kw * cin
    P = max(1, int(K * frac / 2))
    half = rng.normal(size=(P, cout)) * 0.3 + 1.0
    noise = rng.normal(size=(P, cout)) * (rounding * 0.1)
    # residual rows sit well below the planted mean band, so the greedy
    # mean-sorted walk retires them without consuming planted partners
    rest = rng.normal(size=(K - 2 * P, cout)) * 0.02
    wm = np.concatenate([half, -half + noise, rest]).astype(np.float32)
    return wm.reshape(kshape), P


# ---------------------------------------------------------------------------
# im2col
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("xshape,kshape", LENET_CASES)
def test_im2col_lowers_conv_exactly(xshape, kshape):
    rng = np.random.default_rng(xshape[1])
    x = jnp.asarray(rng.normal(size=xshape), jnp.float32)
    w = jnp.asarray(rng.normal(size=kshape), jnp.float32)
    kh, kw, cin, cout = kshape
    patches = im2col(x, kh, kw)
    got = jnp.einsum("nhwk,kf->nhwf", patches, w.reshape(kh * kw * cin, cout))
    want = _xla_conv(x, w)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_conv_im2col_bias_activation():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(3, 10, 12, 4)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 8)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(8,)), jnp.float32)
    got = conv_im2col(x, w, b, activation="relu")
    want = jax.nn.relu(_xla_conv(x, w, b))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_im2col_round_trip():
    """col2im is the exact adjoint of im2col, and the count-normalised
    round-trip reconstructs the image."""
    rng = np.random.default_rng(7)
    xshape, (kh, kw) = (2, 9, 11, 3), (3, 5)
    x = jnp.asarray(rng.normal(size=xshape), jnp.float32)
    cols = im2col(x, kh, kw)
    y = jnp.asarray(rng.normal(size=cols.shape), jnp.float32)
    # adjoint identity: <im2col(x), y> == <x, col2im(y)>
    lhs = float(jnp.vdot(cols, y))
    rhs = float(jnp.vdot(x, col2im(y, xshape, kh, kw)))
    assert abs(lhs - rhs) <= 1e-3 * max(1.0, abs(lhs))
    # overlap-add round-trip: divide by coverage counts to recover x
    counts = overlap_counts(xshape, kh, kw)
    assert float(counts.max()) == kh * kw and float(counts.min()) == 1
    back = col2im(cols, xshape, kh, kw) / counts
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# paired_conv vs lax.conv
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("xshape,kshape", LENET_CASES)
def test_paired_conv_r0_matches_xla(xshape, kshape):
    """Rounding 0 → no pairs → the Pallas path must equal XLA conv ≤ 1e-5."""
    rng = np.random.default_rng(kshape[3])
    x = jnp.asarray(rng.normal(size=xshape), jnp.float32)
    w = jnp.asarray(rng.normal(size=kshape), jnp.float32)
    b = jnp.asarray(rng.normal(size=(kshape[3],)), jnp.float32)
    kh, kw, cin, cout = kshape
    sp = pair_rows_structured(
        np.asarray(w, np.float64).reshape(kh * kw * cin, cout), 0.0
    )
    assert sp.n_pairs == 0
    got = paired_conv(x, w, b, pairing=sp)
    want = _xla_conv(x, w, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("xshape,kshape", LENET_CASES)
def test_paired_conv_bounded_error_at_positive_rounding(xshape, kshape):
    """At r > 0: kernel == folded oracle ≤ 1e-5, and the deviation from the
    exact conv obeys the analytic bound 2·max|x|·P·√N·r (rms criterion)."""
    rounding = 0.1
    rng = np.random.default_rng(sum(kshape))
    x = jnp.asarray(rng.normal(size=xshape), jnp.float32)
    w_np, planted = _pairable_kernel(rng, kshape, rounding)
    w = jnp.asarray(w_np)
    kh, kw, cin, cout = kshape
    sp = pair_rows_structured(
        w_np.astype(np.float64).reshape(kh * kw * cin, cout), rounding
    )
    assert sp.n_pairs >= planted, "planted pairs must be found"

    got = np.asarray(paired_conv(x, w, None, pairing=sp))
    oracle = np.asarray(paired_conv_ref(x, w, None, sp))
    np.testing.assert_allclose(got, oracle, rtol=1e-4, atol=1e-4)

    exact = np.asarray(_xla_conv(x, w))
    err = np.abs(got - exact).max()
    bound = 2 * float(jnp.abs(x).max()) * sp.n_pairs * np.sqrt(cout) * rounding
    assert err <= bound, f"error {err:.3e} exceeds analytic bound {bound:.3e}"


def test_folded_conv_weight_matches_offline_fold():
    """Live-weight folding == StructuredPairing.fold() on the same weights."""
    rng = np.random.default_rng(3)
    kshape = (3, 3, 4, 8)
    w_np, _ = _pairable_kernel(rng, kshape, 0.2)
    wm = w_np.astype(np.float64).reshape(36, 8)
    sp = pair_rows_structured(wm, 0.2)
    live = np.asarray(folded_conv_weight(jnp.asarray(w_np), sp), np.float64)
    np.testing.assert_allclose(live.reshape(36, 8), sp.fold(), rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# conv_impl dispatch (explicit arg, policy, and under jax.grad)
# ---------------------------------------------------------------------------


def test_lenet_conv_impl_switch():
    params = init_lenet(jax.random.key(0))
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(2, 32, 32, 1)), jnp.float32)
    y_xla = lenet_apply(params, x)
    y_col = lenet_apply(params, x, conv_impl="im2col")
    np.testing.assert_allclose(np.asarray(y_col), np.asarray(y_xla), rtol=1e-5, atol=1e-5)
    arts = build_conv_pairings(params, 0.0)
    y_pal = lenet_apply(params, x, conv_impl="pallas_paired", paired=arts)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_xla), rtol=1e-4, atol=1e-5)
    with pytest.raises(ValueError, match="pairing artifacts"):
        lenet_apply(params, x, conv_impl="pallas_paired")


def test_lenet_conv_policy_dispatch():
    """The thread-local pallas_conv policy (what PerfKnobs(conv=...) installs
    via conv_context) must route lenet_apply without touching call sites."""
    params = init_lenet(jax.random.key(2))
    x = jnp.asarray(np.random.default_rng(2).normal(size=(2, 32, 32, 1)), jnp.float32)
    arts = build_conv_pairings(params, 0.0)
    want = lenet_apply(params, x, conv_impl="pallas_paired", paired=arts)
    with pallas_conv(paired=arts):
        got = lenet_apply(params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)

    class Knobs:
        conv = "im2col"
        block_m = block_n = block_k = 0

    with conv_context(Knobs()):
        got2 = lenet_apply(params, x)
    want2 = lenet_apply(params, x, conv_impl="im2col")
    np.testing.assert_allclose(np.asarray(got2), np.asarray(want2), rtol=1e-6, atol=1e-6)


def test_conv_impl_dispatch_under_grad():
    """All three conv_impl choices are differentiable; at rounding 0 their
    parameter gradients agree with the XLA reference path."""
    params = init_lenet(jax.random.key(4))
    x = jnp.asarray(np.random.default_rng(4).normal(size=(2, 32, 32, 1)), jnp.float32)
    arts0 = build_conv_pairings(params, 0.0)

    def loss(p, impl, paired=None):
        return (lenet_apply(p, x, conv_impl=impl, paired=paired) ** 2).mean()

    g_xla = jax.grad(loss)(params, "xla")
    g_col = jax.grad(loss)(params, "im2col")
    g_pal = jax.grad(loss)(params, "pallas_paired", arts0)
    for ref, got in ((g_xla, g_col), (g_xla, g_pal)):
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
            np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=1e-3, atol=1e-4)

    # policy form, under jit + grad (the serving/training route)
    with pallas_conv(paired=arts0):
        g_pol = jax.jit(jax.grad(lambda p: (lenet_apply(p, x) ** 2).mean()))(params)
    for a, b in zip(jax.tree.leaves(g_xla), jax.tree.leaves(g_pol)):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a), rtol=1e-3, atol=1e-4)

    # rounding > 0: grads flow through the frozen pairing structure
    arts = build_conv_pairings(params, 1.0)
    assert sum(a.n_pairs for a in arts.values()) > 0
    g_r = jax.grad(loss)(params, "pallas_paired", arts)
    leaves = jax.tree.leaves(g_r)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves)
    assert sum(float(jnp.abs(g).sum()) for g in leaves) > 0


def test_build_conv_pairings_artifacts():
    params = init_lenet(jax.random.key(5))
    arts = build_conv_pairings(params, 0.05, positions=LENET_CONV_POSITIONS)
    assert set(arts) == {"conv1", "conv2", "conv3"}
    total = sum(a.measured_op_counts()["baseline_lanes"] for a in arts.values())
    assert total == 405600, "kernel baseline lanes must equal the paper's multiplies"
    for a in arts.values():
        c = a.measured_op_counts()
        assert c["baseline_lanes"] - c["paired_lanes"] == c["lanes_saved"]
        assert c["subs_executed"] == a.n_pairs * a.positions
