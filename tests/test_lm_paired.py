"""Paired subtractor GEMMs on the LM decode path.

r=0 parity (≤1e-5 vs the XLA einsum path) for every paired decoder GEMM —
attention qkv, the out-projection (including its fused residual-add
epilogue) and the MLP up/gate/down — on a tiny fp32 decoder config, under
jit and jax.grad; at r > 0 the kernel matches the folded oracle and the
deviation from the exact GEMM obeys the analytic rms bound from
test_pairing.  Both pairing-spectrum endpoints are exercised: structured
(shared-row) and per-column (block_n=1) metadata.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.pairing import pair_rows_structured
from repro.core.transform import (
    LM_PAIRED_WEIGHTS,
    _stack_structured,
    has_lm_pairing,
    pair_lm_params,
)
from repro.kernels.ops import (
    fold_lm_weight,
    fused_paired_dense,
    pallas_paired_gemm,
    perf_context,
)
from repro.models import lm as M
from repro.models.param import unzip


@pytest.fixture(scope="module")
def tiny_lm():
    """fp32 qwen2-family smoke decoder + params (fp32: parity is exactness
    of the kernel path, not bf16 rounding noise)."""
    cfg = dataclasses.replace(get_smoke_config("qwen2-1.5b"), dtype="float32")
    params, _ = unzip(M.init_lm(cfg, jax.random.key(0)))
    return cfg, params


def _layer_weight_matrices(params):
    """{(sub, name): (K, N) jnp matrix} for layer 0 of segment 0."""
    seg = params["segments"][0]
    out = {}
    for sub, name in LM_PAIRED_WEIGHTS:
        if sub not in seg or name not in seg[sub]:
            continue
        w = jnp.asarray(seg[sub][name][0], jnp.float32)  # layer 0
        out[(sub, name)] = w.reshape(-1, w.shape[-1]) if name == "wo" else w.reshape(w.shape[0], -1)
    return out


def _structured_meta(w2: np.ndarray, rounding: float) -> dict:
    """Single-layer structured metadata in the stacked-artifact layout."""
    sp = pair_rows_structured(np.asarray(w2, np.float64), rounding)
    stacked = _stack_structured([sp])
    return {k: jnp.asarray(v[0]) for k, v in stacked.items()}


# ---------------------------------------------------------------------------
# GEMM-level r=0 parity: every paired decoder weight, jit + grad
# ---------------------------------------------------------------------------


def test_each_decoder_gemm_r0_parity(tiny_lm):
    """fused_paired_dense at rounding 0 == x @ W ≤ 1e-5 for qkv/wo/MLP."""
    _, params = tiny_lm
    mats = _layer_weight_matrices(params)
    assert len(mats) == 7, sorted(mats)  # wq wk wv wo + gate/up/down
    rng = np.random.default_rng(0)
    for (sub, name), w2 in mats.items():
        meta = _structured_meta(np.asarray(w2), 0.0)
        x = jnp.asarray(rng.normal(size=(3, w2.shape[0])), jnp.float32)
        got = np.asarray(fused_paired_dense(x, w2, meta, block_m=8, block_n=8))
        want = np.asarray(x @ w2)
        rel = np.abs(got - want).max() / max(np.abs(want).max(), 1e-30)
        assert rel <= 1e-5, f"{sub}.{name}: rel err {rel:.2e}"


def test_fused_paired_dense_under_jit_and_grad(tiny_lm):
    """jit(fused_paired_dense) and its custom VJP match the XLA dense path
    at rounding 0 (the folded equivalent IS the original weight there)."""
    _, params = tiny_lm
    w2 = _layer_weight_matrices(params)[("mlp", "w_down")]
    meta = _structured_meta(np.asarray(w2), 0.0)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(2, 4, w2.shape[0])), jnp.float32)
    res = jnp.asarray(rng.normal(size=(2, 4, w2.shape[1])), jnp.float32)

    got = jax.jit(
        lambda x, w: fused_paired_dense(
            x, w, meta, activation="silu", residual=res, block_m=8, block_n=8
        )
    )(x, w2)
    want = jax.nn.silu(jnp.einsum("...d,df->...f", x, w2)) + res
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)

    def loss(w, kernel):
        if kernel:
            y = fused_paired_dense(x, w, meta, activation="silu",
                                   residual=res, block_m=8, block_n=8)
        else:
            y = jax.nn.silu(jnp.einsum("...d,df->...f", x, w)) + res
        return (y * y).sum()

    g_k = jax.grad(loss)(w2, True)
    g_x = jax.grad(loss)(w2, False)
    np.testing.assert_allclose(np.asarray(g_k), np.asarray(g_x),
                               rtol=1e-4, atol=1e-4)


def test_fused_residual_epilogue_vs_explicit_add(tiny_lm):
    """The residual-add epilogue == the explicit x @ W + res schedule."""
    _, params = tiny_lm
    w2 = _layer_weight_matrices(params)[("attn", "wo")]
    meta = _structured_meta(np.asarray(w2), 0.0)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(5, w2.shape[0])), jnp.float32)
    res = jnp.asarray(rng.normal(size=(5, w2.shape[1])), jnp.float32)
    fused = fused_paired_dense(x, w2, meta, residual=res, block_m=8, block_n=8)
    explicit = fused_paired_dense(x, w2, meta, block_m=8, block_n=8) + res
    np.testing.assert_allclose(np.asarray(fused), np.asarray(explicit),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# r > 0: folded-oracle parity + the analytic rms error bound
# ---------------------------------------------------------------------------


def _pairable_matrix(rng, K, N, rounding):
    """Rows K/2.. ≈ −rows ..K/2 with sub-rounding noise → pairs are found."""
    half = rng.normal(size=(K // 2, N)) + 1.5
    noise = rng.normal(size=(K // 2, N)) * (rounding * 0.1)
    return np.concatenate([half, -half + noise])


@pytest.mark.parametrize("mode,block_n", [("structured", 0), ("per_column", 1)])
def test_positive_rounding_holds_rms_bound(mode, block_n):
    """At r > 0: kernel == folded oracle ≤ 1e-4, and the deviation from the
    exact GEMM obeys 2·max|x|·P·√N·r (the test_pairing rms bound, lifted
    through the contraction)."""
    rounding = 0.1
    K, N = 32, 12
    rng = np.random.default_rng(3)
    W = _pairable_matrix(rng, K, N, rounding)
    w2 = jnp.asarray(W, jnp.float32)
    x = jnp.asarray(rng.normal(size=(6, K)), jnp.float32)

    if mode == "structured":
        meta = _structured_meta(W, rounding)
        n_pairs = int(meta["pair_mask"].sum())
        got = fused_paired_dense(x, w2, meta, block_m=8, block_n=8)
        wf = fold_lm_weight(w2, meta)
    else:
        fake = {"segments": [{"mlp": {"w_down": W[None]}}]}
        pm, rep = pair_lm_params(fake, rounding, mode="per_column")
        meta = {k: jnp.asarray(v[0])
                for k, v in pm["segments"][0]["mlp"]["w_down_pairing"].items()}
        n_pairs = rep.total_pairs // N  # weighted → per-column average ≥ 1
        got = fused_paired_dense(x, w2, meta, pair_block_n=1, block_m=8)
        wf = fold_lm_weight(w2, meta, pair_block_n=1)
    assert n_pairs > 0, "want a nontrivial pairing for this test"

    oracle = jnp.einsum("...d,df->...f", x, wf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(oracle),
                               rtol=1e-4, atol=1e-4)
    exact = np.asarray(x @ w2)
    err = np.abs(np.asarray(got) - exact).max()
    bound = 2 * float(jnp.abs(x).max()) * (K // 2) * np.sqrt(N) * rounding
    assert err <= bound, f"error {err:.3e} exceeds analytic bound {bound:.3e}"


# ---------------------------------------------------------------------------
# model-level: lm_forward / lm_loss under the policy, structured + blocked
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,block_n", [("structured", 0), ("per_column", 1)])
def test_lm_forward_r0_parity(tiny_lm, mode, block_n):
    """Full decoder forward through the paired kernel at rounding 0 matches
    the XLA path ≤ 1e-5 (jit'd, both pairing-spectrum endpoints)."""
    cfg, params = tiny_lm
    knobs = M.PerfKnobs(q_chunk=16, k_chunk=16, remat="none",
                        gemm="pallas_paired", pair_block_n=block_n)
    pm, rep = pair_lm_params(params, 0.0, mode=mode, block_n=block_n)
    assert has_lm_pairing(pm) and not has_lm_pairing(params)
    assert len(rep.leaves) == 7

    batch = {"tokens": jnp.asarray(
        np.random.default_rng(4).integers(0, cfg.vocab, (2, 8)), jnp.int32)}
    want, _, _ = M.lm_forward(cfg, params, batch, knobs=M.PerfKnobs(
        q_chunk=16, k_chunk=16, remat="none"))
    with perf_context(knobs):
        got, _, _ = jax.jit(
            lambda p: M.lm_forward(cfg, p, batch, knobs=knobs)
        )(pm)
    rel = float(jnp.abs(got - want).max() / jnp.abs(want).max())
    assert rel <= 1e-5, f"{mode}: rel err {rel:.2e}"


def test_lm_loss_grad_r0_parity(tiny_lm):
    """jax.grad through lm_loss under the paired policy (scan + custom VJP):
    weight gradients match the XLA path — the artifact survives training."""
    cfg, params = tiny_lm
    pm, _ = pair_lm_params(params, 0.0)
    rng = np.random.default_rng(5)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (1, 6)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (1, 6)), jnp.int32),
    }
    base = M.PerfKnobs(q_chunk=8, k_chunk=8, remat="none", xent_chunk=0)
    knobs = dataclasses.replace(base, gemm="pallas_paired")

    def loss_xla(p):
        return M.lm_loss(cfg, p, batch, knobs=base)[0]

    def loss_paired(p):
        with pallas_paired_gemm():
            return M.lm_loss(cfg, p, batch, knobs=knobs)[0]

    g_ref = jax.grad(loss_xla)(params)
    # allow_int: the pairing metadata (int32 lane indices) rides inside the
    # param tree; its cotangents are float0 (the structure is frozen)
    g_got = jax.grad(loss_paired, allow_int=True)(pm)
    for sub, name in LM_PAIRED_WEIGHTS:
        ref = np.asarray(g_ref["segments"][0][sub][name])
        got = np.asarray(g_got["segments"][0][sub][name])
        np.testing.assert_allclose(
            got, ref, rtol=1e-4, atol=1e-5,
            err_msg=f"grad mismatch on segments[0].{sub}.{name}",
        )


def test_decode_step_r0_parity(tiny_lm):
    """prefill → decode_step through the paired kernel == XLA, per logit."""
    cfg, params = tiny_lm
    pm, _ = pair_lm_params(params, 0.0)
    base = M.PerfKnobs(q_chunk=16, k_chunk=16, remat="none")
    knobs = dataclasses.replace(base, gemm="pallas_paired")
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(6).integers(0, cfg.vocab, (2, 7)), jnp.int32)}
    tok = jnp.asarray([[3], [9]], jnp.int32)
    pos = jnp.asarray([7, 7], jnp.int32)

    _, cache = M.prefill(cfg, params, batch, knobs=base)
    want, _ = M.decode_step(cfg, params, cache, tok, pos)
    with perf_context(knobs):
        _, cache_p = M.prefill(cfg, pm, batch, knobs=knobs)
        got, _ = jax.jit(
            lambda p, c: M.decode_step(cfg, p, c, tok, pos)
        )(pm, cache_p)
    rel = float(jnp.abs(got - want).max() / jnp.abs(want).max())
    assert rel <= 1e-5, f"decode rel err {rel:.2e}"
