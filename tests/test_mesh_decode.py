"""Mesh-wired decode cell: engine parity and metadata placement plumbing.

The test suite runs single-device, so the mesh here is (1, n) — the full 2×4
multi-device parity + ledger gate lives in ``benchmarks/mesh_decode.py`` and
the ``sharded_decode`` analysis target (CI's mesh-decode job runs both under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).  What *is* real at
any mesh size: the wiring path (tp_shard_plan → pair_params(shards=…) →
pairing_axes → paired_shardings_for → pjit), and that it decodes the same
tokens as the single-host engine.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import lm as M
from repro.models.param import unzip
from repro.parallel.sharding import make_mesh_compat
from repro.serving.engine import ServeEngine

KNOBS = M.PerfKnobs(
    q_chunk=16, k_chunk=16, remat="none",
    gemm="pallas_paired", pair_block_n=1, pair_rounding=0.0,
)


@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(get_smoke_config("qwen2-1.5b"), dtype="float32")
    params, _ = unzip(M.init_lm(cfg, jax.random.key(0)))
    return cfg, params


def _mesh():
    return make_mesh_compat((1, jax.device_count()), ("data", "model"))


def test_mesh_engine_token_parity_r0(tiny):
    cfg, params = tiny
    prompts = {0: np.arange(1, 8, dtype=np.int32)}
    ref = ServeEngine(cfg, params, max_seq=24, batch_size=2, knobs=KNOBS)
    eng = ServeEngine(
        cfg, params, max_seq=24, batch_size=2, knobs=KNOBS, mesh=_mesh()
    )
    out_ref = ref.generate(dict(prompts), 5)
    out_mesh = eng.generate(dict(prompts), 5)
    assert out_ref[0] == out_mesh[0]


def test_wire_serve_cell_pairs_and_places(tiny):
    from repro.launch.steps import wire_serve_cell

    cfg, params = tiny
    cell = wire_serve_cell(
        cfg, params, _mesh(), batch_size=2, max_seq=24, knobs=KNOBS
    )
    assert cell.pair_report is not None
    # every paired leaf carries its shard provenance in the report
    assert len(cell.pair_report.leaves) == len(cfg.paired_leaves)
    seg = cell.params["segments"][0]
    assert "wq_pairing" in seg["attn"]
    # metadata sharding mirrors the weight's resolved spec: the wq block
    # axis rides on `model` (size n divides the smoke head dims)
    wq_spec = cell.p_shard["segments"][0]["attn"]["wq"].spec
    meta_spec = cell.p_shard["segments"][0]["attn"]["wq_pairing"]["I"].spec
    assert meta_spec[1] == wq_spec[2]
    # params were device_put against those shardings
    assert jax.tree.leaves(cell.params)[0].committed


def test_mesh_engine_add_release_cycle(tiny):
    """Slot lifecycle works on sharded cache arrays (splice/scrub paths)."""
    cfg, params = tiny
    eng = ServeEngine(
        cfg, params, max_seq=24, batch_size=2, knobs=KNOBS, mesh=_mesh()
    )
    eng.add_request(0, np.arange(1, 6, dtype=np.int32))
    eng.step()
    eng.release_slot(0)
    assert eng.free_slots() == [0, 1]
    eng.add_request(0, np.arange(1, 4, dtype=np.int32))
    eng.step()
