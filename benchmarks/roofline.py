"""Roofline assembly: reads the dry-run JSONs and produces the §Roofline
table — the three terms (compute / memory / collective, seconds per step,
per chip), the dominant bound, and the useful-compute ratio, for every
(arch × shape) on the single-pod mesh (per the task spec; multi-pod cells
prove the pod axis shards and are listed in §Dry-run).

Also sweeps ``block_k`` for the K-tiled paired GEMM kernel
(kernels/paired_matmul.py): for each representative (M, N, K, pair-rate)
shape it validates every tile config against the jnp oracle in interpret
mode, records the estimated per-program VMEM working set and analytic HBM
traffic, and marks the tuning heuristic's pick — the data the heuristic in
kernels/tuning.py is judged against.

    PYTHONPATH=src python -m benchmarks.roofline
"""
from __future__ import annotations

import json
import time
import zlib
from pathlib import Path

from repro.core.cost_model import TPU_V5E

from benchmarks.common import fmt_table, write_result

DRYRUN_DIR = Path(__file__).parent / "results" / "dryrun"

# (label, M, N, K, pair_fraction): pair_fraction of K lanes pair off in I/J
# halves; the rest stay residual.  Shapes follow the workloads the configs
# directory names (decode row, LeNet-ish conv-as-GEMM, d_model-scale FFN).
KERNEL_SWEEP_SHAPES = [
    ("decode_row", 8, 512, 4096, 0.5),
    ("conv_gemm", 256, 120, 400, 0.4),
    ("ffn_proj", 128, 1024, 8192, 0.25),
]
BLOCK_KS = [128, 256, 512, 1024]


def load_cells(mesh: str = "pod16x16", tag: str = "") -> list[dict]:
    cells = []
    for p in sorted(DRYRUN_DIR.glob(f"*__{mesh}{'__' + tag if tag else ''}.json")):
        d = json.loads(p.read_text())
        if tag == "" and d.get("cell", "").count("__") > 2:
            continue  # skip tagged (perf-experiment) results in the baseline table
        cells.append(d)
    return cells


def roofline_row(d: dict) -> dict:
    if d.get("status") == "skipped":
        return {
            "arch": d["cell"].split("__")[0],
            "shape": d["cell"].split("__")[1],
            "bound": "skipped",
            "note": d["reason"][:40],
        }
    if d.get("status") != "ok":
        return {
            "arch": d["cell"].split("__")[0],
            "shape": d["cell"].split("__")[1],
            "bound": "FAILED",
            "note": d.get("error", "")[:40],
        }
    terms = TPU_V5E.terms(
        d["cost"]["flops"], d["cost"]["bytes_accessed"], d["collectives"]["total_bytes"]
    )
    return {
        "arch": d["arch"],
        "shape": d["shape"],
        "t_compute_s": terms["t_compute_s"],
        "t_memory_s": terms["t_memory_s"],
        "t_collective_s": terms["t_collective_s"],
        "bound": terms["bound"],
        "useful": d["model"].get("useful_flops_ratio", 0.0),
        "hbm_GiB": d["memory"]["peak_device_bytes"] / 2**30,
        "fits": "Y" if d["memory"]["peak_device_bytes"] < 16 * 2**30 else "OVER",
    }


def kernel_block_sweep(quick: bool = False) -> list[dict]:
    """Sweep block_k for the paired GEMM; validate each config vs the oracle.

    Runs in interpret mode (this container has no TPU), so the timing column
    is *not* hardware time — the actionable outputs are correctness, the
    VMEM working-set estimate per tile config, and the analytic HBM traffic
    (streamed tiles per output block), which is what distinguishes tile
    configs on hardware.
    """
    import numpy as np
    import jax.numpy as jnp

    from repro.kernels.paired_matmul import paired_matmul_pallas
    from repro.kernels.ref import paired_matmul_ref
    from repro.kernels.tuning import choose_blocks, kernel_vmem_bytes

    rows = []
    shapes = KERNEL_SWEEP_SHAPES[:2] if quick else KERNEL_SWEEP_SHAPES
    block_ks = BLOCK_KS[:2] if quick else BLOCK_KS
    for label, M, N, K, frac in shapes:
        P = int(K * frac / 2)
        R = K - 2 * P
        rng = np.random.default_rng(zlib.crc32(label.encode()))
        x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
        kmat = jnp.asarray(rng.normal(size=(P, N)), jnp.float32)
        w_res = jnp.asarray(rng.normal(size=(R, N)), jnp.float32)
        want = np.asarray(paired_matmul_ref(x, kmat, w_res))
        scale = np.abs(want).max()
        pick = choose_blocks(M, N, P, R, dtype_bytes=4)
        # always sweep the heuristic's own pick, or the marked config would
        # be the one config the sweep never validates
        for bk in sorted(set(block_ks) | {pick.block_k}):
            bm, bn = min(128, M), min(128, N)
            t0 = time.perf_counter()
            got = np.asarray(
                paired_matmul_pallas(
                    x, kmat, w_res,
                    block_m=bm, block_n=bn, block_k=bk, interpret=True,
                )
            )
            dt = time.perf_counter() - t0
            err = float(np.abs(got - want).max() / scale)
            # analytic HBM traffic: every output tile streams its full
            # paired + residual K once (x tiles + weight tiles) + writeback
            n_tiles = -(-M // bm) * (-(-N // bn))
            stream = (2 * bm * P + P * bn + bm * R + R * bn) * 4
            hbm = n_tiles * stream + M * N * 4
            rows.append(
                {
                    "shape": label,
                    "MNK": f"{M}x{N}x{K}",
                    "pairs": P,
                    "block_k": bk,
                    "rel_err": err,
                    "vmem_KiB": kernel_vmem_bytes(
                        bm, bn, min(bk, max(P, R, 1)),
                        dtype_bytes=4, has_pairs=P > 0, has_resid=R > 0,
                    ) / 1024,
                    "hbm_MiB": hbm / 2**20,
                    "interp_s": dt,
                    "heuristic": "<<" if bk == pick.block_k else "",
                    "tile": f"{bm}x{bn}x{bk}",
                }
            )
            assert err <= 1e-5, f"{label} block_k={bk}: rel err {err:.2e}"
    return rows


def run(quick: bool = False) -> dict:
    sweep = kernel_block_sweep(quick)
    cols = ["shape", "MNK", "pairs", "block_k", "rel_err", "vmem_KiB",
            "hbm_MiB", "interp_s", "heuristic"]
    print(fmt_table(sweep, cols, "Paired-GEMM block_k sweep (interpret mode)"))

    cells = load_cells()
    rows = []
    if not cells:
        print("[roofline] no dry-run results found — run repro.launch.dryrun "
              "for the arch x shape table")
    else:
        rows = [roofline_row(d) for d in cells]
        cols = ["arch", "shape", "t_compute_s", "t_memory_s", "t_collective_s",
                "bound", "useful", "hbm_GiB", "fits"]
        print(fmt_table(rows, cols, "Roofline (single-pod 16x16, per chip per step)"))
        n_over = sum(1 for r in rows if r.get("fits") == "OVER")
        n_fail = sum(1 for r in rows if r.get("bound") == "FAILED")
        print(f"[roofline] {len(rows)} cells; {n_fail} failed; {n_over} over-HBM")
    out = {"rows": rows, "kernel_block_sweep": sweep}
    write_result("roofline", out)
    return out


if __name__ == "__main__":
    run()
