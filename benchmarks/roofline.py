"""Roofline assembly: reads the dry-run JSONs and produces the §Roofline
table — the three terms (compute / memory / collective, seconds per step,
per chip), the dominant bound, and the useful-compute ratio, for every
(arch × shape) on the single-pod mesh (per the task spec; multi-pod cells
prove the pod axis shards and are listed in §Dry-run).

Also **autotunes** the K-tiled paired GEMM kernel
(kernels/paired_matmul.py): for each representative (M, N, K, pair-rate,
pool) shape the measured search in ``kernels.tuning.autotune_blocks`` times
every VMEM-feasible tile config, validates each against the jnp oracle, and
persists the winner into the on-disk :class:`~repro.kernels.tuning.TileCache`
(``.cache/tile_cache.json``) that ``choose_blocks`` consults at trace time —
this sweep is what turns the static VMEM heuristic into measured tile
selection.  The table marks both the heuristic's pick and the measured
winner so the gap between them stays visible.

    PYTHONPATH=src python -m benchmarks.roofline
"""
from __future__ import annotations

import json
import zlib
from pathlib import Path

from repro.core.cost_model import TPU_V5E

from benchmarks.common import fmt_table, write_result

DRYRUN_DIR = Path(__file__).parent / "results" / "dryrun"

# (label, M, N, K, pair_fraction, pool): pair_fraction of K lanes pair off
# in I/J halves; the rest stay residual.  Shapes follow the workloads the
# configs directory names (decode row, LeNet-ish conv-as-GEMM, d_model-scale
# FFN) plus the fused conv→pool megakernel (window-major M counts *pooled*
# rows).
KERNEL_SWEEP_SHAPES = [
    ("decode_row", 8, 512, 4096, 0.5, "none"),
    ("conv_gemm", 256, 120, 400, 0.4, "none"),
    ("conv_pool_gemm", 196, 16, 150, 0.4, "max2"),
    ("ffn_proj", 128, 1024, 8192, 0.25, "none"),
]


def load_cells(mesh: str = "pod16x16", tag: str = "") -> list[dict]:
    cells = []
    for p in sorted(DRYRUN_DIR.glob(f"*__{mesh}{'__' + tag if tag else ''}.json")):
        d = json.loads(p.read_text())
        if tag == "" and d.get("cell", "").count("__") > 2:
            continue  # skip tagged (perf-experiment) results in the baseline table
        cells.append(d)
    return cells


def roofline_row(d: dict) -> dict:
    if d.get("status") == "skipped":
        return {
            "arch": d["cell"].split("__")[0],
            "shape": d["cell"].split("__")[1],
            "bound": "skipped",
            "note": d["reason"][:40],
        }
    if d.get("status") != "ok":
        return {
            "arch": d["cell"].split("__")[0],
            "shape": d["cell"].split("__")[1],
            "bound": "FAILED",
            "note": d.get("error", "")[:40],
        }
    terms = TPU_V5E.terms(
        d["cost"]["flops"], d["cost"]["bytes_accessed"], d["collectives"]["total_bytes"]
    )
    return {
        "arch": d["arch"],
        "shape": d["shape"],
        "t_compute_s": terms["t_compute_s"],
        "t_memory_s": terms["t_memory_s"],
        "t_collective_s": terms["t_collective_s"],
        "bound": terms["bound"],
        "useful": d["model"].get("useful_flops_ratio", 0.0),
        "hbm_GiB": d["memory"]["peak_device_bytes"] / 2**30,
        "fits": "Y" if d["memory"]["peak_device_bytes"] < 16 * 2**30 else "OVER",
    }


def kernel_block_sweep(quick: bool = False) -> tuple[list[dict], dict]:
    """Autotune the paired GEMM per sweep shape; persist winners to the cache.

    For every (M, N, K, pair-rate, pool) shape the measured search times each
    VMEM-feasible tile candidate (``kernels.tuning.autotune_blocks``) and
    validates it against the jnp oracle.  Winners are written through to the
    on-disk TileCache, so subsequent traces under ``PerfKnobs(tile_cache=…)``
    (or this same process) take the measured pick over the heuristic.

    Runs in interpret mode in this container, so the timing column is *not*
    hardware time — the search/persist/consult mechanism is what is
    exercised end to end; on a TPU the same sweep yields hardware winners.
    Returns (table rows, autotune summary incl. the cache path).
    """
    import numpy as np
    import jax.numpy as jnp

    from repro.kernels import tuning
    from repro.kernels.paired_matmul import POOLS, paired_matmul_pallas
    from repro.kernels.ref import paired_matmul_ref

    cache = tuning.TileCache()  # .cache/tile_cache.json (versioned)
    rows = []
    winners = {}
    shapes = KERNEL_SWEEP_SHAPES[:3] if quick else KERNEL_SWEEP_SHAPES
    reps = 1 if quick else 3
    for label, M, N, K, frac, pool in shapes:
        P = int(K * frac / 2)
        R = K - 2 * P
        rng = np.random.default_rng(zlib.crc32(label.encode()))
        xshape = (4, M, K) if pool != "none" else (M, K)
        x = jnp.asarray(rng.normal(size=xshape), jnp.float32)
        kmat = jnp.asarray(rng.normal(size=(P, N)), jnp.float32)
        w_res = jnp.asarray(rng.normal(size=(R, N)), jnp.float32)
        if pool == "none":
            want = np.asarray(paired_matmul_ref(x, kmat, w_res))
        else:
            per_w = [paired_matmul_ref(x[w], kmat, w_res) for w in range(4)]
            want = np.asarray(POOLS[pool](jnp.stack(per_w)))
        scale = np.abs(want).max()
        pick = tuning.choose_blocks(
            M, N, P, R, dtype_bytes=4, pool=pool, use_cache=False
        )

        def runner(cfg, x=x, kmat=kmat, w_res=w_res, pool=pool):
            return paired_matmul_pallas(
                x, kmat, w_res,
                block_m=cfg.block_m, block_n=cfg.block_n,
                block_k=cfg.block_k, pool=pool, interpret=True,
            )

        cands = tuning.candidate_configs(M, N, P, R, dtype_bytes=4, pool=pool)
        if quick:
            cands = cands[:3] + ([pick] if pick not in cands[:3] else [])
        # validate every candidate against the oracle before timing it —
        # a fast-but-wrong tile config must never win.  The validation run
        # is also the warmup, so autotune_blocks itself runs warmup=0 and
        # each candidate executes reps+1 times total, not reps+warmup+1.
        for cfg in cands:
            got = np.asarray(runner(cfg))
            err = float(np.abs(got - want).max() / scale)
            assert err <= 1e-5, f"{label} {cfg}: rel err {err:.2e}"
        best, records = tuning.autotune_blocks(
            runner, M, N, P, R,
            dtype_bytes=4, dtype="float32", pool=pool,
            cache=cache, candidates=cands, reps=reps, warmup=0,
        )
        winners[label] = {
            "MNK": f"{M}x{N}x{K}", "pairs": P, "pool": pool,
            "winner": best.as_dict(),
            "heuristic": pick.as_dict(),
            "heuristic_matches": best == pick,
        }
        for rec in records:
            cfg = tuning.TileConfig(
                rec["block_m"], rec["block_n"], rec["block_k"]
            )
            rows.append(
                {
                    "shape": label,
                    "MNK": f"{M}x{N}x{K}",
                    "pairs": P,
                    "pool": pool,
                    "tile": f"{cfg.block_m}x{cfg.block_n}x{cfg.block_k}",
                    "vmem_KiB": rec["vmem_bytes"] / 1024,
                    "interp_s": rec["time_s"],
                    "heuristic": "<<" if cfg == pick else "",
                    "measured": "**" if cfg == best else "",
                }
            )
    path = str(cache.save())
    return rows, {"cache_path": path, "entries": len(cache), "winners": winners}


def run(quick: bool = False) -> dict:
    sweep, autotune = kernel_block_sweep(quick)
    cols = ["shape", "MNK", "pairs", "pool", "tile", "vmem_KiB",
            "interp_s", "heuristic", "measured"]
    print(fmt_table(
        sweep, cols,
        "Paired-GEMM tile autotune (interpret mode; << heuristic, ** winner)",
    ))
    print(
        f"[roofline] tile cache: {autotune['entries']} measured winners → "
        f"{autotune['cache_path']}"
    )

    cells = load_cells()
    rows = []
    if not cells:
        print("[roofline] no dry-run results found — run repro.launch.dryrun "
              "for the arch x shape table")
    else:
        rows = [roofline_row(d) for d in cells]
        cols = ["arch", "shape", "t_compute_s", "t_memory_s", "t_collective_s",
                "bound", "useful", "hbm_GiB", "fits"]
        print(fmt_table(rows, cols, "Roofline (single-pod 16x16, per chip per step)"))
        n_over = sum(1 for r in rows if r.get("fits") == "OVER")
        n_fail = sum(1 for r in rows if r.get("bound") == "FAILED")
        print(f"[roofline] {len(rows)} cells; {n_fail} failed; {n_over} over-HBM")
    out = {
        "rows": rows,
        "kernel_block_sweep": sweep,
        "kernel_autotune": autotune,
        "perf_summary": {"kernel_autotune": autotune},
    }
    write_result("roofline", out)
    return out


if __name__ == "__main__":
    run()
