"""Roofline assembly: reads the dry-run JSONs and produces the §Roofline
table — the three terms (compute / memory / collective, seconds per step,
per chip), the dominant bound, and the useful-compute ratio, for every
(arch × shape) on the single-pod mesh (per the task spec; multi-pod cells
prove the pod axis shards and are listed in §Dry-run).

    PYTHONPATH=src python -m benchmarks.roofline
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.core.cost_model import TPU_V5E

from benchmarks.common import fmt_table, write_result

DRYRUN_DIR = Path(__file__).parent / "results" / "dryrun"


def load_cells(mesh: str = "pod16x16", tag: str = "") -> list[dict]:
    cells = []
    for p in sorted(DRYRUN_DIR.glob(f"*__{mesh}{'__' + tag if tag else ''}.json")):
        d = json.loads(p.read_text())
        if tag == "" and d.get("cell", "").count("__") > 2:
            continue  # skip tagged (perf-experiment) results in the baseline table
        cells.append(d)
    return cells


def roofline_row(d: dict) -> dict:
    if d.get("status") == "skipped":
        return {
            "arch": d["cell"].split("__")[0],
            "shape": d["cell"].split("__")[1],
            "bound": "skipped",
            "note": d["reason"][:40],
        }
    if d.get("status") != "ok":
        return {
            "arch": d["cell"].split("__")[0],
            "shape": d["cell"].split("__")[1],
            "bound": "FAILED",
            "note": d.get("error", "")[:40],
        }
    terms = TPU_V5E.terms(
        d["cost"]["flops"], d["cost"]["bytes_accessed"], d["collectives"]["total_bytes"]
    )
    return {
        "arch": d["arch"],
        "shape": d["shape"],
        "t_compute_s": terms["t_compute_s"],
        "t_memory_s": terms["t_memory_s"],
        "t_collective_s": terms["t_collective_s"],
        "bound": terms["bound"],
        "useful": d["model"].get("useful_flops_ratio", 0.0),
        "hbm_GiB": d["memory"]["peak_device_bytes"] / 2**30,
        "fits": "Y" if d["memory"]["peak_device_bytes"] < 16 * 2**30 else "OVER",
    }


def run(quick: bool = False) -> dict:
    cells = load_cells()
    if not cells:
        print("[roofline] no dry-run results found — run repro.launch.dryrun first")
        return {"rows": []}
    rows = [roofline_row(d) for d in cells]
    cols = ["arch", "shape", "t_compute_s", "t_memory_s", "t_collective_s",
            "bound", "useful", "hbm_GiB", "fits"]
    print(fmt_table(rows, cols, "Roofline (single-pod 16x16, per chip per step)"))
    n_over = sum(1 for r in rows if r.get("fits") == "OVER")
    n_fail = sum(1 for r in rows if r.get("bound") == "FAILED")
    print(f"[roofline] {len(rows)} cells; {n_fail} failed; {n_over} over-HBM")
    out = {"rows": rows}
    write_result("roofline", out)
    return out


if __name__ == "__main__":
    run()
