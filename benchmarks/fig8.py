"""Fig. 8 reproduction: power/area saving vs accuracy per rounding size.

For each rounding size: pair the conv weights per filter (Algorithm 1),
snap pairs to the common magnitude (``fold``), evaluate test accuracy with
the folded weights (bit-identical to the subtractor dataflow), and price the
op mix with the calibrated 65 nm ASIC model.  Also dumps the weight
distribution histogram of conv3 (paper Figs. 3/4).

Paper headline @ rounding 0.05: 32.03 % power, 24.59 % area, 0.1 % accuracy
loss.  The savings are functions of the *op counts*, so our savings differ
only insofar as our trained weights pair at different rates.
"""
from __future__ import annotations

import numpy as np

from repro.core.cost_model import AsicCostModel, OpCounts
from repro.core.pairing import column_pairing_for_conv, fold_columns, pairing_op_counts
from repro.core.transform import build_conv_pairings
from repro.kernels.tuning import choose_blocks, measure
from repro.models.lenet import (
    LENET_CONV_POSITIONS,
    LENET_CONV_SHAPES,
    lenet_accuracy,
    lenet_apply,
)
from repro.train.lenet_trainer import get_trained_lenet

from repro.analysis import RuleContext, run_rules

from benchmarks.common import fmt_table, write_result

ROUNDINGS = [0.0, 0.0001, 0.005, 0.01, 0.015, 0.02, 0.025, 0.05, 0.1, 0.15, 0.2, 0.25, 0.3]
LM_HEADLINE_ROUNDING = 0.05  # the paper's headline point, applied to the LM


def paired_lenet(params, rounding: float):
    """Fold conv weights at the given rounding; return (params', op ledger)."""
    import jax

    new = jax.tree.map(lambda x: x, params)  # shallow copy of the tree
    mults = adds = subs = 0
    for name, (shape, pos) in LENET_CONV_SHAPES.items():
        k = np.asarray(params[name]["w"], dtype=np.float64)
        H, W, Cin, Cout = k.shape
        cp = column_pairing_for_conv(k, rounding)
        folded = fold_columns(k.reshape(H * W * Cin, Cout), cp).reshape(k.shape)
        new[name] = dict(new[name])
        new[name]["w"] = folded.astype(np.float32)
        c = pairing_op_counts(k.size, cp.total_pairs, pos)
        mults += c["mults"]
        adds += c["adds"]
        subs += c["subs"]
    return new, OpCounts(mults=mults, adds=adds, subs=subs)


def measured_conv_path(
    params,
    test_x,
    rounding: float,
    batch: int = 32,
    mode: str = "structured",
    block_n: int = 0,
) -> dict:
    """Execute LeNet through the paired Pallas conv path and *measure* it.

    Unlike the analytic ledger above (per-column Algorithm 1, modeled), this
    builds the per-conv-layer artifacts the kernel actually consumes
    (``mode``/``block_n`` pick the pairing-spectrum point: structured,
    column-blocked, or per-column at ``block_n=1``), runs the forward, and
    reports the op counts the kernel executed: per layer, baseline MXU lanes
    (== the paper's multiply count), lanes after pairing, and VPU subtracts
    per image — plus the max output deviation from the XLA conv reference on
    a real test batch.
    """
    import jax.numpy as jnp

    arts = build_conv_pairings(
        params, rounding, positions=LENET_CONV_POSITIONS,
        mode=mode, block_n=block_n,
    )
    xb = jnp.asarray(test_x[:batch], jnp.float32)
    y_ref = np.asarray(lenet_apply(params, xb, conv_impl="xla"))
    y_pal = np.asarray(
        lenet_apply(params, xb, conv_impl="pallas_paired", paired=arts)
    )
    per_layer = {}
    for name, art in arts.items():
        kh, kw, cin, cout = art.kernel_shape
        per_layer[name] = {
            "K": kh * kw * cin,
            "N": cout,
            "positions": art.positions,
            "n_pairs": art.n_pairs,
            **art.measured_op_counts(),
        }
    total_baseline = sum(v["baseline_lanes"] for v in per_layer.values())
    assert total_baseline == 405600, (
        f"kernel baseline lanes {total_baseline} != paper's 405600 multiplies"
    )
    max_abs = float(np.abs(y_pal - y_ref).max())
    return {
        "rounding": rounding,
        "batch": batch,
        "mode": mode,
        "block_n": block_n,
        "per_layer": per_layer,
        "total_baseline_lanes": total_baseline,
        "total_paired_lanes": sum(v["paired_lanes"] for v in per_layer.values()),
        "total_subs_per_image": sum(v["subs_executed"] for v in per_layer.values()),
        "max_abs_err_vs_xla": max_abs,
        # relative to the logit scale — the CI-stable gate (absolute fp32
        # error grows with batch/accumulation order; relative does not)
        "rel_err_vs_xla": max_abs / max(float(np.abs(y_ref).max()), 1e-30),
    }


def pairing_block_sweep(params, rounding: float, block_ns=None) -> dict:
    """Pairing rate vs block size at one rounding — the spectrum the
    column-blocked kernel opens between structured and per-column pairing.

    For each ``block_n`` (1 == per-column, growing toward structured) the
    conv artifacts are rebuilt and the executed pairing rate recorded:
    ``lanes_saved / baseline_lanes`` (the fraction of MXU lanes the paper's
    subtractor trick removes) plus the VPU subtracts per image the blocked
    kernel pays for it.  ``structured`` is the ∞-block endpoint.
    """
    from repro.core.pairing import pair_columns

    if block_ns is None:
        block_ns = (1, 2, 4, 8, 16)
    points = {}

    def record(tag, arts):
        counts = [a.measured_op_counts() for a in arts.values()]
        baseline = sum(c["baseline_lanes"] for c in counts)
        saved = sum(c["lanes_saved"] for c in counts)
        points[tag] = {
            "lanes_saved": saved,
            "pair_rate": saved / baseline,
            "subs_per_image": sum(c["subs_executed"] for c in counts),
        }

    record("structured", build_conv_pairings(
        params, rounding, positions=LENET_CONV_POSITIONS))
    for bn in block_ns:
        record(f"block_{bn}", build_conv_pairings(
            params, rounding, positions=LENET_CONV_POSITIONS,
            mode="column_blocked", block_n=bn,
        ))

    # the analytic (non-executable reference) per-column rate for comparison
    analytic_pairs = 0
    baseline = 0
    for name, (shape, pos) in LENET_CONV_SHAPES.items():
        k = np.asarray(params[name]["w"], np.float64)
        H, W, Cin, Cout = k.shape
        cp = pair_columns(k.reshape(H * W * Cin, Cout), rounding)
        analytic_pairs += cp.total_pairs * pos
        baseline += k.size * pos
    points["analytic_per_column"] = {
        "lanes_saved": analytic_pairs,
        "pair_rate": analytic_pairs / baseline,
    }
    # block_n=1 *is* the analytic pairing, executed
    assert points["block_1"]["lanes_saved"] == analytic_pairs, (
        points["block_1"]["lanes_saved"], analytic_pairs,
    )
    return {"rounding": rounding, "points": points}


def fused_pool_path(params, test_x, batch: int = 32) -> dict:
    """Fused conv→pool megakernel vs the unfused schedules, measured.

    Three variants of the same LeNet forward on a real test batch:

    * ``xla`` — lax.conv + standalone 2×2 reduce_window (the baseline),
    * ``paired_unfused`` — the Pallas paired conv, pooling still a separate
      XLA op (full activation map round-trips HBM),
    * ``paired_fused`` — the megakernel: bias → relu → 2×2 max reduce inside
      VMEM, one HBM writeback per conv layer,
    * ``paired_fused_blocked`` — the same megakernel through the
      column-blocked layout (block_n=4 artifacts): the schedule audit must
      hold identically — per-block segment metadata adds no extra pooling
      op or kernel launch.

    Besides wall-clock, each variant's *traced program* is audited through
    the ``repro.analysis`` schedule rules: ``pool_ops`` is the measured value
    of ``schedule/no-standalone-pool`` (must be 0 on the fused path) and
    ``conv_kernel_launches`` of ``schedule/writebacks-per-program`` (must
    equal the 3 conv layers — exactly one writeback each).  The audit is
    structural, so it holds identically on TPU where the wall-clock numbers
    become hardware-meaningful.
    """
    import jax
    import jax.numpy as jnp

    arts = build_conv_pairings(params, 0.0, positions=LENET_CONV_POSITIONS)
    barts = build_conv_pairings(
        params, 0.0, positions=LENET_CONV_POSITIONS,
        mode="column_blocked", block_n=4,
    )
    xb = jnp.asarray(test_x[:batch], jnp.float32)

    variants = {
        "xla": dict(conv_impl="xla", paired=None, fuse_pool=False),
        "paired_unfused": dict(conv_impl="pallas_paired", paired=arts,
                               fuse_pool=False),
        "paired_fused": dict(conv_impl="pallas_paired", paired=arts,
                             fuse_pool=True),
        "paired_fused_blocked": dict(conv_impl="pallas_paired", paired=barts,
                                     fuse_pool=True),
    }
    schedule_rules = (
        "schedule/no-standalone-pool",
        "schedule/writebacks-per-program",
    )
    out: dict = {}
    y_ref = None
    for name, kw in variants.items():
        fn = jax.jit(lambda p, x, kw=kw: lenet_apply(p, x, **kw))
        jaxpr = jax.make_jaxpr(lambda p, x, kw=kw: lenet_apply(p, x, **kw))(
            params, xb
        )
        y = np.asarray(fn(params, xb))
        if y_ref is None:
            y_ref = y
        t = measure(lambda: fn(params, xb), reps=3, warmup=1)
        # fused variants carry expectations, so error findings ARE the audit;
        # the unfused variants run the same rules info-only
        expect = (
            {"fused_pool": True, "pallas_calls": len(kw["paired"])}
            if kw["fuse_pool"] else {}
        )
        report = run_rules(
            RuleContext(target=f"fig8/{name}", jaxpr=jaxpr, expect=expect),
            rule_ids=schedule_rules,
        )
        out[name] = {
            "wall_s": t,
            "pool_ops": report.measured("schedule/no-standalone-pool"),
            "conv_kernel_launches": report.measured(
                "schedule/writebacks-per-program"
            ),
            "schedule_errors": [f.as_dict() for f in report.errors()],
            "rel_err_vs_xla": float(
                np.abs(y - y_ref).max() / max(np.abs(y_ref).max(), 1e-30)
            ),
        }

    # the schedule audit must hold on both fused layouts (shared-permutation
    # and column-blocked): zero standalone pool ops, one writeback per conv
    for tag in ("paired_fused", "paired_fused_blocked"):
        fused = out[tag]
        assert not fused["schedule_errors"], (
            f"{tag} violates the fused schedule: {fused['schedule_errors']}"
        )
        assert fused["rel_err_vs_xla"] <= 1e-5, (
            f"{tag} at rounding 0 must match the XLA reference: "
            f"rel err {fused['rel_err_vs_xla']:.2e}"
        )
    assert out["paired_unfused"]["pool_ops"] == 2  # the two pooled layers
    return {"batch": batch, "variants": out}


def _train_tiny_lm(cfg, n_steps: int, seed: int = 0):
    """A few hundred AdamW steps on the deterministic token stream — enough
    to move the init weights to a *trained* distribution (the pairing rate
    is a property of that distribution, which is what the ledger reports)."""
    import jax
    import jax.numpy as jnp

    from repro.data.tokens import token_batches
    from repro.models import lm as M
    from repro.models.param import unzip
    from repro.train.optimizer import adamw, cosine_schedule

    params, _ = unzip(M.init_lm(cfg, jax.random.key(seed)))
    knobs = M.PerfKnobs(q_chunk=32, k_chunk=32, remat="none")
    opt = adamw(cosine_schedule(3e-3, n_steps, warmup_steps=min(5, n_steps)))
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, i, batch):
        (loss, _), grads = jax.value_and_grad(
            lambda p: M.lm_loss(cfg, p, batch, knobs=knobs), has_aux=True
        )(params)
        params, opt_state = opt.update(grads, opt_state, params, i)
        return params, opt_state, loss

    losses = []
    for i, (tok, lab) in enumerate(token_batches(4, 32, cfg.vocab, seed=7)):
        if i >= n_steps:
            break
        params, opt_state, loss = step(
            params, opt_state, jnp.int32(i),
            {"tokens": jnp.asarray(tok), "labels": jnp.asarray(lab)},
        )
        losses.append(float(loss))
    return params, losses


def lm_paired_decode_bench(quick: bool = False) -> dict:
    """Paired subtractor GEMMs on the LM decode path, measured end to end.

    Three claims, all executed (not modeled):

    * **parity** — a ServeEngine with ``gemm="pallas_paired"`` at rounding 0
      (prefill + batched greedy decode on a mixed-length batch) produces
      token-for-token the same stream as the XLA engine;
    * **ledger** — on a *trained* tiny LM at the paper's headline rounding,
      the per-column (block_n=1) pairing removes a nonzero fraction of MXU
      lanes from the decoder GEMMs (reported next to the structured and
      blocked rates, mirroring the conv pairing_block_sweep);
    * **schedule audit** — the traced ``decode_step`` under the paired
      policy contains **zero** standalone residual adds over the hidden
      state (the ``h + attn(x)`` / ``h + mlp(x)`` skip connections execute
      inside the kernel's residual-add epilogue), while the XLA trace of the
      same step keeps them as separate ops;
    * **fused attention** — an engine with ``attn="pallas_fused"`` on top of
      the paired GEMMs (decode attention computed in VMEM and fed straight
      into the paired out-projection epilogue, QKV as one concatenated
      subtractor launch) holds the same r=0 token parity on the same
      mixed-length batch, and its traced ``decode_step`` audits at **5**
      kernel writebacks per decoder layer (down from 7 unfused).
    """
    import dataclasses as dc

    import jax
    import jax.numpy as jnp

    from repro.configs import get_smoke_config
    from repro.core.transform import pair_lm_params
    from repro.kernels.ops import perf_context
    from repro.models import lm as M
    from repro.models.param import unzip
    from repro.serving.engine import ServeEngine

    # fp32: the parity claim is exactness of the kernel path, not bf16 noise
    cfg = dc.replace(get_smoke_config("qwen2-1.5b"), dtype="float32")
    base = dict(q_chunk=16, k_chunk=16, remat="none")
    steps = 4 if quick else 6
    train_steps = 60 if quick else 200

    params, losses = _train_tiny_lm(cfg, train_steps)
    assert losses[-1] < losses[0], "tiny LM must actually train"

    # --- parity: prefill → mixed-length batched decode, token-for-token ----
    rng = np.random.default_rng(0)
    prompts = {
        0: rng.integers(0, cfg.vocab, size=(5,)).astype(np.int32),
        1: rng.integers(0, cfg.vocab, size=(11,)).astype(np.int32),
    }
    eng_x = ServeEngine(cfg, params, max_seq=32, batch_size=2,
                        knobs=M.PerfKnobs(**base))
    eng_p = ServeEngine(cfg, params, max_seq=32, batch_size=2,
                        knobs=M.PerfKnobs(**base, gemm="pallas_paired",
                                          pair_rounding=0.0))
    # fused decode attention riding the same paired engine: per-column
    # (block_n=1) pairing so the QKV projections fuse into one concatenated
    # subtractor launch and the attended output feeds the out-projection
    # epilogue without the HBM round-trip
    eng_f = ServeEngine(cfg, params, max_seq=32, batch_size=2,
                        knobs=M.PerfKnobs(**base, gemm="pallas_paired",
                                          pair_rounding=0.0, pair_block_n=1,
                                          attn="pallas_fused"))
    out_x = eng_x.generate({k: v for k, v in prompts.items()}, steps)
    out_p = eng_p.generate({k: v for k, v in prompts.items()}, steps)
    out_f = eng_f.generate({k: v for k, v in prompts.items()}, steps)
    token_identical = out_x == out_p
    assert token_identical, (
        f"paired decode diverged from XLA at rounding 0: {out_p} vs {out_x}"
    )
    fused_token_identical = out_x == out_f
    assert fused_token_identical, (
        f"fused-attention decode diverged from XLA at rounding 0 on the "
        f"mixed-length batch: {out_f} vs {out_x}"
    )

    # --- ledger: pairing rates on the trained weights ----------------------
    rates = {}
    pm = None  # per-column params-with-metadata, reused by the audit below
    for tag, kw in (
        ("structured", dict(mode="structured")),
        ("block_4", dict(mode="column_blocked", block_n=4)),
        ("per_column", dict(mode="per_column")),
    ):
        paired_params, rep = pair_lm_params(params, LM_HEADLINE_ROUNDING, **kw)
        if tag == "per_column":
            pm = paired_params
        rates[tag] = {
            "baseline_lanes_per_token": rep.total_weights,
            "lanes_saved_per_token": rep.total_pairs,
            "pair_rate": rep.total_pairs / rep.total_weights,
        }
    assert rates["per_column"]["lanes_saved_per_token"] > 0, (
        "per-column pairing must save lanes at the headline rounding"
    )

    # --- schedule audit: residual adds live in the kernel epilogue ---------
    knobs_p = M.PerfKnobs(**base, gemm="pallas_paired", pair_block_n=1,
                          pair_rounding=LM_HEADLINE_ROUNDING)
    cache, _ = unzip(M.init_cache(cfg, 2, 32))
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.asarray([5, 11], jnp.int32)

    def audit(tag, p, knobs, expect):
        with perf_context(knobs):
            jaxpr = jax.make_jaxpr(
                lambda p, c, t, s: M.decode_step(cfg, p, c, t, s)
            )(p, cache, tok, pos)
        return run_rules(
            RuleContext(target=f"fig8/{tag}", jaxpr=jaxpr,
                        hidden_shape=h_shape, expect=expect),
            rule_ids=(
                "schedule/standalone-residual-adds",
                "schedule/writebacks-per-decode-layer",
            ),
        )

    h_shape = (2, 1, cfg.d_model)
    rep_paired = audit(
        "lm_decode_paired", pm, knobs_p,
        # 7 = the paired GEMMs per layer (attn q/k/v/out + MLP gate/up/down)
        {"residual_adds": 0, "writebacks_per_layer": 7},
    )
    # same paired step with the fused attention policy on top: the q·K /
    # softmax / ·V writebacks and the separate out-projection launch collapse
    # into one kernel, so the per-layer writeback budget drops 7 → 5
    knobs_f = dc.replace(knobs_p, attn="pallas_fused")
    rep_fused = audit(
        "lm_decode_fused_attn", pm, knobs_f,
        {"residual_adds": 0, "writebacks_per_layer": 5},
    )
    rep_xla = audit("lm_decode_xla", params, M.PerfKnobs(**base), {})
    resid_adds_paired = rep_paired.measured("schedule/standalone-residual-adds")
    resid_adds_xla = rep_xla.measured("schedule/standalone-residual-adds")
    assert not rep_paired.errors(), (
        f"paired decode violates the schedule rules: "
        f"{[f.as_dict() for f in rep_paired.errors()]}"
    )
    assert not rep_fused.errors(), (
        f"fused-attention decode violates the schedule rules: "
        f"{[f.as_dict() for f in rep_fused.errors()]}"
    )
    fused_writebacks = rep_fused.measured(
        "schedule/writebacks-per-decode-layer")
    assert fused_writebacks == 5, (
        f"fused-attention decode must run exactly 5 kernel writebacks per "
        f"layer (fused QKV + fused attn/out-proj + 3 MLP), measured "
        f"{fused_writebacks}"
    )
    assert resid_adds_xla > 0, (
        "audit is vacuous: the XLA trace shows no residual adds to fuse"
    )

    out = {
        "arch": cfg.name,
        "train_steps": train_steps,
        "train_loss": {"first": losses[0], "last": losses[-1]},
        "decode_steps": steps,
        "parity": {
            "rounding": 0.0,
            "token_identical": bool(token_identical),
            "fused_attn_token_identical": bool(fused_token_identical),
            "tokens": {int(k): v for k, v in out_p.items()},
        },
        "ledger": {"rounding": LM_HEADLINE_ROUNDING, "rates": rates},
        "residual_audit": {
            "hidden_shape": list(h_shape),
            "paired_residual_adds": int(resid_adds_paired),
            "xla_residual_adds": int(resid_adds_xla),
            "paired_writebacks_per_layer": int(
                rep_paired.measured("schedule/writebacks-per-decode-layer")
            ),
            "fused_attn_writebacks_per_layer": int(fused_writebacks),
        },
    }
    out["perf_summary"] = {
        "parity": out["parity"]["token_identical"],
        "lm_ledger": rates,
        "residual_audit": out["residual_audit"],
    }
    print(f"LM paired decode [{cfg.name}] @ r=0: token-identical to XLA over "
          f"{steps} steps × 2 mixed-length slots "
          f"(fused-attn engine: {fused_token_identical})")
    print("LM pairing ledger @ r=0.05 (trained weights): " + ", ".join(
        f"{tag}={r['pair_rate']:.3f}" for tag, r in rates.items()))
    print(f"residual-add audit: paired trace {resid_adds_paired} standalone "
          f"adds (XLA trace {resid_adds_xla}); writebacks/layer "
          f"{out['residual_audit']['paired_writebacks_per_layer']} unfused → "
          f"{fused_writebacks} with fused decode attention")
    return out


def run_lm_paired(quick: bool = False) -> dict:
    """benchmarks/run.py entry: the paired-LM decode bench on its own."""
    out = lm_paired_decode_bench(quick=quick)
    write_result("lm_paired", out)
    return out


def run(quick: bool = False) -> dict:
    params, test_x, test_y, info = get_trained_lenet(verbose=False)
    base_acc = info["test_acc"]
    model = AsicCostModel()
    base_ops = OpCounts(mults=405600, adds=405600, subs=0)

    roundings = ROUNDINGS if not quick else [0.0, 0.01, 0.05, 0.3]
    rows = []
    for r in roundings:
        p2, ops = paired_lenet(params, r)
        acc = lenet_accuracy(p2, test_x, test_y)
        rows.append(
            {
                "rounding": r,
                "subs": ops.subs,
                "power_saving_%": 100 * model.power_saving(base_ops, ops),
                "area_saving_%": 100 * model.area_saving(base_ops, ops),
                "accuracy_%": 100 * acc,
                "acc_loss_%": 100 * (base_acc - acc),
            }
        )

    # weight distribution of conv3 (paper Fig. 3 / Fig. 4)
    w3 = np.asarray(params["conv3"]["w"]).ravel()
    hist, edges = np.histogram(w3, bins=40)
    dist = {
        "mean": float(w3.mean()),
        "std": float(w3.std()),
        "frac_positive": float((w3 > 0).mean()),
        "hist_counts": hist.tolist(),
        "hist_edges": edges.tolist(),
    }

    # TPU tile configs for each conv layer viewed as a GEMM (M = output
    # positions, K = receptive field, N = filters): what the K-tiled paired
    # kernel would use, recorded so hardware runs are reproducible.
    tile_configs = {}
    for name, (shape, pos) in LENET_CONV_SHAPES.items():
        H, W, Cin, Cout = shape
        K = H * W * Cin
        cp = column_pairing_for_conv(np.asarray(params[name]["w"], np.float64), 0.05)
        P = int(np.min(cp.n_pairs)) if cp.n_pairs.size else 0  # shared floor
        tiles = choose_blocks(pos, Cout, P, K - 2 * P, dtype_bytes=4)
        tile_configs[name] = {"M": pos, "N": Cout, "K": K, **tiles.as_dict()}

    # measured paired-conv execution (not just the analytic model): run the
    # Pallas path at rounding 0 (must match XLA ≤ 1e-5) and at the paper's
    # headline rounding, recording per-conv-layer kernel op counts.
    batch = 16 if quick else 32
    measured = {
        "r0": measured_conv_path(params, test_x, 0.0, batch=batch),
        "headline": measured_conv_path(params, test_x, 0.05, batch=batch),
        # structured (shared-row) pairing needs a larger rounding than the
        # paper's per-column pairing before it engages on trained weights —
        # record a point where the kernel actually executes subtractions
        "r_structured": measured_conv_path(params, test_x, 0.3, batch=batch),
        # the column-blocked layout executes a nontrivial pairing rate at the
        # paper's *headline* rounding (structured stays at 0 there): r=0
        # parity gates the layout, headline records what it buys
        "r0_blocked": measured_conv_path(
            params, test_x, 0.0, batch=batch, mode="column_blocked", block_n=4
        ),
        "headline_blocked": measured_conv_path(
            params, test_x, 0.05, batch=batch,
            mode="column_blocked", block_n=4,
        ),
        "headline_per_column": measured_conv_path(
            params, test_x, 0.05, batch=batch,
            mode="column_blocked", block_n=1,
        ),
    }
    for tag in ("r0", "r0_blocked"):
        assert measured[tag]["rel_err_vs_xla"] <= 1e-5, (
            f"paired Pallas conv ({tag}) at rounding 0 must match the XLA "
            f"reference: relative err {measured[tag]['rel_err_vs_xla']:.2e}"
        )

    # pairing rate vs block size at the headline rounding (the gap the
    # column-blocked kernel layout closes)
    block_sweep = pairing_block_sweep(
        params, 0.05, block_ns=(1, 4) if quick else (1, 2, 4, 8, 16)
    )

    # fused conv→pool megakernel: wall-clock vs the unfused schedules plus
    # the structural audit (no standalone pool op, one writeback per conv)
    fused = fused_pool_path(params, test_x, batch=batch)

    out = {
        "rows": rows,
        "baseline_accuracy": base_acc,
        "data_source": info["source"],
        "kernel_tile_configs": tile_configs,
        "measured_conv_path": measured,
        "pairing_block_sweep": block_sweep,
        "fused_pool_path": fused,
        "conv3_weight_distribution": dist,
        "paper_headline": {
            "rounding": 0.05,
            "power_saving_%": 32.03,
            "area_saving_%": 24.59,
            "acc_loss_%": 0.1,
        },
        # machine-readable perf trajectory (benchmarks/run.py lifts this
        # into BENCH_fig8.json; CI gates on fused.pool_ops == 0)
        "perf_summary": {
            "fused_pool": fused,
            "pairing_block_sweep": block_sweep,
            "kernel_tile_configs": tile_configs,
            "kernel_op_counts": {
                tag: {
                    "total_baseline_lanes": m["total_baseline_lanes"],
                    "total_paired_lanes": m["total_paired_lanes"],
                    "total_subs_per_image": m["total_subs_per_image"],
                }
                for tag, m in measured.items()
            },
        },
    }
    print(fmt_table(rows, list(rows[0].keys()), "Fig. 8: trade-off per rounding size"))
    for tag in ("headline", "r_structured", "headline_blocked", "headline_per_column"):
        m = measured[tag]
        mode = m["mode"] if m["block_n"] == 0 else f"blocked(n={m['block_n']})"
        print(
            f"measured paired-conv path [{mode}] @ r={m['rounding']}: "
            f"{m['total_baseline_lanes']} baseline MXU lanes/image → "
            f"{m['total_paired_lanes']} paired, {m['total_subs_per_image']} "
            f"VPU subs/image"
        )
    print("pairing rate vs block size @ r=0.05: " + ", ".join(
        f"{tag}={p['pair_rate']:.3f}"
        for tag, p in block_sweep["points"].items()
    ))
    print(
        f"r=0 err vs XLA conv: abs {measured['r0']['max_abs_err_vs_xla']:.2e} "
        f"rel {measured['r0']['rel_err_vs_xla']:.2e}"
    )
    for name, v in fused["variants"].items():
        print(
            f"conv→pool [{name:>14s}]: {v['wall_s']*1e3:8.1f} ms/batch, "
            f"{v['pool_ops']} standalone pool ops, "
            f"{v['conv_kernel_launches']} kernel writebacks, "
            f"rel err {v['rel_err_vs_xla']:.1e}"
        )
    print(
        f"conv3 weights: mean {dist['mean']:+.4f} std {dist['std']:.4f} "
        f"positive fraction {dist['frac_positive']:.3f} (paper Fig. 3/4: "
        "roughly zero-centred, enabling opposite-sign pairs)"
    )
    write_result("fig8", out)
    return out


if __name__ == "__main__":
    run()
