"""Fig. 8 reproduction: power/area saving vs accuracy per rounding size.

For each rounding size: pair the conv weights per filter (Algorithm 1),
snap pairs to the common magnitude (``fold``), evaluate test accuracy with
the folded weights (bit-identical to the subtractor dataflow), and price the
op mix with the calibrated 65 nm ASIC model.  Also dumps the weight
distribution histogram of conv3 (paper Figs. 3/4).

Paper headline @ rounding 0.05: 32.03 % power, 24.59 % area, 0.1 % accuracy
loss.  The savings are functions of the *op counts*, so our savings differ
only insofar as our trained weights pair at different rates.
"""
from __future__ import annotations

import numpy as np

from repro.core.cost_model import AsicCostModel, OpCounts
from repro.core.pairing import column_pairing_for_conv, fold_columns, pairing_op_counts
from repro.kernels.tuning import choose_blocks
from repro.models.lenet import LENET_CONV_SHAPES, lenet_accuracy
from repro.train.lenet_trainer import get_trained_lenet

from benchmarks.common import fmt_table, write_result

ROUNDINGS = [0.0, 0.0001, 0.005, 0.01, 0.015, 0.02, 0.025, 0.05, 0.1, 0.15, 0.2, 0.25, 0.3]


def paired_lenet(params, rounding: float):
    """Fold conv weights at the given rounding; return (params', op ledger)."""
    import jax

    new = jax.tree.map(lambda x: x, params)  # shallow copy of the tree
    mults = adds = subs = 0
    for name, (shape, pos) in LENET_CONV_SHAPES.items():
        k = np.asarray(params[name]["w"], dtype=np.float64)
        H, W, Cin, Cout = k.shape
        cp = column_pairing_for_conv(k, rounding)
        folded = fold_columns(k.reshape(H * W * Cin, Cout), cp).reshape(k.shape)
        new[name] = dict(new[name])
        new[name]["w"] = folded.astype(np.float32)
        c = pairing_op_counts(k.size, cp.total_pairs, pos)
        mults += c["mults"]
        adds += c["adds"]
        subs += c["subs"]
    return new, OpCounts(mults=mults, adds=adds, subs=subs)


def run(quick: bool = False) -> dict:
    params, test_x, test_y, info = get_trained_lenet(verbose=False)
    base_acc = info["test_acc"]
    model = AsicCostModel()
    base_ops = OpCounts(mults=405600, adds=405600, subs=0)

    roundings = ROUNDINGS if not quick else [0.0, 0.01, 0.05, 0.3]
    rows = []
    for r in roundings:
        p2, ops = paired_lenet(params, r)
        acc = lenet_accuracy(p2, test_x, test_y)
        rows.append(
            {
                "rounding": r,
                "subs": ops.subs,
                "power_saving_%": 100 * model.power_saving(base_ops, ops),
                "area_saving_%": 100 * model.area_saving(base_ops, ops),
                "accuracy_%": 100 * acc,
                "acc_loss_%": 100 * (base_acc - acc),
            }
        )

    # weight distribution of conv3 (paper Fig. 3 / Fig. 4)
    w3 = np.asarray(params["conv3"]["w"]).ravel()
    hist, edges = np.histogram(w3, bins=40)
    dist = {
        "mean": float(w3.mean()),
        "std": float(w3.std()),
        "frac_positive": float((w3 > 0).mean()),
        "hist_counts": hist.tolist(),
        "hist_edges": edges.tolist(),
    }

    # TPU tile configs for each conv layer viewed as a GEMM (M = output
    # positions, K = receptive field, N = filters): what the K-tiled paired
    # kernel would use, recorded so hardware runs are reproducible.
    tile_configs = {}
    for name, (shape, pos) in LENET_CONV_SHAPES.items():
        H, W, Cin, Cout = shape
        K = H * W * Cin
        cp = column_pairing_for_conv(np.asarray(params[name]["w"], np.float64), 0.05)
        P = int(np.min(cp.n_pairs)) if cp.n_pairs.size else 0  # shared floor
        tiles = choose_blocks(pos, Cout, P, K - 2 * P, dtype_bytes=4)
        tile_configs[name] = {"M": pos, "N": Cout, "K": K, **tiles.as_dict()}

    out = {
        "rows": rows,
        "baseline_accuracy": base_acc,
        "data_source": info["source"],
        "kernel_tile_configs": tile_configs,
        "conv3_weight_distribution": dist,
        "paper_headline": {
            "rounding": 0.05,
            "power_saving_%": 32.03,
            "area_saving_%": 24.59,
            "acc_loss_%": 0.1,
        },
    }
    print(fmt_table(rows, list(rows[0].keys()), "Fig. 8: trade-off per rounding size"))
    print(
        f"conv3 weights: mean {dist['mean']:+.4f} std {dist['std']:.4f} "
        f"positive fraction {dist['frac_positive']:.3f} (paper Fig. 3/4: "
        "roughly zero-centred, enabling opposite-sign pairs)"
    )
    write_result("fig8", out)
    return out


if __name__ == "__main__":
    run()
