"""Benchmark harness: one module per paper table/figure + the beyond-paper
and roofline benches.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Order: the LeNet benches reproduce the paper's own artifacts (Table I,
Fig. 8 incl. Fig. 3/4 weight-distribution stats); pairing_rate_lm extends
the technique to the ten assigned architectures; roofline assembles the
dry-run results (run `python -m repro.launch.dryrun` first for fresh cells).
"""
from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import fig8, pairing_rate_lm, roofline, table1

BENCHES = [
    ("table1 (paper Table I)", table1.run),
    ("fig8 (paper Fig. 8 + Fig. 3/4)", fig8.run),
    ("pairing_rate_lm (beyond paper)", pairing_rate_lm.run),
    ("roofline (dry-run analysis)", roofline.run),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()

    results = {}
    for name, fn in BENCHES:
        print(f"\n{'='*70}\n== {name}\n{'='*70}")
        t0 = time.time()
        try:
            results[name] = fn(quick=args.quick)
            print(f"[{name}] done in {time.time()-t0:.1f}s")
        except Exception as e:  # noqa: BLE001
            print(f"[{name}] FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()
            results[name] = {"error": str(e)}
    n_fail = sum(1 for v in results.values() if "error" in v)
    print(f"\n[benchmarks] {len(BENCHES) - n_fail}/{len(BENCHES)} benches succeeded")


if __name__ == "__main__":
    main()
