"""Benchmark harness: one module per paper table/figure + the beyond-paper
and roofline benches.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Order: the LeNet benches reproduce the paper's own artifacts (Table I,
Fig. 8 incl. Fig. 3/4 weight-distribution stats); pairing_rate_lm extends
the technique to the ten assigned architectures; roofline assembles the
dry-run results (run `python -m repro.launch.dryrun` first for fresh cells).

Besides each bench's own ``<name>.json``, the harness emits a
machine-readable ``BENCH_<name>.json`` per bench — wall-clock, status, and
the bench's ``perf_summary`` (kernel op counts, tile configs, fused-path
audit) — so the perf trajectory is tracked across PRs (CI uploads these as
artifacts and gates on the fused-path audit) instead of only printed.

Exit code is nonzero when any selected bench fails — CI's smoke job depends
on that (a green run must mean every bench actually succeeded).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (
    fig8,
    mesh_decode,
    model_zoo,
    pairing_rate_lm,
    roofline,
    serving,
    table1,
)
from benchmarks.common import write_result

BENCHES = [
    ("table1", "paper Table I", table1.run),
    ("fig8", "paper Fig. 8 + Fig. 3/4", fig8.run),
    ("lm_paired", "beyond paper: paired LM decode", fig8.run_lm_paired),
    ("pairing_rate_lm", "beyond paper", pairing_rate_lm.run),
    ("model_zoo", "paired path across all ten config families", model_zoo.run),
    ("serving", "hardened front end: load sweep + chaos, degraded-path parity",
     serving.run),
    ("mesh_decode", "sharded paired decode: mesh parity + per-shard ledgers",
     mesh_decode.run),
    ("roofline", "dry-run analysis", roofline.run),
]


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument(
        "--only", action="append", default=None, metavar="NAME",
        help="run only the named bench (repeatable; for CI sharding): "
             + ", ".join(name for name, _, _ in BENCHES),
    )
    ap.add_argument(
        "--family", default=None, metavar="ARCH",
        help="restrict the model_zoo bench to one config family "
             "(CI matrix legs; other benches ignore it)",
    )
    args = ap.parse_args(argv)

    selected = BENCHES
    if args.only:
        known = {name for name, _, _ in BENCHES}
        unknown = sorted(set(args.only) - known)
        if unknown:
            ap.error(f"unknown bench name(s) {unknown}; choose from {sorted(known)}")
        selected = [b for b in BENCHES if b[0] in args.only]

    results = {}
    for name, desc, fn in selected:
        print(f"\n{'='*70}\n== {name} ({desc})\n{'='*70}")
        t0 = time.time()
        kwargs = {"family": args.family} if name == "model_zoo" else {}
        try:
            results[name] = fn(quick=args.quick, **kwargs)
            print(f"[{name}] done in {time.time()-t0:.1f}s")
        except Exception as e:  # noqa: BLE001
            print(f"[{name}] FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()
            results[name] = {"error": str(e)}
        # machine-readable perf record, one file per bench per run
        res = results[name] if isinstance(results[name], dict) else {}
        write_result(
            f"BENCH_{name}",
            {
                "bench": name,
                "status": "error" if "error" in res else "ok",
                "error": res.get("error"),
                "wall_clock_s": time.time() - t0,
                "quick": args.quick,
                "summary": res.get("perf_summary", {}),
            },
        )
    n_fail = sum(1 for v in results.values() if "error" in v)
    print(f"\n[benchmarks] {len(selected) - n_fail}/{len(selected)} benches succeeded")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
