"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Sequence

RESULTS_DIR = Path(__file__).parent / "results"


def write_result(name: str, payload: Any) -> Path:
    RESULTS_DIR.mkdir(exist_ok=True)
    path = RESULTS_DIR / f"{name}.json"
    path.write_text(json.dumps(payload, indent=2, default=str))
    return path


def count_primitives(jaxpr, name: str) -> int:
    """Count occurrences of a primitive in a (closed) jaxpr, recursively.

    Walks call/custom-vjp/scan sub-jaxprs, so the count covers the whole
    traced program — used to audit the fused conv path's schedule (e.g.
    ``reduce_window_max`` must be absent, ``pallas_call`` counts HBM
    writebacks of the conv layers).
    """
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    n = 0
    for eqn in inner.eqns:
        if eqn.primitive.name == name:
            n += 1
        for v in eqn.params.values():
            for s in v if isinstance(v, (list, tuple)) else [v]:
                if hasattr(s, "jaxpr") or hasattr(s, "eqns"):
                    n += count_primitives(s, name)
    return n


def fmt_table(rows: Sequence[dict], cols: Sequence[str], title: str = "") -> str:
    def fmt(v):
        if isinstance(v, float):
            return f"{v:.4f}" if abs(v) < 100 else f"{v:.1f}"
        return str(v)

    widths = {c: max(len(c), *(len(fmt(r.get(c, ""))) for r in rows)) for c in cols}
    out = []
    if title:
        out.append(f"== {title} ==")
    out.append(" | ".join(c.rjust(widths[c]) for c in cols))
    out.append("-+-".join("-" * widths[c] for c in cols))
    for r in rows:
        out.append(" | ".join(fmt(r.get(c, "")).rjust(widths[c]) for c in cols))
    return "\n".join(out)
