"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Sequence

RESULTS_DIR = Path(__file__).parent / "results"


def write_result(name: str, payload: Any) -> Path:
    RESULTS_DIR.mkdir(exist_ok=True)
    path = RESULTS_DIR / f"{name}.json"
    path.write_text(json.dumps(payload, indent=2, default=str))
    return path


def _walk_eqns(jaxpr):
    """Yield every eqn of a (closed) jaxpr, descending into call /
    custom-vjp / scan / pallas sub-jaxprs carried in eqn params — one walk
    shared by every traced-program audit below."""
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in inner.eqns:
        yield eqn
        for v in eqn.params.values():
            for s in v if isinstance(v, (list, tuple)) else [v]:
                if hasattr(s, "jaxpr") or hasattr(s, "eqns"):
                    yield from _walk_eqns(s)


def count_primitives(jaxpr, name: str) -> int:
    """Count occurrences of a primitive across the whole traced program —
    used to audit the fused conv path's schedule (e.g. ``reduce_window_max``
    must be absent, ``pallas_call`` counts HBM writebacks of the conv
    layers)."""
    return sum(1 for eqn in _walk_eqns(jaxpr) if eqn.primitive.name == name)


def count_shape_adds(jaxpr, shape: Sequence[int]) -> int:
    """Count ``add`` eqns whose output *and both operands* have ``shape``.

    An ``add`` of two full hidden-state tensors is the signature of a
    standalone residual add (``h + attn(x)`` / ``h + mlp(x)``) — bias adds
    and norm arithmetic broadcast from lower-rank operands and never match.
    Used to audit that the paired decode step executes its residual adds
    inside the kernel epilogue instead.
    """
    shape = tuple(shape)

    def is_resid_add(eqn):
        if eqn.primitive.name != "add":
            return False
        avals = [getattr(v, "aval", None) for v in (*eqn.invars, *eqn.outvars)]
        return all(getattr(a, "shape", None) == shape for a in avals)

    return sum(1 for eqn in _walk_eqns(jaxpr) if is_resid_add(eqn))


def fmt_table(rows: Sequence[dict], cols: Sequence[str], title: str = "") -> str:
    def fmt(v):
        if isinstance(v, float):
            return f"{v:.4f}" if abs(v) < 100 else f"{v:.1f}"
        return str(v)

    widths = {c: max(len(c), *(len(fmt(r.get(c, ""))) for r in rows)) for c in cols}
    out = []
    if title:
        out.append(f"== {title} ==")
    out.append(" | ".join(c.rjust(widths[c]) for c in cols))
    out.append("-+-".join("-" * widths[c] for c in cols))
    for r in rows:
        out.append(" | ".join(fmt(r.get(c, "")).rjust(widths[c]) for c in cols))
    return "\n".join(out)
