"""Shared helpers for the benchmark harness.

The jaxpr-walking audits (``count_primitives``, ``count_shape_adds``) live in
:mod:`repro.analysis.jaxpr_walk` — the repo's single walker implementation —
and are re-exported here for the benches that import them by their historical
names.
"""
from __future__ import annotations

import json
from pathlib import Path
from collections.abc import Sequence
from typing import Any

from repro.analysis.jaxpr_walk import (  # noqa: F401  (re-exports)
    count_primitives,
    count_shape_adds,
    walk_eqns as _walk_eqns,
)

RESULTS_DIR = Path(__file__).parent / "results"


def write_result(name: str, payload: Any) -> Path:
    RESULTS_DIR.mkdir(exist_ok=True)
    path = RESULTS_DIR / f"{name}.json"
    path.write_text(json.dumps(payload, indent=2, default=str))
    return path


def fmt_table(rows: Sequence[dict], cols: Sequence[str], title: str = "") -> str:
    def fmt(v):
        if isinstance(v, float):
            return f"{v:.4f}" if abs(v) < 100 else f"{v:.1f}"
        return str(v)

    widths = {c: max(len(c), *(len(fmt(r.get(c, ""))) for r in rows)) for c in cols}
    out = []
    if title:
        out.append(f"== {title} ==")
    out.append(" | ".join(c.rjust(widths[c]) for c in cols))
    out.append("-+-".join("-" * widths[c] for c in cols))
    for r in rows:
        out.append(" | ".join(fmt(r.get(c, "")).rjust(widths[c]) for c in cols))
    return "\n".join(out)
