"""Beyond-paper: subtractor-pairing rates across the ten assigned LM archs.

For each architecture (reduced config — the pairing rate is a property of
the weight *distribution*, which the reduced configs share with their full
siblings), applies the paper's per-column pairing to every weight matrix and
reports the pair fraction + modeled ASIC power/area savings, plus the
structured (TPU) pairing rate.

This answers: "how much of the paper's LeNet-5 result carries over to a
modern LM?" — which no table in the paper covers.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs import ALL_ARCHS, get_smoke_config
from repro.core.transform import pair_model_params
from repro.models import lm as M
from repro.models.param import unzip

from benchmarks.common import fmt_table, write_result

ROUNDING_REL = 0.25  # rounding as a fraction of per-leaf weight std


def run(quick: bool = False) -> dict:
    rows = []
    archs = ALL_ARCHS if not quick else ALL_ARCHS[:3]
    for arch in archs:
        cfg = get_smoke_config(arch)
        params, _ = unzip(M.init_lm(cfg, jax.random.key(0)))
        # per-leaf relative rounding (see EXPERIMENTS.md — fixed absolute
        # rounding is scale-sensitive; relative rounding is our extension)
        stds = [float(np.std(np.asarray(l))) for l in jax.tree.leaves(params)]
        r_abs = ROUNDING_REL * float(np.median([s for s in stds if s > 0]))

        paired, rep = pair_model_params(params, r_abs, min_dim=4)
        s = rep.savings()
        _, rep_s = pair_model_params(params, r_abs, mode="structured", min_dim=4)
        rows.append(
            {
                "arch": arch,
                "weights": rep.total_weights,
                "pair_frac_%": 100 * rep.pair_fraction,
                "power_saving_%": 100 * s["power_saving"],
                "area_saving_%": 100 * s["area_saving"],
                "structured_frac_%": 100 * rep_s.pair_fraction,
            }
        )
    out = {"rounding_rel": ROUNDING_REL, "rows": rows}
    print(fmt_table(rows, list(rows[0].keys()),
                    f"Subtractor pairing on LM archs (relative rounding {ROUNDING_REL}·std)"))
    write_result("pairing_rate_lm", out)
    return out


if __name__ == "__main__":
    run()
