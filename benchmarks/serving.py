"""Load + chaos bench for the hardened serving front end.

Two scenario axes over the paired subtractor engine (qwen2 smoke, fp32,
``gemm="pallas_paired"`` at rounding 0 — the exact-parity point) with an
unpaired XLA fallback engine behind it:

1. **Load sweep** — seeded Poisson arrivals at several offered loads through
   the same front end (length-bucketed admission, chunked prefill, queue
   timeout).  Reports p50/p99 completion latency, p50/p99 time-to-first-token
   and tokens/sec (virtual clock — deterministic per seed) per offered load.
2. **Chaos run** — the same workload with deterministic fault injection:
   NaN/Inf logits, KV-cache poisoning, kernel launch failures, latency
   spikes.  The gates, all asserted here (a red bench fails CI):

   - **zero requests lost** — every request ends completed, degraded, or
     shed with a structured reason;
   - **every slot-targeted fault accounted** — the request occupying a
     faulted slot ends degraded-completed or shed, never plain-completed
     with possibly-garbage tokens;
   - **r=0 token parity of degraded slots** — every degraded completion's
     token stream equals the XLA reference engine's greedy decode of the
     same prompt (graceful degradation means *exact* answers, just slower).

``BENCH_serving.json`` (written by ``benchmarks.run``) carries the summary:
per-load latency/throughput rows plus the chaos ledger.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import fmt_table, write_result
from repro.configs import get_smoke_config
from repro.models import lm as M
from repro.models.param import unzip
from repro.serving import (
    FaultEvent,
    FaultInjector,
    FrontendConfig,
    GuardConfig,
    ServeEngine,
    ServeFrontend,
    faulted_request_ids,
    poisson_workload,
)

SEED = 0
BATCH = 4
MAX_SEQ = 48
HORIZON_S = 0.6
PROMPT_LEN = (3, 20)
NEW_TOKENS = (2, 8)
LOADS_RPS = (10.0, 25.0, 60.0)
LOADS_RPS_QUICK = (10.0, 40.0)

_BASE = dict(q_chunk=16, k_chunk=16, remat="none")


def _engines(cfg, params):
    """(primary paired @ r=0, unpaired XLA fallback) — fresh slot state."""
    primary = ServeEngine(
        cfg, params, max_seq=MAX_SEQ, batch_size=BATCH,
        knobs=M.PerfKnobs(**_BASE, gemm="pallas_paired", pair_rounding=0.0))
    fallback = ServeEngine(
        cfg, params, max_seq=MAX_SEQ, batch_size=BATCH,
        knobs=M.PerfKnobs(**_BASE))
    return primary, fallback


def _frontend_cfg() -> FrontendConfig:
    return FrontendConfig(
        prefill_chunk=6,
        queue_timeout_s=1.0,
        guard=GuardConfig(max_retries=2, quarantine_steps=2),
    )


def _reference_tokens(cfg, params, requests) -> dict[int, list[int]]:
    """Greedy XLA reference for each request's prompt — the parity oracle."""
    ref = ServeEngine(cfg, params, max_seq=MAX_SEQ, batch_size=1,
                      knobs=M.PerfKnobs(**_BASE))
    out = {}
    for r in requests:
        out[r.rid] = ref.generate({0: r.prompt}, n_steps=r.max_new_tokens)[0]
        ref.release_slot(0)
    return out


def _chaos_schedule(quick: bool) -> FaultInjector:
    """Deterministic chaos: pinned early-step faults (the load sweep shows
    the first ~30 steps are saturated, so these provably hit occupied slots)
    plus a seeded low-rate background draw across the whole run."""
    pinned = [
        FaultEvent(step=3, kind="nan_logits", slot=0),
        FaultEvent(step=5, kind="kv_poison", slot=1),
        FaultEvent(step=7, kind="inf_logits", slot=2),
        FaultEvent(step=9, kind="kernel_failure", magnitude=2),
        FaultEvent(step=11, kind="latency_spike", magnitude=8.0),
        FaultEvent(step=14, kind="kv_poison", slot=3),
    ]
    background = () if quick else FaultInjector.from_rates(
        SEED + 1, n_steps=256, batch_size=BATCH,
        rates={"nan_logits": 0.02, "kv_poison": 0.01,
               "kernel_failure": 0.01, "latency_spike": 0.02},
        magnitude=2.0,
    ).events
    return FaultInjector([*pinned, *background])


def run(quick: bool = False) -> dict:
    cfg = dataclasses.replace(get_smoke_config("qwen2-1.5b"), dtype="float32")
    params, _ = unzip(M.init_lm(cfg, jax.random.key(0)))

    # -- load sweep (no faults) ----------------------------------------------
    loads = LOADS_RPS_QUICK if quick else LOADS_RPS
    sweep_rows = []
    for rate in loads:
        workload = poisson_workload(
            rate_rps=rate, horizon_s=HORIZON_S, seed=SEED, vocab=cfg.vocab,
            prompt_len=PROMPT_LEN, new_tokens=NEW_TOKENS)
        primary, fallback = _engines(cfg, params)
        fe = ServeFrontend(primary, fallback, _frontend_cfg())
        summary = fe.run(workload, offered_load_rps=rate).summary()
        assert summary["lost"] == 0, f"load {rate}: lost requests"
        sweep_rows.append({
            "offered_rps": rate,
            "requests": summary["n_requests"],
            "completed": summary["completed"],
            "shed": summary["shed"],
            "p50_s": summary["latency_s"]["p50"],
            "p99_s": summary["latency_s"]["p99"],
            "ttft_p50_s": summary["ttft_s"]["p50"],
            "tok_per_s": summary["tokens_per_s_virtual"],
        })
    print(fmt_table(
        sweep_rows,
        ["offered_rps", "requests", "completed", "shed", "p50_s", "p99_s",
         "ttft_p50_s", "tok_per_s"],
        title="serving load sweep (virtual clock, Poisson arrivals, no faults)",
    ))

    # -- chaos run -----------------------------------------------------------
    chaos_rate = loads[-1] / 2
    workload = poisson_workload(
        rate_rps=chaos_rate, horizon_s=HORIZON_S, seed=SEED, vocab=cfg.vocab,
        prompt_len=PROMPT_LEN, new_tokens=NEW_TOKENS)
    primary, fallback = _engines(cfg, params)
    faults = _chaos_schedule(quick)
    fe = ServeFrontend(primary, fallback, _frontend_cfg(), faults=faults)
    t0 = time.time()
    report = fe.run(workload, offered_load_rps=chaos_rate)
    chaos = report.summary()
    chaos_wall = time.time() - t0

    failures: list[str] = []
    # gate 1: zero requests lost
    if chaos["lost"]:
        failures.append(f"{chaos['lost']} request(s) lost under chaos")
    # gate 2: every slot-targeted fault ends degraded or cleanly shed
    faulted = faulted_request_ids(report)
    if not faulted:
        failures.append("chaos schedule injected no slot-targeted faults "
                        "into occupied slots — the gate gated nothing")
    by_rid = {r.rid: r for r in report.requests}
    for rid in sorted(faulted):
        r = by_rid[rid]
        if r.state == "shed" and not r.shed_reason:
            failures.append(f"rid {rid}: shed without a structured reason")
        elif r.state not in ("degraded", "shed"):
            failures.append(
                f"rid {rid}: took a numeric fault but ended {r.state!r} — "
                f"its tokens never went through the exact fallback path")
    # gate 3: r=0 token parity of every completion vs the XLA reference —
    # degraded slots (the headline claim) and clean paired slots alike
    ref_tokens = _reference_tokens(
        cfg, params,
        [r for r in report.requests if r.state in ("completed", "degraded")])
    n_parity = {"completed": 0, "degraded": 0}
    for r in report.requests:
        if r.state not in ("completed", "degraded"):
            continue
        if r.tokens != ref_tokens[r.rid]:
            failures.append(
                f"rid {r.rid} ({r.state}): token stream diverged from the "
                f"XLA reference at rounding 0")
        else:
            n_parity[r.state] += 1
    if n_parity["degraded"] == 0:
        failures.append("no request completed on the degraded path — "
                        "the parity gate gated nothing")

    print(fmt_table(
        [{
            "requests": chaos["n_requests"],
            "completed": chaos["completed"],
            "degraded": chaos["degraded"],
            "shed": chaos["shed"],
            "faulted": len(faulted),
            "incidents": len(report.incidents),
            "p99_s": chaos["latency_s"]["p99"],
        }],
        ["requests", "completed", "degraded", "shed", "faulted",
         "incidents", "p99_s"],
        title=f"chaos run @ {chaos_rate} req/s "
              f"({len(faults.events)} scheduled fault(s))",
    ))
    print(f"[serving] degraded-path parity: {n_parity['degraded']} degraded + "
          f"{n_parity['completed']} clean completions all match the XLA "
          f"reference (r=0)")

    payload = {
        "seed": SEED,
        "batch": BATCH,
        "max_seq": MAX_SEQ,
        "load_sweep": sweep_rows,
        "chaos": {
            **chaos,
            "wall_s": round(chaos_wall, 3),
            "scheduled_faults": len(faults.events),
            "fired_faults": len(faults.fired),
            "faulted_requests": sorted(faulted),
            "parity_checked": n_parity,
            "incident_log": report.incidents.as_dicts(),
        },
        "failures": failures,
    }
    write_result("serving", payload)
    if failures:
        raise AssertionError("; ".join(failures))
    return {
        "perf_summary": {
            "load_sweep": sweep_rows,
            "chaos": {k: v for k, v in payload["chaos"].items()
                      if k != "incident_log"},
        }
    }


if __name__ == "__main__":
    run()
