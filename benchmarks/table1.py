"""Table I reproduction: add/sub/mult counts vs rounding size for LeNet-5.

The paper counts the three convolutional layers only (their baseline of
405 600 multiplications = 117 600 + 240 000 + 48 000 MACs), pairing weights
*within each filter*.  We run the same accounting on our trained LeNet-5 and
print our ledger next to the paper's published one.  Counts differ in detail
(they depend on the trained weight values) but must match on structure:
adds == mults, adds + subs == 405 600, subs monotone in rounding.

Alongside the paper's analytic (per-column) ledger, each row also reports
what the TPU kernel path *measures*, across the pairing-mode spectrum the
kernel can execute:

* ``structured``       — one shared-row pairing across all output channels
  (the strictest mode: counts lower-bound everything else);
* ``column_blocked``   — one pairing per ``block_n`` output channels
  (the per-n-block kernel layout), swept over KERNEL_BLOCK_NS;
* ``block_n = 1``      — the paper's per-column pairing, *executed*: its
  measured lanes-saved must equal the analytic ledger's subtraction count
  exactly at every rounding (asserted below — the kernel really runs
  Algorithm 1's pairing, not an approximation of it).
"""
from __future__ import annotations

import numpy as np

from repro.core.cost_model import paper_table1
from repro.core.pairing import sweep_rounding
from repro.core.transform import build_conv_pairings
from repro.models.lenet import LENET_CONV_POSITIONS, LENET_CONV_SHAPES
from repro.train.lenet_trainer import get_trained_lenet

from benchmarks.common import fmt_table, write_result

ROUNDINGS = [0.0, 0.0001, 0.005, 0.01, 0.015, 0.02, 0.025, 0.05, 0.1, 0.15, 0.2, 0.25, 0.3]
# column-blocked kernel ledger block sizes: 1 == per-column (the paper),
# larger blocks trade pairing rate for activation bandwidth
KERNEL_BLOCK_NS = (1, 2, 4, 8)


def run(quick: bool = False) -> dict:
    params, _, _, info = get_trained_lenet(verbose=False)

    weights, positions = [], []
    for name, (shape, pos) in LENET_CONV_SHAPES.items():
        k = np.asarray(params[name]["w"], dtype=np.float64)
        H, W, Cin, Cout = k.shape
        weights.append(k.reshape(H * W * Cin, Cout))
        positions.append(pos)

    roundings = ROUNDINGS if not quick else [0.0, 0.01, 0.05, 0.3]
    ours = sweep_rounding(weights, positions, roundings)
    paper = {row["rounding"]: row for row in paper_table1()}

    # measured kernel ledgers per rounding: what the Pallas conv path would
    # execute at that rounding, for the structured pairing and for every
    # column-blocked block size (per-layer artifacts, then the kernel's own
    # op accounting).
    block_ns = KERNEL_BLOCK_NS if not quick else (1, 4)

    def measured_ledger(arts):
        counts = {n: a.measured_op_counts() for n, a in arts.items()}
        return {
            "per_layer": {
                n: {"n_pairs": arts[n].n_pairs, **c} for n, c in counts.items()
            },
            "subs_per_image": sum(c["subs_executed"] for c in counts.values()),
            "lanes_saved": sum(c["lanes_saved"] for c in counts.values()),
        }

    kernel_rows = {}
    for r in roundings:
        arts = build_conv_pairings(params, r, positions=LENET_CONV_POSITIONS)
        entry = measured_ledger(arts)
        entry["blocked"] = {}
        for bn in block_ns:
            barts = build_conv_pairings(
                params, r, positions=LENET_CONV_POSITIONS,
                mode="column_blocked", block_n=bn,
            )
            entry["blocked"][bn] = measured_ledger(barts)
        kernel_rows[r] = entry

    rows = []
    for r in ours:
        p = paper.get(r["rounding"], {})
        k = kernel_rows[r["rounding"]]
        blocked_cols = {
            f"b{bn}_lanes_saved": k["blocked"][bn]["lanes_saved"]
            for bn in block_ns
        }
        rows.append(
            {
                "rounding": r["rounding"],
                "adds": r["adds"],
                "subs": r["subs"],
                "mults": r["mults"],
                "total": r["total"],
                "paper_subs": p.get("subs", "-"),
                "paper_total": p.get("total", "-"),
                "kernel_subs": k["subs_per_image"],
                "kernel_lanes_saved": k["lanes_saved"],
                **blocked_cols,
            }
        )

    # structural invariants of Table I
    for r in ours:
        assert r["adds"] == r["mults"]
        assert r["adds"] + r["subs"] == 405600, (r, "baseline MACs must be 405600")
    for r, k in kernel_rows.items():
        baseline = sum(c["baseline_lanes"] for c in k["per_layer"].values())
        assert baseline == 405600, (r, "kernel baseline lanes must be 405600")
        # acceptance gate: the executed per-column pairing (block_n=1) IS the
        # analytic ledger — measured lanes saved must equal the analytic
        # subtraction count exactly at every rounding, layer by layer
        analytic = {row["rounding"]: row for row in ours}[r]
        b1 = k["blocked"][1]
        assert b1["lanes_saved"] == analytic["subs"], (
            f"r={r}: blocked(1) kernel ledger {b1['lanes_saved']} != "
            f"analytic per-column subs {analytic['subs']}"
        )
        # the spectrum is ordered: structured <= every block size <= per-col
        saved = [k["lanes_saved"]] + [
            k["blocked"][bn]["lanes_saved"] for bn in sorted(block_ns, reverse=True)
        ]
        assert all(a <= b for a, b in zip(saved, saved[1:], strict=False)), (r, saved)

    out = {
        "rows": rows,
        "kernel_measured": kernel_rows,
        "train_info": info,
        # lifted into BENCH_table1.json by benchmarks/run.py
        "perf_summary": {"kernel_op_counts_per_rounding": kernel_rows},
    }
    print(fmt_table(rows, list(rows[0].keys()), "Table I: op counts vs rounding (ours vs paper)"))
    write_result("table1", out)
    return out


if __name__ == "__main__":
    run()
