"""Table I reproduction: add/sub/mult counts vs rounding size for LeNet-5.

The paper counts the three convolutional layers only (their baseline of
405 600 multiplications = 117 600 + 240 000 + 48 000 MACs), pairing weights
*within each filter*.  We run the same accounting on our trained LeNet-5 and
print our ledger next to the paper's published one.  Counts differ in detail
(they depend on the trained weight values) but must match on structure:
adds == mults, adds + subs == 405 600, subs monotone in rounding.

Alongside the paper's analytic (per-column) ledger, each row also reports
what the TPU kernel path *measures*: the structured (shared-row) pairing
the Pallas paired-conv kernel executes — VPU subtracts per image and MXU
contraction lanes saved.  Structured pairing is stricter (one pairing shared
by every output channel), so its counts lower-bound the analytic ones.
"""
from __future__ import annotations

import numpy as np

from repro.core.cost_model import paper_table1
from repro.core.pairing import sweep_rounding
from repro.core.transform import build_conv_pairings
from repro.models.lenet import LENET_CONV_POSITIONS, LENET_CONV_SHAPES
from repro.train.lenet_trainer import get_trained_lenet

from benchmarks.common import fmt_table, write_result

ROUNDINGS = [0.0, 0.0001, 0.005, 0.01, 0.015, 0.02, 0.025, 0.05, 0.1, 0.15, 0.2, 0.25, 0.3]


def run(quick: bool = False) -> dict:
    params, _, _, info = get_trained_lenet(verbose=False)

    weights, positions = [], []
    for name, (shape, pos) in LENET_CONV_SHAPES.items():
        k = np.asarray(params[name]["w"], dtype=np.float64)
        H, W, Cin, Cout = k.shape
        weights.append(k.reshape(H * W * Cin, Cout))
        positions.append(pos)

    roundings = ROUNDINGS if not quick else [0.0, 0.01, 0.05, 0.3]
    ours = sweep_rounding(weights, positions, roundings)
    paper = {row["rounding"]: row for row in paper_table1()}

    # measured structured pairing per rounding: what the Pallas conv kernel
    # would execute at that rounding (per-layer artifacts, then the kernel's
    # own op accounting).
    kernel_rows = {}
    for r in roundings:
        arts = build_conv_pairings(params, r, positions=LENET_CONV_POSITIONS)
        counts = {n: a.measured_op_counts() for n, a in arts.items()}
        kernel_rows[r] = {
            "per_layer": {
                n: {"n_pairs": arts[n].n_pairs, **c} for n, c in counts.items()
            },
            "subs_per_image": sum(c["subs_executed"] for c in counts.values()),
            "lanes_saved": sum(c["lanes_saved"] for c in counts.values()),
        }

    rows = []
    for r in ours:
        p = paper.get(r["rounding"], {})
        k = kernel_rows[r["rounding"]]
        rows.append(
            {
                "rounding": r["rounding"],
                "adds": r["adds"],
                "subs": r["subs"],
                "mults": r["mults"],
                "total": r["total"],
                "paper_subs": p.get("subs", "-"),
                "paper_total": p.get("total", "-"),
                "kernel_subs": k["subs_per_image"],
                "kernel_lanes_saved": k["lanes_saved"],
            }
        )

    # structural invariants of Table I
    for r in ours:
        assert r["adds"] == r["mults"]
        assert r["adds"] + r["subs"] == 405600, (r, "baseline MACs must be 405600")
    for r, k in kernel_rows.items():
        baseline = sum(c["baseline_lanes"] for c in k["per_layer"].values())
        assert baseline == 405600, (r, "kernel baseline lanes must be 405600")

    out = {
        "rows": rows,
        "kernel_measured": kernel_rows,
        "train_info": info,
        # lifted into BENCH_table1.json by benchmarks/run.py
        "perf_summary": {"kernel_op_counts_per_rounding": kernel_rows},
    }
    print(fmt_table(rows, list(rows[0].keys()), "Table I: op counts vs rounding (ours vs paper)"))
    write_result("table1", out)
    return out


if __name__ == "__main__":
    run()
