"""Model-zoo pairing sweep: every assigned config family through the paired
path.

For each of the ten architecture families (dense / MoE / MLA / SSM / hybrid /
enc-dec / VLM) at toy scale:

1. **r=0 parity** — full ``lm_forward`` under
   ``PerfKnobs(gemm="pallas_paired", conv="pallas_paired")`` on a
   ``pair_params(params, 0.0)`` tree must match the plain XLA forward to
   ≤ 1e-5 relative error (fp32).  At rounding 0 the pairing criterion admits
   no pairs, every lane lands in the residual GEMM, and the subtractor
   kernel must reproduce the exact matmul — the correctness anchor for the
   whole spectrum.
2. **r=0.05 pairing-rate ledger** — per-column pairing at the paper's
   working rounding, reported per leaf and per family, asserting a nonzero
   rate everywhere including at least one MoE *expert* einsum (the
   stacked-expert-axis metadata `olmoe`/`deepseek` used to fall back from).

CI runs one family per matrix leg (``--family``) and merges the
``BENCH_model_zoo.json`` summaries into a single artifact.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from benchmarks.common import fmt_table, write_result
from repro.configs import ALL_ARCHS, get_smoke_config
from repro.core.transform import pair_params
from repro.kernels.ops import perf_context
from repro.launch.inputs import make_batch
from repro.models import lm as M
from repro.models.param import unzip

B, S = 2, 16
QUICK_FAMILIES = ("qwen2-1.5b", "olmoe-1b-7b")  # dense + MoE cover both kernels

_BASE = dict(q_chunk=8, k_chunk=8, remat="none")
KNOBS_XLA = M.PerfKnobs(**_BASE)
KNOBS_PAIRED = M.PerfKnobs(**_BASE, gemm="pallas_paired", conv="pallas_paired")

PARITY_TOL = 1e-5
LEDGER_ROUNDING = 0.05


def _is_expert_leaf(path: str) -> bool:
    return ".moe." in path and ".moe.shared." not in path


def _run_family(arch: str) -> dict:
    cfg = dataclasses.replace(get_smoke_config(arch), dtype="float32")
    params, _ = unzip(M.init_lm(cfg, jax.random.key(0)))
    batch = make_batch(cfg, B, S, "prefill")

    # -- r=0 parity: paired kernel path vs XLA einsum path -------------------
    paired0, rep0 = pair_params(
        params, 0.0, mode="structured", leaves=cfg.paired_leaves or None
    )
    want, _, _ = M.lm_forward(cfg, params, batch, knobs=KNOBS_XLA)
    with perf_context(KNOBS_PAIRED):
        got, _, _ = jax.jit(
            lambda p: M.lm_forward(cfg, p, batch, knobs=KNOBS_PAIRED)
        )(paired0)
    rel = float(jnp.abs(got - want).max() / jnp.abs(want).max())

    # -- r=0.05 per-column pairing-rate ledger -------------------------------
    _, rep = pair_params(
        params, LEDGER_ROUNDING, mode="per_column",
        leaves=cfg.paired_leaves or None,
    )
    leaves = [
        {
            "path": lf.path,
            "shape": list(lf.shape),
            "pair_fraction": lf.pair_fraction,
            "is_expert": _is_expert_leaf(lf.path),
        }
        for lf in rep.leaves
    ]
    expert_fracs = [l["pair_fraction"] for l in leaves if l["is_expert"]]
    return {
        "family": cfg.family,
        "parity_rel_err": rel,
        "parity_ok": rel <= PARITY_TOL,
        "pair_fraction_r005": rep.pair_fraction,
        "n_leaves": len(rep.leaves),
        "moe_expert_pair_fraction": max(expert_fracs) if expert_fracs else None,
        "leaves": leaves,
    }


def run(quick: bool = False, family: str | None = None) -> dict:
    if family is not None:
        if family not in ALL_ARCHS:
            raise ValueError(f"unknown family {family!r}; choose from {ALL_ARCHS}")
        families = (family,)
    else:
        families = QUICK_FAMILIES if quick else ALL_ARCHS

    rows = []
    fam_results: dict[str, dict] = {}
    failures: list[str] = []
    for arch in families:
        t0 = time.time()
        res = _run_family(arch)
        res["wall_clock_s"] = round(time.time() - t0, 2)
        fam_results[arch] = res
        rows.append(
            {
                "arch": arch,
                "family": res["family"],
                "rel_err_r0": res["parity_rel_err"],
                "pair_frac_r005": res["pair_fraction_r005"],
                "expert_frac": res["moe_expert_pair_fraction"] or "-",
                "leaves": res["n_leaves"],
            }
        )
        if not res["parity_ok"]:
            failures.append(
                f"{arch}: r=0 rel err {res['parity_rel_err']:.2e} > {PARITY_TOL:.0e}"
            )
        if not res["pair_fraction_r005"] > 0:
            failures.append(f"{arch}: zero pairing rate at r={LEDGER_ROUNDING}")
        if res["moe_expert_pair_fraction"] is not None and (
            not res["moe_expert_pair_fraction"] > 0
        ):
            failures.append(f"{arch}: MoE expert einsums pair nothing")

    print(fmt_table(
        rows,
        ["arch", "family", "rel_err_r0", "pair_frac_r005", "expert_frac", "leaves"],
        title=f"model zoo: r=0 parity + r={LEDGER_ROUNDING} per-column pairing rate",
    ))

    payload = {
        "rounding": LEDGER_ROUNDING,
        "parity_tol": PARITY_TOL,
        "families": fam_results,
        "failures": failures,
    }
    write_result("model_zoo", payload)
    if failures:
        raise AssertionError("; ".join(failures))
    return {
        "perf_summary": {
            "rounding": LEDGER_ROUNDING,
            "families": {
                a: {k: v for k, v in r.items() if k != "leaves"}
                for a, r in fam_results.items()
            },
        }
    }


if __name__ == "__main__":
    run()
