"""Mesh-sharded paired decode: token parity + per-shard ledger equality.

The distributed claim of the subtractor path is locality: tensor-parallel
splits of the projection weights cut across per-column pairing blocks, so the
``(Pmax, Rmax)`` metadata must be *built per shard* (no pair crosses a shard
boundary) and *placed beside its weight shard* — never regathered inside the
decode loop.  This bench gates both halves numerically:

1. **r = 0 token parity** — a 2×N mesh ServeEngine (CI runs it 2×4 under
   ``XLA_FLAGS=--xla_force_host_platform_device_count=8``) decodes the same
   prompts token-for-token as the single-host engine.  At r = 0 the paired
   kernel is exact, so any divergence is a sharding bug, not rounding.
2. **r = 0.05 ledger equality** — for every leaf the shard-aware build
   reports, the per-shard pair ledger must sum to the leaf's total; for
   column-sharded leaves (block-aligned splits don't constrain per-column
   pairing) the total must equal the single-host build's; and for one
   representative column-sharded (wq) and row-sharded (w_down) leaf the
   per-shard counts must equal *standalone* pairings of the corresponding
   weight slices — per-shard metadata is exactly what each device would have
   built from its local rows/columns.

The placement half (zero resharding of metadata inside the decode while-loop)
is the ``sharded_decode`` analysis target's job; this bench covers the math.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from benchmarks.common import fmt_table, write_result
from repro.configs import get_smoke_config
from repro.core.pairing import pair_rows_blocked
from repro.core.transform import pair_params, tp_shard_plan
from repro.launch.steps import abstract_params
from repro.models import lm as M
from repro.models.param import unzip
from repro.parallel.rules import rules_for
from repro.parallel.sharding import make_mesh_compat
from repro.serving.engine import ServeEngine

LEDGER_ROUNDING = 0.05


def _knobs(rounding: float) -> M.PerfKnobs:
    return M.PerfKnobs(
        q_chunk=16, k_chunk=16, remat="none",
        gemm="pallas_paired", pair_block_n=1, pair_rounding=rounding,
    )


def _gemm_stack(seg: dict, sub: str, name: str) -> np.ndarray:
    """(L, K, N) float64 GEMM view of one stacked decoder leaf."""
    arr = np.asarray(seg[sub][name], np.float64)
    L = arr.shape[0]
    if name == "wo":
        K = int(np.prod(arr.shape[1:-1]))
        return arr.reshape(L, K, arr.shape[-1])
    return arr.reshape(L, arr.shape[1], -1)


def _standalone_shard_ledger(
    mats: np.ndarray, rounding: float, rs: int, cs: int
) -> list[int]:
    """Per-shard weighted per-column pair counts from *standalone* builds on
    each shard's weight slice — the independent reference the shard-aware
    build's ledger must reproduce exactly."""
    L, K, N = mats.shape
    n_shards = max(rs, cs)
    totals = [0] * n_shards
    for m in mats:
        for s in range(n_shards):
            if cs > 1:
                sl = m[:, s * (N // cs):(s + 1) * (N // cs)]
            else:
                sl = m[s * (K // rs):(s + 1) * (K // rs), :]
            totals[s] += pair_rows_blocked(sl, rounding, 1).weighted_pairs
    return totals


def run(quick: bool = False) -> dict:
    n_dev = jax.device_count()
    mesh_shape = (2, n_dev // 2) if n_dev >= 4 else (1, n_dev)
    mesh = make_mesh_compat(mesh_shape, ("data", "model"))
    cfg = dataclasses.replace(get_smoke_config("qwen2-1.5b"), dtype="float32")
    params, _ = unzip(M.init_lm(cfg, jax.random.key(0)))
    failures: list[str] = []

    # -- 1) r=0 token parity: mesh engine vs single-host engine -------------
    n_steps = 4 if quick else 10
    rng = np.random.default_rng(0)
    prompts = {
        0: rng.integers(1, cfg.vocab, size=7).astype(np.int32),
        1: rng.integers(1, cfg.vocab, size=12).astype(np.int32),
    }
    ref = ServeEngine(cfg, params, max_seq=32, batch_size=2, knobs=_knobs(0.0))
    out_ref = ref.generate(dict(prompts), n_steps)
    t0 = time.time()
    eng = ServeEngine(
        cfg, params, max_seq=32, batch_size=2, knobs=_knobs(0.0), mesh=mesh
    )
    t_wire = time.time() - t0
    out_mesh = eng.generate(dict(prompts), n_steps)
    t0 = time.time()
    eng.step()
    t_step = time.time() - t0
    for slot in prompts:
        if out_ref[slot] != out_mesh[slot]:
            failures.append(
                f"r=0 token mismatch slot {slot}: single-host "
                f"{out_ref[slot]} vs mesh {out_mesh[slot]}"
            )

    # -- 2) r=0.05 per-shard ledger equality --------------------------------
    rules = rules_for(cfg, "decode", mesh)
    _, param_axes = abstract_params(cfg)
    plan = tp_shard_plan(
        param_axes, params, mesh, rules, leaves=cfg.paired_leaves
    )
    _, rep_mesh = pair_params(
        params, LEDGER_ROUNDING, mode="per_column",
        leaves=cfg.paired_leaves, shards=plan,
    )
    _, rep_single = pair_params(
        params, LEDGER_ROUNDING, mode="per_column", leaves=cfg.paired_leaves
    )
    single_by_path = {lr.path: lr for lr in rep_single.leaves}
    rows = []
    for lr in rep_mesh.leaves:
        single = single_by_path[lr.path]
        if lr.shard_pairs is not None and sum(lr.shard_pairs) != lr.n_pairs:
            failures.append(
                f"{lr.path}: shard ledger {lr.shard_pairs} sums to "
                f"{sum(lr.shard_pairs)} != total {lr.n_pairs}"
            )
        if lr.col_shards > 1 and lr.n_pairs != single.n_pairs:
            # a block-aligned column split never constrains per-column
            # pairing — the sharded total must equal the single-host total
            failures.append(
                f"{lr.path}: column-sharded total {lr.n_pairs} != "
                f"single-host {single.n_pairs}"
            )
        rows.append({
            "leaf": lr.path.split("].")[-1],
            "rs": lr.row_shards,
            "cs": lr.col_shards,
            "pairs": lr.n_pairs,
            "single_host": single.n_pairs,
            "pair_frac": lr.pair_fraction,
        })

    # -- 3) per-shard == standalone slice builds (wq column / w_down row) ---
    seg = params["segments"][0]
    slice_checks = []
    for sub, name in (("attn", "wq"), ("mlp", "w_down")):
        rs, cs = plan[(sub, name)]
        lr = next(
            l for l in rep_mesh.leaves if l.path.endswith(f"{sub}.{name}")
        )
        if max(rs, cs) > 1:
            want = _standalone_shard_ledger(
                _gemm_stack(seg, sub, name), LEDGER_ROUNDING, rs, cs
            )
            got = list(lr.shard_pairs or ())
            if got != want:
                failures.append(
                    f"{sub}.{name}: per-shard ledger {got} != standalone "
                    f"slice builds {want}"
                )
            slice_checks.append(
                {"leaf": f"{sub}.{name}", "rs": rs, "cs": cs,
                 "per_shard": got, "standalone": want}
            )

    print(fmt_table(
        rows, ["leaf", "rs", "cs", "pairs", "single_host", "pair_frac"],
        f"mesh_decode r={LEDGER_ROUNDING} shard ledger "
        f"(mesh {mesh_shape[0]}x{mesh_shape[1]})",
    ))
    sharded_leaves = sum(1 for r in rows if r["rs"] > 1 or r["cs"] > 1)
    print(
        f"[mesh_decode] {n_dev} device(s) as {mesh_shape}; r=0 parity over "
        f"{n_steps} steps x {len(prompts)} slots; {sharded_leaves}/{len(rows)}"
        f" leaves shard-built; wire {t_wire:.1f}s, decode step {t_step*1e3:.0f}ms"
    )

    payload = {
        "mesh": list(mesh_shape),
        "devices": n_dev,
        "rounding": LEDGER_ROUNDING,
        "parity_steps": n_steps,
        "parity_ok": not any("token mismatch" in f for f in failures),
        "ledger": rows,
        "slice_checks": slice_checks,
        "wire_seconds": t_wire,
        "decode_step_seconds": t_step,
        "failures": failures,
    }
    write_result("mesh_decode", payload)
    if failures:
        raise AssertionError("; ".join(failures))
    return {"perf_summary": payload}
