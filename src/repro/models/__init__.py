"""Model zoo: LeNet-5 (the paper's network) + the assigned LM-family archs."""

from repro.models.lenet import init_lenet, lenet_apply, LENET_CONV_POSITIONS  # noqa: F401
