"""LeNet-5, exactly the architecture of the paper's Fig. 2.

32x32x1 input → C1 conv 5x5x6 → pool → C3 conv 5x5x16 → pool →
C5 conv 5x5x120 (1x1 spatial) → F6 dense 84 → output dense 10 (softmax).

Conv MAC counts (valid padding, stride 1) reproduce the paper's Table-I
baseline of 405 600 multiplications:

    C1: 28·28·6·(5·5·1)   = 117 600
    C3: 10·10·16·(5·5·6)  = 240 000
    C5:  1·1·120·(5·5·16) =  48 000
                    total = 405 600

Pure-JAX functional implementation (params = pytree of numpy/jax arrays).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# (output spatial positions, kernel shape) per conv layer — used by Table I.
LENET_CONV_SHAPES = {
    "conv1": ((5, 5, 1, 6), 28 * 28),
    "conv2": ((5, 5, 6, 16), 10 * 10),
    "conv3": ((5, 5, 16, 120), 1 * 1),
}
LENET_CONV_POSITIONS = {k: pos for k, (_, pos) in LENET_CONV_SHAPES.items()}


def init_lenet(key: jax.Array, dtype=jnp.float32) -> dict:
    """He-initialised LeNet-5 parameters."""
    keys = jax.random.split(key, 5)

    def conv_init(k, shape):
        fan_in = shape[0] * shape[1] * shape[2]
        return (jax.random.normal(k, shape, dtype) * np.sqrt(2.0 / fan_in))

    def dense_init(k, shape):
        return jax.random.normal(k, shape, dtype) * np.sqrt(2.0 / shape[0])

    return {
        "conv1": {"w": conv_init(keys[0], (5, 5, 1, 6)), "b": jnp.zeros((6,), dtype)},
        "conv2": {"w": conv_init(keys[1], (5, 5, 6, 16)), "b": jnp.zeros((16,), dtype)},
        "conv3": {"w": conv_init(keys[2], (5, 5, 16, 120)), "b": jnp.zeros((120,), dtype)},
        "fc1": {"w": dense_init(keys[3], (120, 84)), "b": jnp.zeros((84,), dtype)},
        "fc2": {"w": dense_init(keys[4], (84, 10)), "b": jnp.zeros((10,), dtype)},
    }


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w,
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


CONV_IMPLS = ("xla", "im2col", "pallas_paired")


def _resolve_conv(conv_impl, paired, fuse_pool):
    """Fill conv dispatch choices from the thread-local policy (ops.pallas_conv)."""
    from repro.kernels import ops as kops

    pol = kops.current_conv_policy()
    impl = conv_impl or (pol.impl if pol is not None else "xla")
    if paired is None and pol is not None:
        paired = pol.paired
    if fuse_pool is None:
        fuse_pool = pol.fuse_pool if pol is not None else False
    blocks = {}
    if pol is not None and impl == "pallas_paired":
        blocks = dict(
            block_m=pol.block_m, block_n=pol.block_n, block_k=pol.block_k,
            interpret=pol.interpret,
        )
    assert impl in CONV_IMPLS, f"conv_impl must be one of {CONV_IMPLS}, got {impl!r}"
    if impl == "pallas_paired" and paired is None:
        raise ValueError(
            "conv_impl='pallas_paired' needs per-layer pairing artifacts: "
            "pass paired=build_conv_pairings(params, rounding) "
            "(repro.core.transform) or set them on the pallas_conv policy"
        )
    # the fused conv→pool epilogue only exists in the Pallas megakernel
    fuse_pool = bool(fuse_pool) and impl == "pallas_paired"
    return impl, paired, fuse_pool, blocks


def lenet_apply(
    params: dict,
    x: jax.Array,
    *,
    conv_impl: str | None = None,
    paired: dict | None = None,
    fuse_pool: bool | None = None,
) -> jax.Array:
    """Forward pass: x (N, 32, 32, 1) → logits (N, 10).

    ``conv_impl`` selects the conv lowering: ``"xla"`` (lax.conv, default),
    ``"im2col"`` (patch GEMM via XLA), or ``"pallas_paired"`` (patch GEMM
    through the fused subtractor kernel; needs ``paired`` —
    per-layer artifacts from ``repro.core.transform.build_conv_pairings``,
    built with either pairing mode: structured shared-row artifacts route to
    the shared-permutation kernel, column-blocked artifacts
    (``mode="column_blocked"``, down to the paper's per-column pairing at
    ``block_n=1``) route to the per-n-block kernel layout).
    ``fuse_pool`` (pallas_paired only) absorbs the 2×2 max-pool after
    conv1/conv2 into the kernel epilogue — the separate ``_maxpool2`` ops
    disappear and each conv layer makes exactly one (pooled) HBM writeback.
    ``None`` defers either choice to the thread-local ``pallas_conv``
    policy, so serving knobs can flip the implementation without touching
    call sites.  All paths are differentiable (the paired path carries a
    custom VJP).
    """
    from repro.kernels.paired_conv import conv_im2col, paired_conv

    impl, paired, fuse_pool, blocks = _resolve_conv(conv_impl, paired, fuse_pool)

    def conv(name, x, pool=False):
        w, b = params[name]["w"], params[name]["b"]
        if impl == "pallas_paired":
            # bias + relu (and, when fused, the 2×2 pool) run in the kernel
            # epilogue — a pooled layer writes HBM exactly once
            if pool and fuse_pool:
                return paired_conv(
                    x, w, b, pairing=paired[name], activation="relu",
                    pool="max2", **blocks,
                )
            y = paired_conv(
                x, w, b, pairing=paired[name], activation="relu", **blocks
            )
        elif impl == "im2col":
            y = conv_im2col(x, w, b, activation="relu")
        else:
            y = jax.nn.relu(_conv(x, w, b))
        return _maxpool2(y) if pool else y

    x = conv("conv1", x, pool=True)  # 28 → 14
    x = conv("conv2", x, pool=True)  # 10 → 5
    x = conv("conv3", x)  # 1
    x = x.reshape(x.shape[0], -1)  # (N, 120)
    x = jax.nn.relu(x @ params["fc1"]["w"] + params["fc1"]["b"])
    return x @ params["fc2"]["w"] + params["fc2"]["b"]


def lenet_loss(params: dict, images: jax.Array, labels: jax.Array):
    logits = lenet_apply(params, images)
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
    acc = (jnp.argmax(logits, axis=-1) == labels).mean()
    return loss, acc


def lenet_accuracy(
    params: dict,
    images,
    labels,
    batch: int = 512,
    *,
    conv_impl: str | None = None,
    paired: dict | None = None,
    fuse_pool: bool | None = None,
) -> float:
    """Full-dataset accuracy, batched to bound memory."""
    hits = 0

    @jax.jit
    def apply(p, xb):
        return lenet_apply(
            p, xb, conv_impl=conv_impl, paired=paired, fuse_pool=fuse_pool
        )

    for i in range(0, images.shape[0], batch):
        logits = apply(params, jnp.asarray(images[i : i + batch]))
        hits += int((jnp.argmax(logits, -1) == jnp.asarray(labels[i : i + batch])).sum())
    return hits / images.shape[0]
