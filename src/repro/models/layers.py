"""Layer zoo shared by all ten assigned architectures.

Everything is a pure function over plain value pytrees (see param.py for the
axes annotations made at init time).  Conventions:

* activations: (batch, seq, d_model) in the config compute dtype (bf16);
* all softmax / normalisation statistics accumulate in fp32;
* attention weights keep heads explicit — (d_model, heads, head_dim) — so the
  "q_heads"/"kv_heads" logical axes are shardable;
* every attention path goes through ``flash_attention`` (blocked online
  softmax — the pure-JAX analogue of the fused TPU kernel, so the lowered
  HLO has the memory profile the roofline analysis assumes) or through
  ``decode_attention`` (single query position against a cache).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.param import Param
from repro.parallel.sharding import constrain

# ---------------------------------------------------------------------------
# initialisers
# ---------------------------------------------------------------------------


def _dense_init(key, shape, axes, scale_dim=0, dtype=jnp.float32) -> Param:
    """Truncated-normal fan-in init annotated with logical axes."""
    fan_in = shape[scale_dim] if isinstance(scale_dim, int) else int(np.prod([shape[i] for i in scale_dim]))
    w = jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype) / math.sqrt(fan_in)
    return Param(w, axes)


def _zeros(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.zeros(shape, dtype), axes)


def _ones(shape, axes, dtype=jnp.float32) -> Param:
    return Param(jnp.ones(shape, dtype), axes)


# ---------------------------------------------------------------------------
# norms / rope / activations
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, d: int | None = None) -> dict:
    d = d or cfg.d_model
    p = {"scale": _ones((d,), ("embed",))}
    if cfg.norm == "layernorm":
        p["bias"] = _zeros((d,), ("embed",))
    return p


def apply_norm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if "bias" in p:  # layernorm
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        var = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(scale: jax.Array, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head RMS norm over head_dim (qwen3 qk_norm). x: (..., head_dim)."""
    xf = x.astype(jnp.float32)
    var = (xf * xf).mean(-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding, rotate-half convention.

    x: (B, S, H, D) with D even; positions: (B, S) int32.
    """
    d = x.shape[-1]
    freqs = (theta ** (-jnp.arange(0, d, 2, dtype=jnp.float32) / d))  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def activation(name: str, x: jax.Array) -> jax.Array:
    return jax.nn.silu(x) if name == "silu" else jax.nn.gelu(x)


def dense(x: jax.Array, w: jax.Array, bias: jax.Array | None = None,
          act: str | None = None, *, pairing: dict | None = None,
          residual: jax.Array | None = None) -> jax.Array:
    """GEMM over the last axis with optional bias + activation + residual.

    The single dispatch point between the XLA einsum path (default) and the
    K-tiled, epilogue-fused Pallas kernels:

    * a :func:`repro.kernels.ops.pallas_paired_gemm` policy
      (``PerfKnobs(gemm="pallas_paired")``) routes any call that carries
      ``pairing`` metadata (``core.transform.pair_lm_params``) through the
      *subtractor* kernel — pair magnitudes recomputed from the live ``w``,
      bias/activation/``residual`` all fused into the single writeback;
    * a :func:`repro.kernels.ops.pallas_gemm` policy
      (``PerfKnobs(gemm="pallas")``) routes the matmul + bias + activation
      through the plain fused kernel;
    * otherwise the XLA einsum path runs (``pairing`` is ignored there: the
      live weights ARE the r=0-exact reference the paired path is tested
      against).

    ``residual`` is an output-shaped skip connection added *after* the
    activation — on the paired path it executes inside the kernel epilogue,
    on the other paths as a plain add, so callers can thread their
    ``h + sublayer(x)`` through unconditionally.
    """
    from repro.kernels import ops as kops

    ppol = kops.current_paired_gemm_policy()
    if pairing is not None and ppol is not None:
        return kops.fused_paired_dense(
            x, w, pairing, bias, activation=act or "none", residual=residual,
            pair_block_n=ppol.pair_block_n,
            block_m=ppol.block_m, block_n=ppol.block_n, block_k=ppol.block_k,
            interpret=ppol.interpret,
        )
    pol = kops.current_gemm_policy()
    if pol is not None:
        y = kops.fused_dense(
            x, w, bias, activation=act or "none",
            block_m=pol.block_m, block_n=pol.block_n, block_k=pol.block_k,
            interpret=pol.interpret,
        )
        return y + residual.astype(y.dtype) if residual is not None else y
    y = jnp.einsum("...d,df->...f", x, w)
    if bias is not None:
        y = y + bias
    if act:
        y = activation(act, y)
    if residual is not None:
        y = y + residual.astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# flash attention (blocked online softmax, pure JAX)
# ---------------------------------------------------------------------------


def _block_mask(pos_q, pos_k, *, causal: bool, window: int, n_sink: int):
    """(Q, K) bool mask for one (q-block, k-block) pair of position vectors."""
    pq = pos_q[:, None]
    pk = pos_k[None, :]
    ok = jnp.ones(pq.shape[:1] + pk.shape[1:], bool)
    if causal:
        ok = pk <= pq
    if window:
        in_window = pk > pq - window
        if n_sink:
            in_window = in_window | (pk < n_sink)
        ok = ok & in_window
    return ok


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    n_sink: int = 0,
    q_offset: int = 0,
    q_chunk: int = 1024,
    k_chunk: int = 1024,
    causal_skip: bool = True,
) -> jax.Array:
    """Blocked attention with online softmax (fp32 statistics).

    q: (B, Sq, H, D);  k, v: (B, Sk, KH, D) with H = KH * G (GQA).
    Returns (B, Sq, H, D).  Memory high-water mark is one
    (B, KH, G, q_chunk, k_chunk) score block instead of (Sq, Sk).

    ``causal_skip``: for pure-causal attention the q-blocks are unrolled
    (their count is static) and each one scans only the KV blocks at or
    below its causal bound — fully-masked blocks are never computed.
    Halves score-block FLOPs + HBM traffic at Sq == Sk (§Perf iteration 2c).
    Sliding-window/sink cases keep the scanned path with masking.
    """
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    G = H // KH
    scale = 1.0 / math.sqrt(D)

    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    nq = -(-Sq // q_chunk)
    nk = -(-Sk // k_chunk)
    pad_q = nq * q_chunk - Sq
    pad_k = nk * k_chunk - Sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    qb = q.reshape(B, nq, q_chunk, KH, G, D).transpose(1, 0, 2, 3, 4, 5)
    kb = k.reshape(B, nk, k_chunk, KH, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, k_chunk, KH, D).transpose(1, 0, 2, 3, 4)

    pos_q_all = q_offset + jnp.arange(nq * q_chunk, dtype=jnp.int32)
    pos_k_all = jnp.arange(nk * k_chunk, dtype=jnp.int32)

    def q_block(args, nk_hi: int | None = None):
        # Everything inside this scope is what the Pallas kernel
        # (kernels/flash_attention.py) keeps in VMEM on the TPU target; the
        # dry-run's `attn_fused` accounting recognises the scope name.
        with jax.named_scope("flash_vmem"):
            return _q_block_inner(args, nk_hi)

    def _q_block_inner(args, nk_hi):
        qi, qblk = args  # qblk: (B, q_chunk, KH, G, D)
        pos_q = jax.lax.dynamic_slice_in_dim(pos_q_all, qi * q_chunk, q_chunk)

        # NOTE: both loop bodies are checkpointed — without this, reverse-mode
        # through the scan saves every (q_chunk, k_chunk) score/probability
        # block, i.e. O(Sq·Sk) residuals, exactly the quadratic buffer flash
        # attention exists to avoid (observed: +13 GiB/device on train_4k).
        @jax.checkpoint
        def kv_step(carry, kv):
            m, l, acc = carry
            ki, kblk, vblk = kv
            s = jnp.einsum(
                "bqkgd,bckd->bkgqc", qblk, kblk,
                preferred_element_type=jnp.float32,
            ) * scale  # (B, KH, G, q_chunk, k_chunk)
            pos_k = jax.lax.dynamic_slice_in_dim(pos_k_all, ki * k_chunk, k_chunk)
            ok = _block_mask(pos_q, pos_k, causal=causal, window=window, n_sink=n_sink)
            ok = ok & (pos_k < Sk)[None, :]  # padded keys are never attended
            s = jnp.where(ok[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(-1))
            # guard fully-masked rows (m_new == -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(ok[None, None, None], p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + p.sum(-1)
            pv = jnp.einsum(
                "bkgqc,bckd->bkgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KH, G, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KH, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KH, G, q_chunk, D), jnp.float32)
        lim = nk if nk_hi is None else nk_hi
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(lim), kb[:lim], vb[:lim])
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 3, 1, 2, 4)  # (B, q_chunk, KH, G, D)

    if causal and causal_skip and not window and Sq > q_chunk:
        # static unroll: q-block i only ever sees KV blocks up to its causal
        # bound — fully-masked blocks are never lowered at all.
        blocks = []
        for qi in range(nq):
            hi = min(nk, -(-(q_offset + (qi + 1) * q_chunk) // k_chunk))
            blocks.append(
                jax.checkpoint(lambda a, _hi=hi: q_block(a, _hi))(
                    (jnp.int32(qi), qb[qi])
                )
            )
        out = jnp.stack(blocks)  # (nq, B, q_chunk, KH, G, D)
    else:
        out = jax.lax.map(jax.checkpoint(q_block), (jnp.arange(nq), qb))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * q_chunk, H, D)
    return out[:, :Sq].astype(q.dtype)


def decode_attention(
    q: jax.Array,  # (B, 1, H, D)
    k_cache: jax.Array,  # (B, S, KH, D)
    v_cache: jax.Array,
    pos: jax.Array,  # (B,) current position of the new token
    *,
    window: int = 0,
    n_sink: int = 0,
) -> jax.Array:
    """Single-token attention against a (possibly longer) cache."""
    B, _, H, D = q.shape
    _, S, KH, _ = k_cache.shape
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, KH, G, D)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache, preferred_element_type=jnp.float32) * scale
    pk = jnp.arange(S, dtype=jnp.int32)[None, :]  # (1, S)
    ok = pk <= pos[:, None]
    if window:
        in_w = pk > (pos[:, None] - window)
        if n_sink:
            in_w = in_w | (pk < n_sink)
        ok = ok & in_w
    s = jnp.where(ok[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------


def init_attention(cfg: ModelConfig, key) -> dict:
    d, H, KH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, H, hd), ("embed", "q_heads", "head_dim")),
        "wk": _dense_init(ks[1], (d, KH, hd), ("embed", "kv_heads", "head_dim")),
        "wv": _dense_init(ks[2], (d, KH, hd), ("embed", "kv_heads", "head_dim")),
        "wo": _dense_init(ks[3], (H, hd, d), ("q_heads", "head_dim", "embed"), scale_dim=(0, 1)),
    }
    if cfg.qkv_bias:
        p["bq"] = _zeros((H, hd), ("q_heads", "head_dim"))
        p["bk"] = _zeros((KH, hd), ("kv_heads", "head_dim"))
        p["bv"] = _zeros((KH, hd), ("kv_heads", "head_dim"))
    if cfg.qk_norm:
        p["q_norm"] = _ones((hd,), ("head_dim",))
        p["k_norm"] = _ones((hd,), ("head_dim",))
    return p


def _qkv_post(cfg: ModelConfig, p: dict, q, k, v, positions: jax.Array):
    """Bias / qk-norm / rope applied to freshly projected (…, heads, hd)."""
    cdt = q.dtype
    if cfg.qkv_bias:
        q = q + p["bq"].astype(cdt)
        k = k + p["bk"].astype(cdt)
        v = v + p["bv"].astype(cdt)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q)
        k = rms_head_norm(p["k_norm"], k)
    if cfg.rope_theta:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _qkv(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array):
    cdt = x.dtype
    d = x.shape[-1]

    def proj(name):
        # flattened-head GEMM view so the projection goes through `dense`
        # (and with it the paired-kernel policy, when `name`'s weight
        # carries pair_lm_params metadata)
        w = p[name].astype(cdt)
        heads, hd = w.shape[-2], w.shape[-1]
        y = dense(x, w.reshape(d, heads * hd),
                  pairing=p.get(name + "_pairing"))
        return y.reshape(*x.shape[:-1], heads, hd)

    q, k, v = proj("wq"), proj("wk"), proj("wv")
    return _qkv_post(cfg, p, q, k, v, positions)


def _fused_qkv_proj(p: dict, x: jax.Array, ppol):
    """All three QKV projections as ONE subtractor launch, when possible.

    The q/k/v weights concatenate along their output columns and their
    *blocked* pairing metadata concatenates along the block axis (lane lists
    pad to a common Pmax/Rmax with masked zero lanes — exact, the zero-lane
    trick), so a single :func:`repro.kernels.ops.fused_paired_dense` call
    projects all three.  Requires every weight to carry 2-D blocked metadata
    and the block size to divide the wq/wv column counts so block boundaries
    stay on weight boundaries (always true per-column, ``pair_block_n=1``).
    Returns ``(q, k, v)`` shaped ``(…, heads, hd)`` or None when the layout
    doesn't allow the concatenation (caller falls back to per-weight calls).
    """
    names = ("wq", "wk", "wv")
    metas = [p.get(n + "_pairing") for n in names]
    if any(m is None or m["I"].ndim != 2 for m in metas):
        return None
    bn = ppol.pair_block_n
    d = x.shape[-1]
    ws = [p[n].astype(x.dtype).reshape(d, -1) for n in names]
    ns = [w.shape[1] for w in ws]
    if bn < 1 or ns[0] % bn or ns[1] % bn:
        return None
    pmax = max(m["I"].shape[1] for m in metas)
    rmax = max(m["resid"].shape[1] for m in metas)
    pad = lambda a, n: jnp.pad(a, ((0, 0), (0, n - a.shape[1])))
    meta = {
        key: jnp.concatenate(
            [pad(m[key], pmax if key in ("I", "J", "pair_mask") else rmax)
             for m in metas], axis=0)
        for key in ("I", "J", "pair_mask", "resid", "resid_mask")
    }
    from repro.kernels import ops as kops

    y = kops.fused_paired_dense(
        x, jnp.concatenate(ws, axis=1), meta,
        pair_block_n=bn, block_m=ppol.block_m, block_n=ppol.block_n,
        block_k=ppol.block_k, interpret=ppol.interpret,
    )
    yq, yk, yv = jnp.split(y, [ns[0], ns[0] + ns[1]], axis=-1)
    shape = lambda arr, n: arr.reshape(*x.shape[:-1], *p[n].shape[-2:])
    return shape(yq, "wq"), shape(yk, "wk"), shape(yv, "wv")


def attn_out_proj(p: dict, out: jax.Array,
                  residual: jax.Array | None = None) -> jax.Array:
    """Attention output projection through `dense`, flattened-head view.

    ``residual`` is the sublayer's skip connection (the pre-attention
    hidden state): under the paired-GEMM policy it fuses into the kernel's
    residual-add epilogue — the decoder's ``h + attn(x)`` stops being a
    standalone add — and on the XLA path it is the same plain add as
    before.
    """
    cdt = out.dtype
    wo = p["wo"].astype(cdt)
    H, hd, d = wo.shape
    o2 = out.reshape(*out.shape[:-2], H * hd)
    return dense(o2, wo.reshape(H * hd, d),
                 pairing=p.get("wo_pairing"), residual=residual)


def attention_block(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    n_sink: int = 0,
    q_chunk: int = 1024,
    k_chunk: int = 1024,
) -> jax.Array:
    """Full attention sublayer (projections + flash attention + out proj)."""
    q, k, v = _qkv(cfg, p, x, positions)
    q = constrain(q, "batch", None, "q_heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    out = flash_attention(
        q, k, v, causal=causal, window=window, n_sink=n_sink,
        q_chunk=q_chunk, k_chunk=k_chunk,
    )
    return attn_out_proj(p, out)


def attention_decode_block(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,  # (B, 1, d)
    cache: dict,  # {"k": (B, S, KH, hd), "v": ...}
    pos: jax.Array,  # (B,)
    *,
    window: int = 0,
    n_sink: int = 0,
    residual: jax.Array | None = None,
) -> tuple[jax.Array, dict]:
    from repro.kernels import ops as kops

    apol = kops.current_attn_policy()
    if apol is None or x.shape[1] != 1:
        q, k, v = _qkv(cfg, p, x, pos[:, None])
        B = x.shape[0]
        bidx = jnp.arange(B)
        k_cache = cache["k"].at[bidx, pos].set(k[:, 0].astype(cache["k"].dtype))
        v_cache = cache["v"].at[bidx, pos].set(v[:, 0].astype(cache["v"].dtype))
        out = decode_attention(q, k_cache, v_cache, pos, window=window, n_sink=n_sink)
        y = attn_out_proj(p, out, residual=residual)
        return y, {"k": k_cache, "v": v_cache}

    # fused decode path (PerfKnobs(attn="pallas_fused")): one subtractor
    # launch projects q|k|v together when the blocked metadata concatenates,
    # then one kernel runs attention + the paired out-projection + the
    # sublayer residual — the attended values never round-trip HBM between
    # the attention and the out-projection (kernels/decode_attention.py).
    ppol = kops.current_paired_gemm_policy()
    qkv = _fused_qkv_proj(p, x, ppol) if ppol is not None else None
    if qkv is None:
        q, k, v = _qkv(cfg, p, x, pos[:, None])
    else:
        q, k, v = _qkv_post(cfg, p, *qkv, pos[:, None])
    B = x.shape[0]
    bidx = jnp.arange(B)
    k_cache = cache["k"].at[bidx, pos].set(k[:, 0].astype(cache["k"].dtype))
    v_cache = cache["v"].at[bidx, pos].set(v[:, 0].astype(cache["v"].dtype))
    wo = p["wo"].astype(x.dtype)
    H, hd, d = wo.shape
    meta = p.get("wo_pairing") if ppol is not None else None
    y = kops.fused_attn_decode(
        q, k_cache, v_cache, pos, wo.reshape(H * hd, d), meta,
        residual=residual,
        pair_block_n=ppol.pair_block_n if ppol is not None else 0,
        window=window, n_sink=n_sink,
        k_chunk=apol.k_chunk, interpret=apol.interpret,
    )
    return y, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(cfg: ModelConfig, key) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    return {
        "wq": _dense_init(ks[0], (d, H, m.qk_nope_dim + m.qk_rope_dim), ("embed", "q_heads", "head_dim")),
        "w_dkv": _dense_init(ks[1], (d, m.kv_lora_rank), ("embed", "kv_lora")),
        "w_kr": _dense_init(ks[2], (d, m.qk_rope_dim), ("embed", "head_dim")),
        "w_uk": _dense_init(ks[3], (m.kv_lora_rank, H, m.qk_nope_dim), ("kv_lora", "q_heads", "head_dim")),
        "w_uv": _dense_init(ks[4], (m.kv_lora_rank, H, m.v_head_dim), ("kv_lora", "q_heads", "head_dim")),
        "wo": _dense_init(ks[5], (H, m.v_head_dim, d), ("q_heads", "head_dim", "embed"), scale_dim=(0, 1)),
        "kv_norm": _ones((m.kv_lora_rank,), ("kv_lora",)),
    }


def mla_block(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,
    positions: jax.Array,
    *,
    q_chunk: int = 1024,
    k_chunk: int = 1024,
) -> jax.Array:
    """Training/prefill MLA: materialise per-head K/V from the latent.

    The down-projections (wq/w_dkv/w_kr) and the out-projection route
    through `dense` in the flattened-head view — onto the subtractor kernel
    when their weights carry pair_params metadata — while the latent
    up-projections (w_uk/w_uv) stay as einsums (absorbed-matrix form)."""
    m = cfg.mla
    cdt = x.dtype
    d = x.shape[-1]
    H = cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    wq = p["wq"].astype(cdt)
    q = dense(x, wq.reshape(d, H * qk),
              pairing=p.get("wq_pairing")).reshape(*x.shape[:-1], H, qk)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    c_kv = dense(x, p["w_dkv"].astype(cdt), pairing=p.get("w_dkv_pairing"))
    c_kv = rms_head_norm(p["kv_norm"], c_kv)
    k_rope = dense(x, p["w_kr"].astype(cdt), pairing=p.get("w_kr_pairing"))
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (B,S,1,rope)

    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"].astype(cdt))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"].astype(cdt))

    H = cfg.n_heads
    k_rope_b = jnp.broadcast_to(k_rope, k_rope.shape[:2] + (H, m.qk_rope_dim))
    qc = jnp.concatenate([q_nope, q_rope], axis=-1)
    kc = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    # pad v to qk head dim so flash kernel shapes line up, then slice back
    out = flash_attention(qc, kc, jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qc.shape[-1] - v.shape[-1]))),
                          causal=True, q_chunk=q_chunk, k_chunk=k_chunk)
    out = out[..., : m.v_head_dim]
    return dense(out.reshape(*out.shape[:-2], H * m.v_head_dim),
                 p["wo"].astype(cdt).reshape(H * m.v_head_dim, d),
                 pairing=p.get("wo_pairing"))


def mla_decode_block(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,  # (B, 1, d)
    cache: dict,  # {"c_kv": (B, S, R), "k_rope": (B, S, rope)}
    pos: jax.Array,
) -> tuple[jax.Array, dict]:
    """Absorbed-matrix MLA decode: attention runs directly in the compressed
    latent space — the cache stores only (c_kv, k_rope), the paper's memory
    saving — W_uk is folded into the query and W_uv into the output."""
    m = cfg.mla
    cdt = x.dtype
    B = x.shape[0]
    d = x.shape[-1]
    H = cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    q = dense(x, p["wq"].astype(cdt).reshape(d, H * qk),
              pairing=p.get("wq_pairing")).reshape(*x.shape[:-1], H, qk)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = rope(q_rope, pos[:, None], cfg.rope_theta)

    c_new = dense(x, p["w_dkv"].astype(cdt), pairing=p.get("w_dkv_pairing"))
    c_new = rms_head_norm(p["kv_norm"], c_new)
    kr_new = rope(
        dense(x, p["w_kr"].astype(cdt), pairing=p.get("w_kr_pairing"))[:, :, None, :],
        pos[:, None], cfg.rope_theta,
    )[:, :, 0, :]

    bidx = jnp.arange(B)
    c_kv = cache["c_kv"].at[bidx, pos].set(c_new[:, 0].astype(cache["c_kv"].dtype))
    k_rope = cache["k_rope"].at[bidx, pos].set(kr_new[:, 0].astype(cache["k_rope"].dtype))

    # absorb W_uk: q_lat (B, H, R) = q_nope @ W_uk^T
    q_lat = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0], p["w_uk"].astype(cdt))
    s = jnp.einsum("bhr,bsr->bhs", q_lat, c_kv, preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bhk,bsk->bhs", q_rope[:, 0], k_rope, preferred_element_type=jnp.float32)
    s = s / math.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    S = c_kv.shape[1]
    ok = jnp.arange(S, dtype=jnp.int32)[None, :] <= pos[:, None]
    s = jnp.where(ok[:, None], s, -jnp.inf)
    pr = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", pr.astype(cdt), c_kv, preferred_element_type=jnp.float32).astype(cdt)
    # absorb W_uv into the output projection
    out = jnp.einsum("bhr,rhk->bhk", o_lat, p["w_uv"].astype(cdt))
    y = dense(out.reshape(B, H * m.v_head_dim),
              p["wo"].astype(cdt).reshape(H * m.v_head_dim, d),
              pairing=p.get("wo_pairing"))
    return y[:, None], {"c_kv": c_kv, "k_rope": k_rope}


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(cfg: ModelConfig, key, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(ks[0], (d, f), ("embed", "ff")),
        "w_up": _dense_init(ks[1], (d, f), ("embed", "ff")),
        "w_down": _dense_init(ks[2], (f, d), ("ff", "embed")),
    }


def mlp_block(cfg: ModelConfig, p: dict, x: jax.Array,
              residual: jax.Array | None = None) -> jax.Array:
    """Gated MLP; ``residual`` fuses the sublayer skip connection into the
    down-projection (kernel epilogue under the paired policy, plain add
    otherwise)."""
    cdt = x.dtype
    g = dense(x, p["w_gate"].astype(cdt), act=cfg.act,
              pairing=p.get("w_gate_pairing"))
    u = dense(x, p["w_up"].astype(cdt), pairing=p.get("w_up_pairing"))
    h = constrain(g * u, "batch", None, "ff")
    return dense(h, p["w_down"].astype(cdt),
                 pairing=p.get("w_down_pairing"), residual=residual)


# ---------------------------------------------------------------------------
# MoE (GShard-style top-k with capacity, sort-based dispatch)
# ---------------------------------------------------------------------------


def init_moe(cfg: ModelConfig, key) -> dict:
    mo = cfg.moe
    d, E, F = cfg.d_model, mo.n_experts, mo.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, E), ("embed", "experts")),
        "w_gate": _dense_init(ks[1], (E, d, F), ("experts", "embed", "expert_ff"), scale_dim=1),
        "w_up": _dense_init(ks[2], (E, d, F), ("experts", "embed", "expert_ff"), scale_dim=1),
        "w_down": _dense_init(ks[3], (E, F, d), ("experts", "expert_ff", "embed"), scale_dim=1),
    }
    if mo.n_shared:
        sub = jax.random.split(ks[4], 3)
        fs = F * mo.n_shared
        p["shared"] = {
            "w_gate": _dense_init(sub[0], (d, fs), ("embed", "ff")),
            "w_up": _dense_init(sub[1], (d, fs), ("embed", "ff")),
            "w_down": _dense_init(sub[2], (fs, d), ("ff", "embed")),
        }
    return p


def _moe_route(cfg, x, topi, topw):
    """Per-sequence routing: buffers + inverse maps. All ops carry the batch
    dim (per-sequence capacity), so nothing ever crosses sequences."""
    mo = cfg.moe
    B, S, d = x.shape
    E, K = mo.n_experts, mo.top_k
    C = max(1, int(math.ceil(S * K / E * mo.capacity_factor)))
    SK = S * K
    cdt = x.dtype
    bidx = jnp.arange(B, dtype=jnp.int32)[:, None]
    flat_e = topi.reshape(B, SK)
    sort_idx = jnp.argsort(flat_e, axis=-1).astype(jnp.int32)
    sorted_e = jnp.take_along_axis(flat_e, sort_idx, axis=-1)
    counts = jnp.zeros((B, E), jnp.int32).at[bidx, flat_e].add(1)
    starts = jnp.cumsum(counts, axis=-1) - counts
    pos_in_e = (
        jnp.arange(SK, dtype=jnp.int32)[None]
        - jnp.take_along_axis(starts, sorted_e, axis=-1)
    )
    keep = pos_in_e < C
    tok_of = (sort_idx // K).astype(jnp.int32)
    buf_slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)

    gathered = jnp.take_along_axis(x, tok_of[..., None], axis=1)
    xb = jnp.zeros((B, E * C + 1, d), cdt).at[bidx, buf_slot].set(gathered)
    xb = xb[:, : E * C].reshape(B, E, C, d)

    w_sorted = jnp.take_along_axis(topw.reshape(B, SK), sort_idx, axis=-1)
    inv_tok = jnp.full((B, E * C + 1), S, jnp.int32).at[bidx, buf_slot].set(tok_of)
    inv_w = jnp.zeros((B, E * C + 1), jnp.float32).at[bidx, buf_slot].set(
        w_sorted * keep
    )
    return xb, inv_tok[:, : E * C], inv_w[:, : E * C], counts, C


def _moe_combine(B, S, d, yb, inv_tok, inv_w, cdt):
    bidx = jnp.arange(B, dtype=jnp.int32)[:, None]
    EC = yb.shape[1] * yb.shape[2]
    contrib = yb.reshape(B, EC, d) * inv_w[..., None].astype(cdt)
    y2 = jnp.zeros((B, S + 1, d), cdt).at[bidx, inv_tok].add(contrib)
    return y2[:, :S]


def moe_block(cfg: ModelConfig, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss).

    Three dispatch paths, most-specific first:

    1. **shard_map** (a mesh with a model axis is active and E divides it) —
       the production path.  Routing runs replicated within each data row
       (it is cheap integer work), every model rank slices *its own* experts'
       buffers out of the local dispatch, computes its expert FFNs, scatters
       its partial outputs, and one psum over `model` closes the combine.
       No all-to-all is needed because activations are batch-sharded over
       `data` only (model ranks in a data row hold identical tokens).  This
       exists because the pjit-visible scatter formulation below makes XLA's
       SPMD partitioner replicate the dispatch buffers (observed on
       deepseek/train_4k: 328 GiB/device and 371 s of collective time —
       EXPERIMENTS.md §Perf).
    2. **dense** (T·K ≤ 2E, i.e. decode) — run every expert on every token;
       no capacity drops, dispatch overhead would dominate the tiny GEMMs.
    3. **pjit scatter** fallback (no mesh, e.g. smoke tests) — per-sequence
       sort-based dispatch.

    Tokens over per-sequence capacity are dropped (standard GShard
    trade-off)."""
    from repro.kernels import ops as kops

    mo = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = mo.n_experts, mo.top_k
    cdt = x.dtype

    # Expert GEMMs route through the subtractor kernel when pair_params
    # metadata is attached and a paired policy is active — experts map onto
    # the blocked kernel's column-block grid (shard_map path stays unpaired:
    # its per-rank expert slices would need per-rank metadata slicing).
    ppol = kops.current_paired_gemm_policy()
    paired = ppol is not None and "w_gate_pairing" in p
    ekw = dict(
        pair_block_n=ppol.pair_block_n, block_m=ppol.block_m,
        block_k=ppol.block_k, interpret=ppol.interpret,
    ) if paired else {}

    x2 = x.reshape(T, d)
    logits = jnp.einsum("td,de->te", x2.astype(jnp.float32), p["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)  # (T, E)
    topw, topi = jax.lax.top_k(gates, K)  # (T, K)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    if T * K <= 2 * E:
        # decode / tiny-batch path: run every expert densely — no capacity,
        # no token drops (what serving engines do for single-token steps,
        # where dispatch overhead would dominate the tiny GEMMs).
        if paired:
            g = kops.fused_paired_expert_dense(
                x2, p["w_gate"].astype(cdt), p["w_gate_pairing"],
                activation=cfg.act, **ekw)
            u = kops.fused_paired_expert_dense(
                x2, p["w_up"].astype(cdt), p["w_up_pairing"], **ekw)
            y_all = kops.fused_paired_expert_dense(
                jnp.moveaxis(g * u, 1, 0), p["w_down"].astype(cdt),
                p["w_down_pairing"], x_per_expert=True, **ekw)
        else:
            g = activation(cfg.act, jnp.einsum("td,edf->tef", x2, p["w_gate"].astype(cdt)))
            u = jnp.einsum("td,edf->tef", x2, p["w_up"].astype(cdt))
            y_all = jnp.einsum("tef,efd->ted", g * u, p["w_down"].astype(cdt))
        w_full = jnp.zeros((T, E), cdt)
        w_full = w_full.at[jnp.arange(T)[:, None], topi].set(topw.astype(cdt))
        y2 = jnp.einsum("ted,te->td", y_all, w_full)
        if mo.n_shared:
            sh = p["shared"]
            gs = dense(x2, sh["w_gate"].astype(cdt), act=cfg.act,
                       pairing=sh.get("w_gate_pairing"))
            us = dense(x2, sh["w_up"].astype(cdt), pairing=sh.get("w_up_pairing"))
            y2 = y2 + dense(gs * us, sh["w_down"].astype(cdt),
                            pairing=sh.get("w_down_pairing"))
        return y2.reshape(B, S, d), jnp.float32(0.0)

    # ---- choose the expert-compute path ------------------------------------
    from repro.parallel.sharding import current as _current_mesh_rules

    mesh, rules = _current_mesh_rules()
    model_axis = rules.mesh_axes("experts") if rules else None
    use_shard_map = (
        mesh is not None
        and isinstance(model_axis, str)
        and model_axis in mesh.axis_names
        and E % mesh.shape[model_axis] == 0
    )

    if use_shard_map:
        y2, counts = _moe_shard_map(cfg, p, x, topi, topw, mesh, rules, model_axis)
    else:
        x = constrain(x, "batch", None, None)
        xb, inv_tok, inv_w, counts, C = _moe_route(cfg, x, topi, topw)
        xb = constrain(xb, "batch", "experts", None, None)
        if paired:
            # experts-as-column-blocks: flatten the (B, C) token dims so every
            # expert's buffer is one row block of the blocked subtractor GEMM
            xe = xb.transpose(1, 0, 2, 3).reshape(E, B * C, d)
            g = kops.fused_paired_expert_dense(
                xe, p["w_gate"].astype(cdt), p["w_gate_pairing"],
                activation=cfg.act, x_per_expert=True, **ekw)
            u = kops.fused_paired_expert_dense(
                xe, p["w_up"].astype(cdt), p["w_up_pairing"],
                x_per_expert=True, **ekw)
            yb2 = kops.fused_paired_expert_dense(
                jnp.moveaxis(g * u, 1, 0), p["w_down"].astype(cdt),
                p["w_down_pairing"], x_per_expert=True, **ekw)
            yb = yb2.reshape(B, C, E, d).transpose(0, 2, 1, 3)
        else:
            g = activation(cfg.act, jnp.einsum("becd,edf->becf", xb, p["w_gate"].astype(cdt)))
            u = jnp.einsum("becd,edf->becf", xb, p["w_up"].astype(cdt))
            yb = jnp.einsum("becf,efd->becd", g * u, p["w_down"].astype(cdt))
        yb = constrain(yb, "batch", "experts", None, None)
        y2 = _moe_combine(B, S, d, yb, inv_tok, inv_w, cdt)
        counts = counts.sum(0)

    if mo.n_shared:
        sh = p["shared"]
        x3 = x.reshape(T, d)
        gs = dense(x3, sh["w_gate"].astype(cdt), act=cfg.act,
                   pairing=sh.get("w_gate_pairing"))
        us = dense(x3, sh["w_up"].astype(cdt), pairing=sh.get("w_up_pairing"))
        y2 = y2 + dense(gs * us, sh["w_down"].astype(cdt),
                        pairing=sh.get("w_down_pairing")).reshape(B, S, d)

    # ---- load-balance aux loss (Switch-style) -------------------------------
    me = gates.mean(0)  # mean router prob per expert
    ce = counts.astype(jnp.float32) / max(T * K, 1)  # dispatched fraction
    aux = (me * ce).sum() * (E * mo.router_aux_weight)

    return y2, aux


def _moe_shard_map(cfg, p, x, topi, topw, mesh, rules, model_axis):
    """Explicit-collective MoE: see moe_block docstring, path (1)."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    mo = cfg.moe
    B, S, d = x.shape
    E = mo.n_experts
    n_model = mesh.shape[model_axis]
    E_loc = E // n_model
    cdt = x.dtype
    batch_axes = rules.mesh_axes("batch")

    def body(x_l, topi_l, topw_l, wg_l, wu_l, wd_l):
        # x_l: (B_loc, S, d) — identical on every model rank of a data row.
        Bl = x_l.shape[0]
        xb, inv_tok, inv_w, counts, C = _moe_route(cfg, x_l, topi_l, topw_l)
        # my experts only
        e0 = jax.lax.axis_index(model_axis) * E_loc
        xb_mine = jax.lax.dynamic_slice_in_dim(xb, e0, E_loc, axis=1)
        g = activation(cfg.act, jnp.einsum("becd,edf->becf", xb_mine, wg_l.astype(cdt)))
        u = jnp.einsum("becd,edf->becf", xb_mine, wu_l.astype(cdt))
        yb = jnp.einsum("becf,efd->becd", g * u, wd_l.astype(cdt))
        # partial combine over my experts, then close the sum over `model`
        inv_tok_m = jax.lax.dynamic_slice_in_dim(
            inv_tok.reshape(Bl, E, C), e0, E_loc, axis=1
        ).reshape(Bl, E_loc * C)
        inv_w_m = jax.lax.dynamic_slice_in_dim(
            inv_w.reshape(Bl, E, C), e0, E_loc, axis=1
        ).reshape(Bl, E_loc * C)
        y2 = _moe_combine(Bl, S, d, yb, inv_tok_m, inv_w_m, cdt)
        y2 = jax.lax.psum(y2, model_axis)
        # (E,) global dispatch counts: sum local batch, then across data rows
        # (model peers hold identical counts, so no psum over model)
        counts = jax.lax.psum(counts.sum(0), batch_axes)
        return y2, counts

    bspec = P(batch_axes, None, None)
    y2, counts = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            bspec,
            P(batch_axes, None, None),
            P(batch_axes, None, None),
            P(model_axis, None, None),
            P(model_axis, None, None),
            P(model_axis, None, None),
        ),
        out_specs=(bspec, P()),
        check_rep=False,
    )(
        x,
        topi.reshape(B, S, -1),
        topw.reshape(B, S, -1).astype(jnp.float32),
        p["w_gate"],
        p["w_up"],
        p["w_down"],
    )
    return y2, counts


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) block
# ---------------------------------------------------------------------------


def init_ssm(cfg: ModelConfig, key) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    H = d_in // s.head_dim
    GN = s.n_groups * s.d_state
    ks = jax.random.split(key, 8)
    # dt bias: softplus^-1 of dt sampled log-uniform in [dt_min, dt_max]
    u = jax.random.uniform(ks[6], (H,))
    dt0 = jnp.exp(u * (math.log(s.dt_max) - math.log(s.dt_min)) + math.log(s.dt_min))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))
    return {
        "w_z": _dense_init(ks[0], (d, d_in), ("embed", "ssm_in")),
        "w_x": _dense_init(ks[1], (d, d_in), ("embed", "ssm_in")),
        "w_B": _dense_init(ks[2], (d, GN), ("embed", "ssm_state")),
        "w_C": _dense_init(ks[3], (d, GN), ("embed", "ssm_state")),
        "w_dt": _dense_init(ks[4], (d, H), ("embed", "ssm_heads")),
        "conv_x": Param(
            jax.random.normal(ks[5], (s.conv_width, d_in)) / math.sqrt(s.conv_width),
            ("conv", "ssm_in"),
        ),
        "conv_B": Param(jnp.zeros((s.conv_width, GN)).at[-1].set(1.0), ("conv", "ssm_state")),
        "conv_C": Param(jnp.zeros((s.conv_width, GN)).at[-1].set(1.0), ("conv", "ssm_state")),
        "A_log": Param(jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)), ("ssm_heads",)),
        "D": _ones((H,), ("ssm_heads",)),
        "dt_bias": Param(dt_bias, ("ssm_heads",)),
        "norm": _ones((d_in,), ("ssm_in",)),
        "w_out": _dense_init(ks[7], (d_in, d), ("ssm_in", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq. x: (B, S, C); w: (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1]] * w[i][None, None, :] for i in range(W)
    )
    return jax.nn.silu(out)


def _segsum_decay(dA_chunk: jax.Array) -> jax.Array:
    """Lower-triangular decay matrix L[q, t] = exp(sum_{t<i<=q} dA_i).

    dA_chunk: (..., Q). Returns (..., Q, Q) with zeros above the diagonal.
    """
    Q = dA_chunk.shape[-1]
    cs = jnp.cumsum(dA_chunk, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # sum over (t, q]
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_scan(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) (already softplus'd, positive)
    A: jax.Array,  # (H,) negative
    B_: jax.Array,  # (B, S, G, N)
    C_: jax.Array,  # (B, S, G, N)
    *,
    chunk: int,
    h0: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD (Mamba-2 Listing 1, matmul form): returns (y, h_final).

    y: (B, S, H, P); h_final: (B, H, P, N).
    """
    Bb, S, H, P = x.shape
    G, N = B_.shape[2], B_.shape[3]
    rep = H // G
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Q = chunk

    xc = x.reshape(Bb, nc, Q, H, P)
    dtc = dt.reshape(Bb, nc, Q, H).astype(jnp.float32)
    Bc = B_.reshape(Bb, nc, Q, G, N)
    Cc = C_.reshape(Bb, nc, Q, G, N)

    dA = dtc * A  # (B, nc, Q, H) negative
    cs = jnp.cumsum(dA, axis=2)  # within-chunk cumulative

    # ---- intra-chunk (diagonal blocks) --------------------------------------
    # scores[b,c,g,q,t] = C[q]·B[t]  (group-shared)
    scores = jnp.einsum("bcqgn,bctgn->bcgqt", Cc, Bc, preferred_element_type=jnp.float32)
    L = _segsum_decay(dA.transpose(0, 1, 3, 2))  # (B, nc, H, Q, Q)
    # group-shared scores broadcast over heads within a group
    Lg = L.reshape(Bb, nc, G, rep, Q, Q)
    sg = scores[:, :, :, None]  # (B, nc, G, 1, Q, Q)
    W = sg * Lg  # (B, nc, G, rep, Q, Q)
    xdt = xc.astype(jnp.float32) * dtc[..., None]  # (B, nc, Q, H, P)
    xdt_g = xdt.reshape(Bb, nc, Q, G, rep, P)
    y_diag = jnp.einsum("bcgrqt,bctgrp->bcqgrp", W, xdt_g)

    # ---- chunk states --------------------------------------------------------
    # decay from t to end of chunk: exp(cs[last] - cs[t])
    decay_end = jnp.exp(cs[:, :, -1:, :] - cs)  # (B, nc, Q, H)
    dg = (decay_end * dtc).reshape(Bb, nc, Q, G, rep)
    states = jnp.einsum("bctgn,bctgr,bctgrp->bcgrpn", Bc, dg, xc.reshape(Bb, nc, Q, G, rep, P).astype(jnp.float32))

    # ---- inter-chunk recurrence (sequential over chunks) ---------------------
    chunk_decay = jnp.exp(cs[:, :, -1, :])  # (B, nc, H)

    def step(h, inp):
        st, dec = inp  # st: (B, G, rep, P, N), dec: (B, H)
        h_new = h * dec.reshape(Bb, G, rep, 1, 1) + st
        return h_new, h  # emit state *before* this chunk

    h_init = (
        h0.reshape(Bb, G, rep, P, N)
        if h0 is not None
        else jnp.zeros((Bb, G, rep, P, N), jnp.float32)
    )
    h_last, h_prevs = jax.lax.scan(
        step,
        h_init,
        (states.transpose(1, 0, 2, 3, 4, 5), chunk_decay.transpose(1, 0, 2)),
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4, 5)  # (B, nc, G, rep, P, N)

    # ---- inter-chunk output ---------------------------------------------------
    decay_in = jnp.exp(cs)  # decay from chunk start to q (inclusive)
    din_g = decay_in.reshape(Bb, nc, Q, G, rep)
    y_off = jnp.einsum("bcqgn,bcqgr,bcgrpn->bcqgrp", Cc, din_g, h_prevs)

    y = (y_diag + y_off).reshape(Bb, nc * Q, H, P)[:, :S]
    return y.astype(x.dtype), h_last.reshape(Bb, H, P, N)


def ssm_block(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    """Mamba-2 block forward (training/prefill)."""
    s = cfg.ssm
    cdt = x.dtype
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim

    z = dense(x, p["w_z"].astype(cdt), pairing=p.get("w_z_pairing"))
    xi = dense(x, p["w_x"].astype(cdt), pairing=p.get("w_x_pairing"))
    Bi = dense(x, p["w_B"].astype(cdt), pairing=p.get("w_B_pairing"))
    Ci = dense(x, p["w_C"].astype(cdt), pairing=p.get("w_C_pairing"))
    dt = dense(x, p["w_dt"].astype(cdt), pairing=p.get("w_dt_pairing"))

    xi = _causal_conv(xi, p["conv_x"].astype(cdt))
    Bi = _causal_conv(Bi, p["conv_B"].astype(cdt))
    Ci = _causal_conv(Ci, p["conv_C"].astype(cdt))

    Bb, S = x.shape[:2]
    xh = xi.reshape(Bb, S, H, s.head_dim)
    Bg = Bi.reshape(Bb, S, s.n_groups, s.d_state)
    Cg = Ci.reshape(Bb, S, s.n_groups, s.d_state)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    y, _ = ssd_scan(xh, dtp, A, Bg, Cg, chunk=s.chunk)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(Bb, S, d_in).astype(cdt)

    # gated RMSNorm then output projection
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt((yf * yf).mean(-1, keepdims=True) + 1e-6) * p["norm"]).astype(cdt)
    return dense(y, p["w_out"].astype(cdt), pairing=p.get("w_out_pairing"))


def ssm_decode_block(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,  # (B, 1, d)
    cache: dict,  # {"h": (B,H,P,N) fp32, "conv_x": (B,W-1,d_in), "conv_B": .., "conv_C": ..}
    pos: jax.Array,  # (B,) — unused (state carries time), kept for interface parity
) -> tuple[jax.Array, dict]:
    s = cfg.ssm
    cdt = x.dtype
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    Bb = x.shape[0]

    z = dense(x, p["w_z"].astype(cdt), pairing=p.get("w_z_pairing"))[:, 0]
    xi = dense(x, p["w_x"].astype(cdt), pairing=p.get("w_x_pairing"))[:, 0]
    Bi = dense(x, p["w_B"].astype(cdt), pairing=p.get("w_B_pairing"))[:, 0]
    Ci = dense(x, p["w_C"].astype(cdt), pairing=p.get("w_C_pairing"))[:, 0]
    dt = dense(x, p["w_dt"].astype(cdt), pairing=p.get("w_dt_pairing"))[:, 0]

    def conv_step(cache_c, new, w):
        # cache_c: (B, W-1, C); new: (B, C)
        window = jnp.concatenate([cache_c, new[:, None]], axis=1)  # (B, W, C)
        out = jax.nn.silu((window * w[None]).sum(1))
        return out, window[:, 1:]

    xi, conv_x = conv_step(cache["conv_x"], xi, p["conv_x"].astype(cdt))
    Bi, conv_B = conv_step(cache["conv_B"], Bi, p["conv_B"].astype(cdt))
    Ci, conv_C = conv_step(cache["conv_C"], Ci, p["conv_C"].astype(cdt))

    xh = xi.reshape(Bb, H, s.head_dim).astype(jnp.float32)
    Bg = Bi.reshape(Bb, s.n_groups, s.d_state).astype(jnp.float32)
    Cg = Ci.reshape(Bb, s.n_groups, s.d_state).astype(jnp.float32)
    rep = H // s.n_groups
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, H)
    A = -jnp.exp(p["A_log"])

    h = cache["h"]  # (B, H, P, N) fp32
    dA = jnp.exp(dtp * A)  # (B, H)
    Brep = jnp.repeat(Bg, rep, axis=1)  # (B, H, N)
    Crep = jnp.repeat(Cg, rep, axis=1)
    Bx = jnp.einsum("bhp,bhn->bhpn", xh * dtp[..., None], Brep)
    h = h * dA[..., None, None] + Bx
    y = jnp.einsum("bhpn,bhn->bhp", h, Crep)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(Bb, d_in).astype(cdt)

    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt((yf * yf).mean(-1, keepdims=True) + 1e-6) * p["norm"]).astype(cdt)
    out = dense(y, p["w_out"].astype(cdt), pairing=p.get("w_out_pairing"))
    return out[:, None], {"h": h, "conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C}
