"""Parameters annotated with logical sharding axes.

Model init functions build trees whose leaves are ``Param(value, axes)``;
``unzip`` splits that into a plain value tree (used by forward / optimizer)
and an axes tree (used by parallel/sharding.py to build NamedShardings).
The axes names are *logical* ("embed", "ff", "q_heads", "experts", …);
per-(arch, mode) rule tables map them onto mesh axes.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax


@dataclasses.dataclass
class Param:
    value: Any  # array or ShapeDtypeStruct
    axes: tuple[str | None, ...]

    def __post_init__(self):
        shape = getattr(self.value, "shape", None)
        if shape is not None and len(shape) != len(self.axes):
            raise ValueError(f"axes {self.axes} do not match shape {shape}")


def is_param(x) -> bool:
    return isinstance(x, Param)


def unzip(tree: Any) -> tuple[Any, Any]:
    """Split a Param tree into (values, axes) with identical treedefs."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)
    return values, axes


PAIRING_META_AXIS = "pairing_meta"


def _meta_axes_for(leaf: Any, stacked: bool) -> tuple[str, ...]:
    nd = len(getattr(leaf, "shape", ()))
    if stacked and nd:
        return ("layers",) + (PAIRING_META_AXIS,) * (nd - 1)
    return (PAIRING_META_AXIS,) * nd


def pairing_axes(values: Any, axes: Any) -> Any:
    """Axes tree for a *paired* value tree.

    Mirrors ``axes`` (the :func:`unzip` axes of the unpaired params) onto the
    paired tree produced by ``core.transform.pair_params``: every
    ``"<name>_pairing"`` sibling dict gains axes tuples — ``"layers"`` on the
    stacked layer dim (when the sibling weight is layer-stacked) and
    :data:`PAIRING_META_AXIS` on every other dim — so the paired values and
    the returned axes share a treedef.  The base rule tables map
    ``"pairing_meta"`` to ``None`` (replicated is always a *correct*
    placement); ``parallel.sharding.paired_shardings_for`` then overrides the
    block axis of each metadata leaf from its sibling weight's resolved spec
    so metadata lands on the same device as the weight shard it indexes.
    """
    if isinstance(values, dict):
        out = {}
        for k, v in values.items():
            if k.endswith("_pairing") and not (
                isinstance(axes, dict) and k in axes
            ):
                w_axes = axes.get(k[: -len("_pairing")]) if isinstance(axes, dict) else None
                stacked = isinstance(w_axes, tuple) and w_axes[:1] == ("layers",)
                out[k] = jax.tree.map(
                    lambda leaf, s=stacked: _meta_axes_for(leaf, s), v
                )
            else:
                out[k] = pairing_axes(v, axes[k])
        return out
    if isinstance(values, list | tuple):
        return type(values)(pairing_axes(v, a) for v, a in zip(values, axes))
    return axes


def stack_params(trees: list[Any]) -> Any:
    """Stack a list of identical Param trees along a new leading "layers" axis
    (for lax.scan over a segment of identical layers)."""
    import jax.numpy as jnp

    def stack(*leaves: Param) -> Param:
        vals = [l.value for l in leaves]
        if isinstance(vals[0], jax.ShapeDtypeStruct):
            v = jax.ShapeDtypeStruct((len(vals),) + tuple(vals[0].shape), vals[0].dtype)
        else:
            v = jnp.stack(vals)
        return Param(v, ("layers",) + leaves[0].axes)

    return jax.tree.map(stack, *trees, is_leaf=is_param)
