"""Parameters annotated with logical sharding axes.

Model init functions build trees whose leaves are ``Param(value, axes)``;
``unzip`` splits that into a plain value tree (used by forward / optimizer)
and an axes tree (used by parallel/sharding.py to build NamedShardings).
The axes names are *logical* ("embed", "ff", "q_heads", "experts", …);
per-(arch, mode) rule tables map them onto mesh axes.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax


@dataclasses.dataclass
class Param:
    value: Any  # array or ShapeDtypeStruct
    axes: tuple[str | None, ...]

    def __post_init__(self):
        shape = getattr(self.value, "shape", None)
        if shape is not None and len(shape) != len(self.axes):
            raise ValueError(f"axes {self.axes} do not match shape {shape}")


def is_param(x) -> bool:
    return isinstance(x, Param)


def unzip(tree: Any) -> tuple[Any, Any]:
    """Split a Param tree into (values, axes) with identical treedefs."""
    values = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)
    return values, axes


def stack_params(trees: list[Any]) -> Any:
    """Stack a list of identical Param trees along a new leading "layers" axis
    (for lax.scan over a segment of identical layers)."""
    import jax.numpy as jnp

    def stack(*leaves: Param) -> Param:
        vals = [l.value for l in leaves]
        if isinstance(vals[0], jax.ShapeDtypeStruct):
            v = jax.ShapeDtypeStruct((len(vals),) + tuple(vals[0].shape), vals[0].dtype)
        else:
            v = jnp.stack(vals)
        return Param(v, ("layers",) + leaves[0].axes)

    return jax.tree.map(stack, *trees, is_leaf=is_param)
