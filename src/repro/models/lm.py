"""The unified LM: decoder-only / MoE / SSM / hybrid / enc-dec / VLM.

One ``init_lm`` + four step-level entry points cover every assigned
architecture:

* ``lm_loss``       — training forward + masked cross-entropy (+ MoE aux)
* ``prefill``       — inference prefill: logits for the last position and a
                      filled cache (collected as scan outputs, so the cache
                      layout *is* the (layers, batch, seq, …) scan layout)
* ``init_cache``    — empty cache ShapeDtype/array tree with logical axes
* ``decode_step``   — one new token against the cache (per-sequence positions)

The decoder stack is a list of *segments* (maximal runs of identical layer
kinds); each segment is one ``lax.scan`` over parameters stacked along a
leading "layers" axis.  ``PerfKnobs`` carries the schedule parameters the
§Perf hillclimb tunes (attention chunk sizes, remat policy).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.param import Param, stack_params
from repro.parallel.sharding import constrain


# ---------------------------------------------------------------------------
# knobs the perf loop tunes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PerfKnobs:
    q_chunk: int = 1024
    k_chunk: int = 1024
    remat: str = "full"  # full | dots | none
    ssd_chunk: int = 0  # 0 → config default
    xent_chunk: int = 512  # seq-chunked cross-entropy (0 → unchunked)
    precast: bool = False  # cast stacked matrices to bf16 before the scan —
    # measured NEGATIVE on mistral/train_4k (flops 2.2e15→4.4e15, §Perf it. 2)
    attn_fused: bool = False  # account flash-attention interiors as
    # VMEM-resident (the validated Pallas kernel replaces them on TPU);
    # launch/dryrun then adds the kernel's boundary HBM traffic analytically
    gemm: str = "xla"  # "xla" | "pallas" | "pallas_paired" — route layer
    # GEMMs (layers.dense) through the K-tiled epilogue-fused Pallas kernel
    # instead of XLA einsums; "pallas_paired" additionally routes every
    # weight carrying pair_params metadata (attention/MLA projections, MLP
    # and MoE-expert up/gate/down, SSM projections) through the *subtractor*
    # kernel, with the sublayer residual adds fused into the kernel epilogue
    pair_rounding: float = 0.0  # rounding size for the LM pairing artifacts
    # (gemm="pallas_paired"): ServeEngine builds pair_lm_params(params,
    # pair_rounding, mode from pair_block_n) when the params don't already
    # carry metadata.  0.0 pairs nothing but still exercises the full
    # permuted-gather + kernel path (the r=0 parity anchor)
    conv: str = "xla"  # "xla" | "im2col" | "pallas_paired" — conv lowering
    # (models.lenet consults the policy; LM archs have no 2-D convs, no-op)
    fuse_pool: bool = False  # conv→pool megakernel: absorb the 2×2 max-pool
    # into the paired-conv epilogue (pallas_paired only; one HBM writeback
    # per conv layer, no standalone pooling op in the schedule)
    attn: str = "xla"  # "xla" | "pallas_fused" — decode-attention lowering:
    # "pallas_fused" routes attention_decode_block through the fused Pallas
    # kernel (kernels.decode_attention): the single-token online softmax
    # runs in VMEM scratch and the paired out-projection + sublayer residual
    # execute in the kernel flush, so the attended values never round-trip
    # HBM; with pair_block_n >= 1 the q|k|v projections additionally
    # concatenate into one subtractor launch.  Prefill is unaffected.
    pair_block_n: int = 0  # pairing-mode spectrum for the subtractor paths:
    # 0 → structured (one shared-row pairing across all output channels);
    # n >= 1 → column-blocked (one pairing per n output channels, executed
    # by the blocked kernel; 1 == the paper's per-column pairing).  Smaller
    # blocks pair more lanes at equal rounding, at n_blocks× activation
    # bandwidth — see core.pairing.pair_rows_blocked.
    block_m: int = 0  # Pallas GEMM tile sizes; 0 → kernels.tuning heuristic
    block_n: int = 0
    block_k: int = 0
    tile_cache: str = ""  # path to a persisted kernels.tuning.TileCache;
    # measured winners there beat the VMEM heuristic ("" → heuristic only)


DEFAULT_KNOBS = PerfKnobs()


def _remat(fn, knobs: PerfKnobs):
    if knobs.remat == "none":
        return fn
    if knobs.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def padded_vocab(cfg: ModelConfig) -> int:
    """Vocab rounded up to 128 so the vocab axis shards on any mesh we use."""
    return ((cfg.vocab + 127) // 128) * 128


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(cfg: ModelConfig, kind: str, key) -> dict:
    ks = iter(jax.random.split(key, 8))
    p: dict[str, Any] = {"ln1": L.init_norm(cfg)}
    has_attn = kind in ("dense", "moe", "hybrid_full", "hybrid_swa", "encdec")
    if has_attn:
        p["attn"] = L.init_mla(cfg, next(ks)) if cfg.mla else L.init_attention(cfg, next(ks))
    if kind == "encdec":
        p["lnx"] = L.init_norm(cfg)
        p["xattn"] = L.init_attention(
            dataclasses.replace(cfg, qk_norm=False, qkv_bias=False), next(ks)
        )
    if kind in ("ssm", "hybrid_full", "hybrid_swa"):
        p["mamba"] = L.init_ssm(cfg, next(ks))
    if kind in ("hybrid_full", "hybrid_swa"):
        p["ln_attn_out"] = L.init_norm(cfg)
        p["ln_ssm_out"] = L.init_norm(cfg)
    # FFN
    if kind == "moe":
        p["ln2"] = L.init_norm(cfg)
        p["moe"] = L.init_moe(cfg, next(ks))
    elif kind == "dense" and cfg.moe is not None:
        p["ln2"] = L.init_norm(cfg)
        p["mlp"] = L.init_mlp(cfg, next(ks), d_ff=cfg.moe.d_ff_dense)
    elif kind != "ssm" and cfg.d_ff:
        p["ln2"] = L.init_norm(cfg)
        p["mlp"] = L.init_mlp(cfg, next(ks))
    return p


def init_lm(cfg: ModelConfig, key) -> dict:
    """Returns a Param tree (use param.unzip to split values/axes)."""
    Vp = padded_vocab(cfg)
    d = cfg.d_model
    n_keys = cfg.n_layers + (cfg.encoder.n_layers if cfg.encoder else 0) + 16
    keys = iter(jax.random.split(key, n_keys))

    tree: dict[str, Any] = {
        "embed": L._dense_init(next(keys), (Vp, d), ("vocab", "embed"), scale_dim=1),
        "final_norm": L.init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        tree["lm_head"] = L._dense_init(next(keys), (d, Vp), ("embed", "vocab"))
    if cfg.meta_tokens:
        tree["meta"] = Param(
            jax.random.normal(next(keys), (cfg.meta_tokens, d)) * 0.02, ("meta", "embed")
        )
    if cfg.vision_prefix:
        tree["vision_proj"] = L._dense_init(
            next(keys), (cfg.vision_embed_dim, d), ("head_dim", "embed")
        )

    segs = []
    for kind, count in cfg.segments():
        seg_kind = "encdec" if cfg.family == "encdec" else kind
        stacked = stack_params(
            [_init_layer(cfg, seg_kind, next(keys)) for _ in range(count)]
        )
        segs.append({"kind": seg_kind, "params": stacked})
    tree["segments"] = [s["params"] for s in segs]

    if cfg.encoder is not None:
        enc_layers = [
            {
                "ln1": L.init_norm(cfg),
                "attn": L.init_attention(
                    dataclasses.replace(cfg, qkv_bias=False, qk_norm=False), next(keys)
                ),
                "ln2": L.init_norm(cfg),
                "mlp": L.init_mlp(cfg, next(keys)),
            }
            for _ in range(cfg.encoder.n_layers)
        ]
        tree["encoder"] = {
            "segments": [stack_params(enc_layers)],
            "final_norm": L.init_norm(cfg),
        }
    return tree


def segment_kinds(cfg: ModelConfig) -> list[tuple[str, int]]:
    if cfg.family == "encdec":
        return [("encdec", n) for _, n in cfg.segments()]
    return list(cfg.segments())


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------


def _sinusoid(positions: jax.Array, d: int) -> jax.Array:
    """(B, S) → (B, S, d) sinusoidal embedding (whisper-style stub)."""
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1))
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def embed_tokens(cfg: ModelConfig, params: dict, tokens: jax.Array, cdt) -> jax.Array:
    h = jnp.take(params["embed"], tokens, axis=0).astype(cdt)
    return h * jnp.asarray(math.sqrt(cfg.d_model), cdt) if cfg.tie_embeddings else h


def lm_logits(cfg: ModelConfig, params: dict, h: jax.Array) -> jax.Array:
    h = L.apply_norm(params["final_norm"], h)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, params["embed"].astype(h.dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"].astype(h.dtype))
    Vp = logits.shape[-1]
    pad_mask = jnp.arange(Vp) >= cfg.vocab
    logits = jnp.where(pad_mask[None, None, :], -1e9, logits.astype(jnp.float32))
    return constrain(logits, "batch", None, "vocab")


# ---------------------------------------------------------------------------
# decoder layer forward (training / prefill)
# ---------------------------------------------------------------------------


def _window_for(cfg: ModelConfig, kind: str) -> int:
    if kind == "hybrid_swa":
        return cfg.sliding_window
    if kind in ("dense", "moe") and cfg.sliding_window:
        return cfg.sliding_window
    return 0


def layer_fwd(
    cfg: ModelConfig,
    kind: str,
    p: dict,
    h: jax.Array,
    positions: jax.Array,
    enc_out: jax.Array | None,
    knobs: PerfKnobs,
    collect_cache: bool = False,
):
    """Returns (h, aux, cache_entry or None)."""
    aux = jnp.float32(0.0)
    cache = None
    window = _window_for(cfg, kind)
    n_sink = cfg.meta_tokens

    x = L.apply_norm(p["ln1"], h)
    if kind == "ssm":
        y, ssm_cache = _ssm_with_cache(cfg, p["mamba"], x, collect_cache)
        h = h + y
        cache = ssm_cache
    elif kind in ("hybrid_full", "hybrid_swa"):
        attn_cache = None
        if cfg.mla:
            a = L.mla_block(cfg, p["attn"], x, positions, q_chunk=knobs.q_chunk, k_chunk=knobs.k_chunk)
        else:
            a, attn_cache = _attn_with_cache(
                cfg, p["attn"], x, positions, window, n_sink, knobs, collect_cache
            )
        m, ssm_cache = _ssm_with_cache(cfg, p["mamba"], x, collect_cache)
        y = 0.5 * (L.apply_norm(p["ln_attn_out"], a) + L.apply_norm(p["ln_ssm_out"], m))
        h = h + y
        if collect_cache:
            cache = {**(attn_cache or {}), **(ssm_cache or {})}
    else:  # dense / moe / encdec — attention first
        if cfg.mla:
            if collect_cache:
                a, cache = _mla_with_cache(cfg, p["attn"], x, positions, knobs)
            else:
                a = L.mla_block(cfg, p["attn"], x, positions, q_chunk=knobs.q_chunk, k_chunk=knobs.k_chunk)
            h = h + a
        else:
            # the skip connection rides the out-projection (fused into the
            # paired kernel's epilogue under gemm="pallas_paired")
            h, cache = _attn_with_cache(
                cfg, p["attn"], x, positions, window, n_sink, knobs,
                collect_cache, residual=h,
            )
        if kind == "encdec":
            xq = L.apply_norm(p["lnx"], h)
            # skip connection rides the paired out-projection epilogue
            h = _cross_attention(cfg, p["xattn"], xq, enc_out, knobs, residual=h)

    if "mlp" in p or "moe" in p:
        x2 = L.apply_norm(p["ln2"], h)
        if "moe" in p:
            y2, aux = L.moe_block(cfg, p["moe"], x2)
            h = h + y2
        else:
            h = L.mlp_block(cfg, p["mlp"], x2, residual=h)

    h = constrain(h, "batch", "seq", None)
    return h, aux, cache


def _attn_with_cache(cfg, p, x, positions, window, n_sink, knobs, collect_cache,
                     residual=None):
    q, k, v = L._qkv(cfg, p, x, positions)
    q = constrain(q, "batch", None, "q_heads", None)
    k = constrain(k, "batch", None, "kv_heads", None)
    v = constrain(v, "batch", None, "kv_heads", None)
    out = L.flash_attention(
        q, k, v, causal=True, window=window, n_sink=n_sink,
        q_chunk=knobs.q_chunk, k_chunk=knobs.k_chunk,
    )
    y = L.attn_out_proj(p, out, residual=residual)
    cache = None
    if collect_cache:
        k = constrain(k, "batch", "cache_seq", "kv_heads", "head_dim")
        v = constrain(v, "batch", "cache_seq", "kv_heads", "head_dim")
        cache = {"k": k, "v": v}
    return y, cache


def _mla_with_cache(cfg, p, x, positions, knobs):
    """MLA prefill that also emits the compressed (c_kv, k_rope) cache."""
    m = cfg.mla
    cdt = x.dtype
    d = x.shape[-1]
    H = cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    c_kv = L.dense(x, p["w_dkv"].astype(cdt), pairing=p.get("w_dkv_pairing"))
    c_kv = L.rms_head_norm(p["kv_norm"], c_kv)
    k_rope = L.dense(x, p["w_kr"].astype(cdt), pairing=p.get("w_kr_pairing"))
    k_rope = L.rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]

    q = L.dense(x, p["wq"].astype(cdt).reshape(d, H * qk),
                pairing=p.get("wq_pairing")).reshape(*x.shape[:-1], H, qk)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = L.rope(q_rope, positions, cfg.rope_theta)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uk"].astype(cdt))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["w_uv"].astype(cdt))
    k_rope_b = jnp.broadcast_to(k_rope[:, :, None, :], k_rope.shape[:2] + (H, m.qk_rope_dim))
    qc = jnp.concatenate([q_nope, q_rope], axis=-1)
    kc = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    out = L.flash_attention(
        qc, kc, jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qc.shape[-1] - v.shape[-1]))),
        causal=True, q_chunk=knobs.q_chunk, k_chunk=knobs.k_chunk,
    )[..., : m.v_head_dim]
    y = L.dense(out.reshape(*out.shape[:-2], H * m.v_head_dim),
                p["wo"].astype(cdt).reshape(H * m.v_head_dim, d),
                pairing=p.get("wo_pairing"))
    c_kv_c = constrain(c_kv, "batch", "cache_seq", "kv_lora")
    k_rope_c = constrain(k_rope, "batch", "cache_seq", "head_dim")
    return y, {"c_kv": c_kv_c, "k_rope": k_rope_c}


def _xattn_q(p, xq):
    """Cross-attention query projection through `layers.dense` so the wq
    pairing metadata (configs with xattn paired_leaves) reaches the
    subtractor kernel — the k/v projections run over the *encoder* output
    once at prefill and stay plain einsums."""
    cdt = xq.dtype
    d = xq.shape[-1]
    w = p["wq"].astype(cdt)
    h, hd = w.shape[-2:]
    q = L.dense(xq, w.reshape(d, h * hd), pairing=p.get("wq_pairing"))
    return q.reshape(*xq.shape[:-1], h, hd)


def _cross_attention(cfg, p, xq, enc_out, knobs, residual=None):
    cdt = xq.dtype
    q = _xattn_q(p, xq)
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(cdt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(cdt))
    out = L.flash_attention(q, k, v, causal=False, q_chunk=knobs.q_chunk, k_chunk=knobs.k_chunk)
    return L.attn_out_proj(p, out, residual=residual)


def _ssm_with_cache(cfg, p, x, collect_cache):
    if not collect_cache:
        return L.ssm_block(cfg, p, x), None
    # prefill: run the block but also emit (h_final, conv tails)
    s = cfg.ssm
    cdt = x.dtype
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    z = L.dense(x, p["w_z"].astype(cdt), pairing=p.get("w_z_pairing"))
    xi0 = L.dense(x, p["w_x"].astype(cdt), pairing=p.get("w_x_pairing"))
    Bi0 = L.dense(x, p["w_B"].astype(cdt), pairing=p.get("w_B_pairing"))
    Ci0 = L.dense(x, p["w_C"].astype(cdt), pairing=p.get("w_C_pairing"))
    dt = L.dense(x, p["w_dt"].astype(cdt), pairing=p.get("w_dt_pairing"))
    xi = L._causal_conv(xi0, p["conv_x"].astype(cdt))
    Bi = L._causal_conv(Bi0, p["conv_B"].astype(cdt))
    Ci = L._causal_conv(Ci0, p["conv_C"].astype(cdt))
    Bb, S = x.shape[:2]
    xh = xi.reshape(Bb, S, H, s.head_dim)
    Bg = Bi.reshape(Bb, S, s.n_groups, s.d_state)
    Cg = Ci.reshape(Bb, S, s.n_groups, s.d_state)
    dtp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, h_last = L.ssd_scan(xh, dtp, A, Bg, Cg, chunk=s.chunk)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(Bb, S, d_in).astype(cdt)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt((yf * yf).mean(-1, keepdims=True) + 1e-6) * p["norm"]).astype(cdt)
    out = L.dense(y, p["w_out"].astype(cdt), pairing=p.get("w_out_pairing"))
    W = s.conv_width
    cache = {
        "h": h_last,
        "conv_x": xi0[:, -(W - 1):],
        "conv_B": Bi0[:, -(W - 1):],
        "conv_C": Ci0[:, -(W - 1):],
    }
    return out, cache


# ---------------------------------------------------------------------------
# encoder (whisper)
# ---------------------------------------------------------------------------


def encoder_fwd(cfg: ModelConfig, enc_params: dict, frames: jax.Array, knobs: PerfKnobs) -> jax.Array:
    """frames: (B, F, d_model) precomputed frame embeddings (conv stub)."""
    cdt = frames.dtype
    B, F, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(F, dtype=jnp.int32), (B, F))
    h = frames + _sinusoid(pos, cfg.d_model).astype(cdt)

    def body(carry, lp):
        h = carry
        x = L.apply_norm(lp["ln1"], h)
        a = L.attention_block(
            dataclasses.replace(cfg, qkv_bias=False, qk_norm=False),
            lp["attn"], x, pos, causal=False,
            q_chunk=knobs.q_chunk, k_chunk=knobs.k_chunk,
        )
        h = h + a
        x2 = L.apply_norm(lp["ln2"], h)
        h = h + L.mlp_block(cfg, lp["mlp"], x2)
        return h, None

    for seg in enc_params["segments"]:
        h, _ = jax.lax.scan(_remat(body, knobs), h, seg)
    return L.apply_norm(enc_params["final_norm"], h)


# ---------------------------------------------------------------------------
# full forward (training / prefill)
# ---------------------------------------------------------------------------


def _prepare_inputs(cfg: ModelConfig, params: dict, batch: dict, knobs: PerfKnobs):
    """Embeds tokens (+ meta tokens / vision patches), runs encoder if any.

    Returns (h, positions, enc_out, logits_offset) where logits_offset is the
    number of prefix positions (meta tokens) to strip from outputs.
    """
    cdt = jnp.dtype(cfg.dtype)
    tokens = batch["tokens"]
    B, S = tokens.shape
    h = embed_tokens(cfg, params, tokens, cdt)

    if cfg.vision_prefix:
        patches = batch["patches"].astype(cdt)  # (B, P, vision_embed_dim)
        pe = jnp.einsum("bpe,ed->bpd", patches, params["vision_proj"].astype(cdt))
        h = jnp.concatenate([pe, h[:, cfg.vision_prefix :]], axis=1)

    offset = 0
    if cfg.meta_tokens:
        meta = jnp.broadcast_to(
            params["meta"].astype(cdt)[None], (B, cfg.meta_tokens, cfg.d_model)
        )
        h = jnp.concatenate([meta, h], axis=1)
        offset = cfg.meta_tokens

    St = h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(St, dtype=jnp.int32), (B, St))
    if cfg.family == "encdec":
        h = h + _sinusoid(positions, cfg.d_model).astype(cdt)

    enc_out = None
    if cfg.encoder is not None:
        enc_out = encoder_fwd(cfg, params["encoder"], batch["frames"].astype(cdt), knobs)

    h = constrain(h, "batch", "seq", None)
    return h, positions, enc_out, offset


def _precast_segments(cfg: ModelConfig, params: dict) -> dict:
    """Cast matrix params to the compute dtype once, *before* the layer scan.

    With FSDP (fp32 master weights 2-D sharded over data×model), casting
    inside the scan means the per-layer all-gather moves fp32 — and XLA may
    hoist the gather out of the loop, materializing the full fp32 stack per
    model shard (~30 GiB for the 123B config; EXPERIMENTS.md §Perf it. 2).
    Casting the stacked tree first halves gather bytes and keeps the hoisted
    buffer bf16.  Vector params (norm scales, biases, A_log, dt_bias) stay
    fp32 — they are tiny and precision-critical.
    """
    cdt = jnp.dtype(cfg.dtype)

    def cast(a):
        if hasattr(a, "dtype") and jnp.issubdtype(a.dtype, jnp.floating) and a.ndim >= 3:
            # >=3: every stacked (layers, ...) matrix; stacked vectors are 2-D
            return a.astype(cdt)
        return a

    out = dict(params)
    out["segments"] = jax.tree.map(cast, params["segments"])
    if "encoder" in params:
        enc = dict(params["encoder"])
        enc["segments"] = jax.tree.map(cast, params["encoder"]["segments"])
        out["encoder"] = enc
    return out


def lm_forward(
    cfg: ModelConfig,
    params: dict,
    batch: dict,
    *,
    knobs: PerfKnobs = DEFAULT_KNOBS,
    collect_cache: bool = False,
):
    """Returns (logits, aux, cache_or_None)."""
    if knobs.precast:
        params = _precast_segments(cfg, params)
    h, positions, enc_out, offset = _prepare_inputs(cfg, params, batch, knobs)
    kinds = segment_kinds(cfg)
    caches = []
    aux_total = jnp.float32(0.0)

    for (kind, _), seg_params in zip(kinds, params["segments"], strict=True):

        def body(carry, lp, _kind=kind):
            h, aux = carry
            h2, aux2, cache = layer_fwd(
                cfg, _kind, lp, h, positions, enc_out, knobs,
                collect_cache=collect_cache,
            )
            return (h2, aux + aux2), cache

        (h, aux_total), seg_cache = jax.lax.scan(
            _remat(body, knobs), (h, aux_total), seg_params
        )
        caches.append(seg_cache)

    if offset:
        h = h[:, offset:]
    logits = lm_logits(cfg, params, h)
    cache = caches if collect_cache else None
    return logits, aux_total, cache


def _hidden_for_loss(
    cfg: ModelConfig,
    params: dict,
    batch: dict,
    knobs: PerfKnobs,
):
    """Forward up to the (final-normed) hidden states, skipping the logits."""
    if knobs.precast:
        params = _precast_segments(cfg, params)
    h, positions, enc_out, offset = _prepare_inputs(cfg, params, batch, knobs)
    aux_total = jnp.float32(0.0)
    for (kind, _), seg_params in zip(segment_kinds(cfg), params["segments"], strict=True):

        def body(carry, lp, _kind=kind):
            h, aux = carry
            h2, aux2, _ = layer_fwd(cfg, _kind, lp, h, positions, enc_out, knobs)
            return (h2, aux + aux2), None

        (h, aux_total), _ = jax.lax.scan(_remat(body, knobs), (h, aux_total), seg_params)
    if offset:
        h = h[:, offset:]
    return L.apply_norm(params["final_norm"], h), aux_total


def chunked_xent(
    cfg: ModelConfig,
    params: dict,
    h: jax.Array,  # (B, S, d) final-normed hiddens
    labels: jax.Array,  # (B, S)
    mask: jax.Array,  # (B, S) float32
    chunk: int,
) -> jax.Array:
    """Sequence-chunked softmax cross-entropy.

    The (B, S, vocab) fp32 logits tensor never exists: each chunk's logits
    are built, reduced to (logsumexp, label-logit), and freed; the chunk body
    is checkpointed so the backward pass rebuilds chunk logits instead of
    saving them.  This is what keeps the 152k-vocab configs inside HBM.
    """
    B, S, d = h.shape
    W = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    Vp = W.shape[0] if cfg.tie_embeddings else W.shape[1]
    chunk = min(chunk, S) if chunk else S
    n = -(-S // chunk)
    pad = n * chunk - S
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hc = h.reshape(B, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n, chunk).transpose(1, 0, 2)
    mc = mask.reshape(B, n, chunk).transpose(1, 0, 2)

    vocab_ok = (jnp.arange(Vp) < cfg.vocab)

    @jax.checkpoint
    def body(acc, xs):
        hx, lx, mx = xs  # (B, chunk, d), (B, chunk), (B, chunk)
        if cfg.tie_embeddings:
            logits = jnp.einsum("bsd,vd->bsv", hx, W.astype(hx.dtype))
        else:
            logits = jnp.einsum("bsd,dv->bsv", hx, W.astype(hx.dtype))
        logits = jnp.where(vocab_ok[None, None, :], logits.astype(jnp.float32), -1e9)
        logits = constrain(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)  # (B, chunk)
        # masked-sum (not a dot_general) — partitions cleanly over the
        # sharded vocab axis with a single psum, no involuntary remat
        onehot = lx[..., None] == jnp.arange(Vp)[None, None, :]
        lab = jnp.where(onehot, logits, 0.0).sum(-1)
        return acc + ((lse - lab) * mx).sum(), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (hc, lc, mc))
    return total


def lm_loss(
    cfg: ModelConfig,
    params: dict,
    batch: dict,
    *,
    knobs: PerfKnobs = DEFAULT_KNOBS,
):
    """Masked next-token cross-entropy (+ router aux). Returns (loss, metrics)."""
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    if cfg.vision_prefix:  # patch positions carry no token labels
        pos = jnp.arange(labels.shape[1])[None, :]
        mask = mask * (pos >= cfg.vision_prefix)
    denom = jnp.maximum(mask.sum(), 1.0)

    h, aux = _hidden_for_loss(cfg, params, batch, knobs)
    labels_safe = jnp.maximum(labels, 0)
    total = chunked_xent(cfg, params, h, labels_safe, mask, knobs.xent_chunk)
    xent = total / denom
    loss = xent + aux
    return loss, {"xent": xent, "aux": aux}


# ---------------------------------------------------------------------------
# caches + decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int) -> dict:
    """Param tree (values + logical axes) for an empty decode cache.

    ``max_seq`` counts token positions; meta tokens extend it internally.
    """
    cdt = jnp.dtype(cfg.dtype)
    S = max_seq + cfg.meta_tokens
    segs = []
    for kind, count in segment_kinds(cfg):
        entry: dict[str, Param] = {}
        if kind in ("dense", "moe", "encdec", "hybrid_full", "hybrid_swa"):
            if cfg.mla:
                m = cfg.mla
                entry["c_kv"] = Param(
                    jnp.zeros((count, batch_size, S, m.kv_lora_rank), cdt),
                    ("layers", "batch", "cache_seq", "kv_lora"),
                )
                entry["k_rope"] = Param(
                    jnp.zeros((count, batch_size, S, m.qk_rope_dim), cdt),
                    ("layers", "batch", "cache_seq", "head_dim"),
                )
            else:
                KH, hd = cfg.n_kv_heads, cfg.head_dim
                # hybrid_swa layers get the same full-length (S) cache as
                # every other attention layer: the decode scatter writes at
                # absolute positions, so a window-sized ring buffer needs a
                # modular write index + rotated attention mask that do not
                # exist yet.  When that lands, allocate
                # min(S, window + meta_tokens + 1) rows here instead.
                Sc = S
                for name in ("k", "v"):
                    entry[name] = Param(
                        jnp.zeros((count, batch_size, Sc, KH, hd), cdt),
                        ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
                    )
        if kind in ("ssm", "hybrid_full", "hybrid_swa"):
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            H = d_in // s.head_dim
            GN = s.n_groups * s.d_state
            W = s.conv_width
            entry["h"] = Param(
                jnp.zeros((count, batch_size, H, s.head_dim, s.d_state), jnp.float32),
                ("layers", "batch", "ssm_heads", "head_dim", "ssm_state"),
            )
            entry["conv_x"] = Param(
                jnp.zeros((count, batch_size, W - 1, d_in), cdt),
                ("layers", "batch", "conv", "ssm_in"),
            )
            entry["conv_B"] = Param(
                jnp.zeros((count, batch_size, W - 1, GN), cdt),
                ("layers", "batch", "conv", "ssm_state"),
            )
            entry["conv_C"] = Param(
                jnp.zeros((count, batch_size, W - 1, GN), cdt),
                ("layers", "batch", "conv", "ssm_state"),
            )
        if kind == "encdec":
            F = cfg.encoder.frames
            KH, hd = cfg.n_kv_heads, cfg.head_dim
            for name in ("xk", "xv"):
                entry[name] = Param(
                    jnp.zeros((count, batch_size, F, KH, hd), cdt),
                    ("layers", "batch", "frames", "kv_heads", "head_dim"),
                )
        segs.append(entry)
    return {"segments": segs}


def layer_decode(
    cfg: ModelConfig,
    kind: str,
    p: dict,
    c: dict,
    h: jax.Array,  # (B, 1, d)
    pos: jax.Array,  # (B,) absolute position incl. meta offset
):
    window = _window_for(cfg, kind)
    n_sink = cfg.meta_tokens
    x = L.apply_norm(p["ln1"], h)
    c_out = dict(c)
    if kind == "ssm":
        y, ssm_c = L.ssm_decode_block(cfg, p["mamba"], x, c, pos)
        h = h + y
        c_out.update(ssm_c)
    elif kind in ("hybrid_full", "hybrid_swa"):
        a, attn_c = L.attention_decode_block(
            cfg, p["attn"], x, {"k": c["k"], "v": c["v"]}, pos,
            window=window, n_sink=n_sink,
        )
        m, ssm_c = L.ssm_decode_block(
            cfg, p["mamba"], x,
            {k: c[k] for k in ("h", "conv_x", "conv_B", "conv_C")}, pos,
        )
        y = 0.5 * (L.apply_norm(p["ln_attn_out"], a) + L.apply_norm(p["ln_ssm_out"], m))
        h = h + y
        c_out.update(attn_c)
        c_out.update(ssm_c)
    else:
        if cfg.mla:
            a, mla_c = L.mla_decode_block(
                cfg, p["attn"], x, {"c_kv": c["c_kv"], "k_rope": c["k_rope"]}, pos
            )
            c_out.update(mla_c)
            h = h + a
        else:
            # skip connection fused into the out-projection epilogue
            h, attn_c = L.attention_decode_block(
                cfg, p["attn"], x, {"k": c["k"], "v": c["v"]}, pos,
                window=window, n_sink=n_sink, residual=h,
            )
            c_out.update(attn_c)
        if kind == "encdec":
            xq = L.apply_norm(p["lnx"], h)
            # cross attention against the precomputed encoder K/V; the wq/wo
            # projections route through layers.dense so the xattn pairing
            # metadata reaches the subtractor kernel, with the skip
            # connection fused into the out-projection epilogue
            q = _xattn_q(p["xattn"], xq)
            out = L.decode_attention(
                q, c["xk"], c["xv"],
                jnp.full((h.shape[0],), c["xk"].shape[1] - 1, jnp.int32),
            )
            h = L.attn_out_proj(p["xattn"], out, residual=h)

    if "mlp" in p or "moe" in p:
        x2 = L.apply_norm(p["ln2"], h)
        if "moe" in p:
            y2, _ = L.moe_block(cfg, p["moe"], x2)
            h = h + y2
        else:
            h = L.mlp_block(cfg, p["mlp"], x2, residual=h)
    return h, c_out


def decode_step(
    cfg: ModelConfig,
    params: dict,
    cache: dict,
    tokens: jax.Array,  # (B, 1) the newest token
    pos: jax.Array,  # (B,) its position (0-based, token coordinates)
):
    """One decode step. Returns (logits (B, 1, V), new_cache)."""
    cdt = jnp.dtype(cfg.dtype)
    h = embed_tokens(cfg, params, tokens, cdt)
    pos_abs = pos + cfg.meta_tokens
    if cfg.family == "encdec":
        h = h + _sinusoid(pos_abs[:, None], cfg.d_model).astype(cdt)

    new_segs = []
    for (kind, _), seg_params, seg_cache in zip(
        segment_kinds(cfg), params["segments"], cache["segments"], strict=True
    ):

        def body(h, xs, _kind=kind):
            lp, c = xs
            h2, c2 = layer_decode(cfg, _kind, lp, c, h, pos_abs)
            return h2, c2

        h, seg_cache_new = jax.lax.scan(body, h, (seg_params, seg_cache))
        new_segs.append(seg_cache_new)

    logits = lm_logits(cfg, params, h)
    return logits, {"segments": new_segs}


# ---------------------------------------------------------------------------
# prefill (returns a serving-ready cache)
# ---------------------------------------------------------------------------


def prefill(
    cfg: ModelConfig,
    params: dict,
    batch: dict,
    *,
    knobs: PerfKnobs = DEFAULT_KNOBS,
):
    """Forward over the prompt; returns (last-position logits, cache).

    The cache tensors come straight out of the scan (layers-leading layout);
    SSM entries carry the final state, attention entries the full K/V.
    """
    logits, _, caches = lm_forward(cfg, params, batch, knobs=knobs, collect_cache=True)
    cache = {"segments": caches}
    if cfg.encoder is not None:
        # precompute cross K/V once per request
        cdt = jnp.dtype(cfg.dtype)
        enc_out = encoder_fwd(cfg, params["encoder"], batch["frames"].astype(cdt), knobs)
        for (kind, _), seg_params, entry in zip(
            segment_kinds(cfg), params["segments"], cache["segments"], strict=True
        ):
            if kind != "encdec":
                continue

            def xkv(lp):
                k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wk"].astype(cdt))
                v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wv"].astype(cdt))
                return k, v

            xk, xv = jax.vmap(xkv)(seg_params)  # over layers axis
            entry["xk"] = xk
            entry["xv"] = xv
    return logits[:, -1:], cache
