"""Data pipelines: MNIST (real or synthetic fallback) + LM token streams."""

from repro.data.mnist import load_mnist, synthetic_mnist  # noqa: F401
from repro.data.tokens import token_batches  # noqa: F401
