"""MNIST pipeline with a deterministic procedural fallback.

The paper evaluates LeNet-5 on MNIST.  This container has no network access,
so if the real IDX files are not present locally we fall back to a
*synthetic MNIST*: seven-segment style digit skeletons rasterised at 28x28
with random affine jitter, stroke thickness and pixel noise.  The fallback is
deterministic (seeded) and hard enough that the accuracy-vs-rounding trend of
the paper (Fig. 8) is measurable; EXPERIMENTS.md records which source was
used.

Real data is picked up automatically if the standard files
(train-images-idx3-ubyte etc., optionally .gz) exist in ``data_dir``,
``$MNIST_DIR``, ``/root/data/mnist`` or ``~/.cache/mnist``.
"""
from __future__ import annotations

import gzip
import os
import struct
from pathlib import Path

import numpy as np

_SEARCH_DIRS = ["/root/data/mnist", "~/.cache/mnist", "/root/data", "."]

_FILES = {
    "train": ("train-images-idx3-ubyte", "train-labels-idx1-ubyte"),
    "test": ("t10k-images-idx3-ubyte", "t10k-labels-idx1-ubyte"),
}

# ---------------------------------------------------------------------------
# Real MNIST (IDX format)
# ---------------------------------------------------------------------------


def _open_maybe_gz(path: Path):
    if path.exists():
        return open(path, "rb")
    gz = path.with_name(path.name + ".gz")
    if gz.exists():
        return gzip.open(gz, "rb")
    return None


def _read_idx(f) -> np.ndarray:
    magic, = struct.unpack(">I", f.read(4))
    ndim = magic & 0xFF
    shape = struct.unpack(">" + "I" * ndim, f.read(4 * ndim))
    return np.frombuffer(f.read(), dtype=np.uint8).reshape(shape)


def _try_load_real(split: str, data_dir: str | None):
    dirs = [data_dir] if data_dir else []
    if os.environ.get("MNIST_DIR"):
        dirs.append(os.environ["MNIST_DIR"])
    dirs.extend(_SEARCH_DIRS)
    img_name, lbl_name = _FILES[split]
    for d in dirs:
        if not d:
            continue
        base = Path(d).expanduser()
        fi = _open_maybe_gz(base / img_name)
        fl = _open_maybe_gz(base / lbl_name)
        if fi and fl:
            with fi, fl:
                images = _read_idx(fi).astype(np.float32) / 255.0
                labels = _read_idx(fl).astype(np.int32)
            return images[..., None], labels
    return None


# ---------------------------------------------------------------------------
# Synthetic fallback: jittered seven-segment digits
# ---------------------------------------------------------------------------

# segment endpoints in a unit box: (x0, y0, x1, y1); y grows downward
_SEGS = {
    "a": (0.2, 0.1, 0.8, 0.1),  # top
    "b": (0.8, 0.1, 0.8, 0.5),  # top-right
    "c": (0.8, 0.5, 0.8, 0.9),  # bottom-right
    "d": (0.2, 0.9, 0.8, 0.9),  # bottom
    "e": (0.2, 0.5, 0.2, 0.9),  # bottom-left
    "f": (0.2, 0.1, 0.2, 0.5),  # top-left
    "g": (0.2, 0.5, 0.8, 0.5),  # middle
}

_DIGIT_SEGS = {
    0: "abcdef",
    1: "bc",
    2: "abged",
    3: "abgcd",
    4: "fgbc",
    5: "afgcd",
    6: "afgedc",
    7: "abc",
    8: "abcdefg",
    9: "abcdfg",
}


def _raster_digit(digit: int, rng: np.random.Generator, size: int = 28) -> np.ndarray:
    """Rasterise one jittered seven-segment digit into (size, size) [0,1]."""
    img = np.zeros((size, size), dtype=np.float32)
    # random affine: scale, rotation, shift
    scale = rng.uniform(0.62, 0.92)
    theta = rng.uniform(-0.22, 0.22)
    cx, cy = rng.uniform(0.38, 0.62), rng.uniform(0.38, 0.62)
    ct, st_ = np.cos(theta), np.sin(theta)
    thick = rng.uniform(0.055, 0.095)
    seg_jit = rng.normal(0.0, 0.012, size=(7, 4))

    ys, xs = np.mgrid[0:size, 0:size]
    px = (xs + 0.5) / size
    py = (ys + 0.5) / size

    for si, seg in enumerate(_SEGS):
        if seg not in _DIGIT_SEGS[digit]:
            continue
        x0, y0, x1, y1 = np.array(_SEGS[seg]) + seg_jit[si % 7]
        # transform endpoints: center, rotate, scale, shift
        pts = []
        for (u, v) in ((x0, y0), (x1, y1)):
            u, v = u - 0.5, v - 0.5
            u, v = ct * u - st_ * v, st_ * u + ct * v
            pts.append((cx + scale * u, cy + scale * v))
        (ax, ay), (bx, by) = pts
        # distance from each pixel to the segment
        dx, dy = bx - ax, by - ay
        L2 = dx * dx + dy * dy + 1e-9
        t = np.clip(((px - ax) * dx + (py - ay) * dy) / L2, 0.0, 1.0)
        dist = np.sqrt((px - (ax + t * dx)) ** 2 + (py - (ay + t * dy)) ** 2)
        img = np.maximum(img, np.clip(1.2 - dist / thick, 0.0, 1.0))

    img = np.clip(img, 0.0, 1.0)
    img += rng.normal(0.0, 0.06, img.shape).astype(np.float32)
    return np.clip(img, 0.0, 1.0)


def synthetic_mnist(
    n: int, seed: int = 0, size: int = 28
) -> tuple[np.ndarray, np.ndarray]:
    """Deterministic synthetic digits: (n, size, size, 1) float32, (n,) int32."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    images = np.stack([_raster_digit(int(d), rng, size) for d in labels])
    return images[..., None], labels


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def load_mnist(
    split: str = "train",
    *,
    data_dir: str | None = None,
    synthetic_n: int | None = None,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray, str]:
    """Returns (images NHWC float32 [0,1], labels int32, source).

    source is "real" when IDX files were found, else "synthetic".
    """
    real = _try_load_real(split, data_dir)
    if real is not None:
        return real[0], real[1], "real"
    n = synthetic_n or (20000 if split == "train" else 4000)
    # different seeds per split so test is disjoint from train
    imgs, lbls = synthetic_mnist(n, seed=seed + (0 if split == "train" else 10_007))
    return imgs, lbls, "synthetic"


def pad_to_32(images: np.ndarray) -> np.ndarray:
    """LeNet-5 takes 32x32 inputs (paper Fig. 2); MNIST is 28x28 → pad."""
    return np.pad(images, ((0, 0), (2, 2), (2, 2), (0, 0)))


def batches(images, labels, batch_size: int, *, seed: int = 0, epochs: int = 1):
    """Simple shuffled minibatch iterator (host-side, deterministic)."""
    n = images.shape[0]
    rng = np.random.default_rng(seed)
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            sel = order[i : i + batch_size]
            yield images[sel], labels[sel]
