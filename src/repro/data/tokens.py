"""Deterministic synthetic LM token pipeline.

Generates shardable (tokens, labels) batches for the LM-family architectures.
The stream is a seeded Markov-ish sequence (so models can actually reduce
loss — unigram-uniform data would pin loss at log|V|): token t+1 is a hash
mix of t with occasional resets, giving learnable bigram structure.

At fleet scale each data-parallel worker calls ``token_batches`` with its own
``shard_index / shard_count``; batches are deterministic functions of
(seed, step, shard), which is what makes checkpoint-resume and elastic
re-sharding reproducible — a restarted (or re-sized) job regenerates exactly
the stream it needs from the step counter alone.
"""
from __future__ import annotations

from collections.abc import Iterator

import numpy as np


def _mix(x: np.ndarray, salt: int) -> np.ndarray:
    """splitmix64-style integer hash (vectorised, uint64)."""
    z = x.astype(np.uint64) + np.uint64(0x9E3779B97F4A7C15 + salt)
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


def synthetic_tokens(
    batch: int, seq_len: int, vocab: int, *, seed: int, step: int, shard: int = 0
) -> np.ndarray:
    """(batch, seq_len+1) int32 tokens; deterministic in (seed, step, shard)."""
    n = batch * (seq_len + 1)
    base = (
        np.uint64(seed) * np.uint64(1_000_003)
        + np.uint64(step) * np.uint64(7_777_777)
        + np.uint64(shard) * np.uint64(104_729)
    )
    idx = np.arange(n, dtype=np.uint64) + base
    # bigram structure: token i depends on hash(i // 2) so consecutive pairs
    # correlate; a model can learn this far below log|V|.
    stream = _mix(idx >> np.uint64(1), 17) % np.uint64(max(vocab - 1, 1))
    noise = _mix(idx, 29) % np.uint64(max(vocab - 1, 1))
    take_noise = (_mix(idx, 43) % np.uint64(5)) == 0
    toks = np.where(take_noise, noise, stream).astype(np.int64) % vocab
    return toks.reshape(batch, seq_len + 1).astype(np.int32)


def token_batches(
    batch: int,
    seq_len: int,
    vocab: int,
    *,
    seed: int = 0,
    start_step: int = 0,
    shard_index: int = 0,
    shard_count: int = 1,
) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Yields (tokens, labels) = (B, S), (B, S) forever, deterministically.

    ``shard_index/shard_count`` partition the *batch* dimension so data
    parallel workers see disjoint streams; resizing shard_count re-partitions
    the same global stream (elastic scaling keeps determinism per step).
    """
    assert batch % shard_count == 0, "global batch must divide by shard count"
    local = batch // shard_count
    step = start_step
    while True:
        full = synthetic_tokens(batch, seq_len, vocab, seed=seed, step=step, shard=0)
        mine = full[shard_index * local : (shard_index + 1) * local]
        yield mine[:, :-1], mine[:, 1:]
        step += 1
