"""Analysis targets: build the :class:`RuleContext` each CLI target exposes.

Each target traces (and where useful, compiles) one real inference path with
the same policy plumbing the benches and the serving engine use, then
declares what the schedule *must* look like — the expectations the error
rules gate on.  Weights are fresh inits: every shipped rule checks structure
(schedule, dtypes, block specs, index validity), none of which depends on
trained values, so the CLI stays fast enough for a CI job.
"""
from __future__ import annotations

import dataclasses as dc

from repro.analysis.core import RuleContext

TARGETS = ("lenet_fused", "lm_decode", "serve_step", "serve_frontend",
           "fused_attn_decode", "model_zoo", "sharded_decode")

# paired decode routes exactly the LM_PAIRED_WEIGHTS GEMMs (attention
# q/k/v/out + MLP gate/up/down) through the subtractor kernel — one HBM
# writeback each per layer
_DECODE_WRITEBACKS_PER_LAYER = 7

# with attn="pallas_fused" the three QKV projections concatenate into one
# subtractor launch and the attention + out-projection + residual fuse into
# the decode-attention kernel: qkv + attn·out + MLP gate/up/down — the
# attended values never reach HBM between attention and the out-projection
_FUSED_DECODE_WRITEBACKS_PER_LAYER = 5


def _paired_knobs():
    from repro.models import lm as M

    return M.PerfKnobs(
        q_chunk=16, k_chunk=16, remat="none",
        gemm="pallas_paired", pair_block_n=1, pair_rounding=0.05,
    )


def _smoke_lm_cfg():
    from repro.configs import get_smoke_config

    # fp32 keeps the target aligned with the parity benches (the bf16
    # subtractor dtype rule is exercised by the test suite's bf16 kernels)
    return dc.replace(get_smoke_config("qwen2-1.5b"), dtype="float32")


def _paired_lm_pieces():
    """(cfg, paired params, cache, tokens, pos, knobs) shared by the two LM
    targets."""
    import jax
    import jax.numpy as jnp

    from repro.core.transform import pair_lm_params
    from repro.models import lm as M
    from repro.models.param import unzip

    cfg = _smoke_lm_cfg()
    params, _ = unzip(M.init_lm(cfg, jax.random.key(0)))
    pm, _ = pair_lm_params(params, 0.05, mode="per_column")
    cache, _ = unzip(M.init_cache(cfg, 2, 32))
    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.asarray([5, 11], jnp.int32)
    return cfg, pm, cache, tok, pos, _paired_knobs()


def build_lenet_fused() -> RuleContext:
    """Fused conv→pool LeNet forward on the paired Pallas path."""
    import jax
    import jax.numpy as jnp

    from repro.core.transform import build_conv_pairings
    from repro.models.lenet import LENET_CONV_POSITIONS, init_lenet, lenet_apply

    params = init_lenet(jax.random.key(0))
    arts = build_conv_pairings(params, 0.0, positions=LENET_CONV_POSITIONS)
    x = jnp.zeros((4, 32, 32, 1), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda p, xb: lenet_apply(
            p, xb, conv_impl="pallas_paired", paired=arts, fuse_pool=True
        )
    )(params, x)
    return RuleContext(
        target="lenet_fused",
        jaxpr=jaxpr,
        pairing_artifacts=arts,
        expect={
            "fused_pool": True,
            # one megakernel writeback per conv layer, nothing else
            "pallas_calls": len(arts),
        },
    )


def build_lm_decode() -> RuleContext:
    """Single-host paired LM decode step (the ServeEngine path)."""
    import jax

    from repro.kernels.ops import perf_context
    from repro.models import lm as M

    cfg, pm, cache, tok, pos, knobs = _paired_lm_pieces()

    def step(p, c, t, s):
        with perf_context(knobs):
            return M.decode_step(cfg, p, c, t, s)

    with perf_context(knobs):
        jaxpr = jax.make_jaxpr(
            lambda p, c, t, s: M.decode_step(cfg, p, c, t, s)
        )(pm, cache, tok, pos)
    hlo = jax.jit(step).lower(pm, cache, tok, pos).compile().as_text()
    return RuleContext(
        target="lm_decode",
        jaxpr=jaxpr,
        hlo_text=hlo,
        params=pm,
        hidden_shape=(2, 1, cfg.d_model),
        expect={
            "residual_adds": 0,
            "writebacks_per_layer": _DECODE_WRITEBACKS_PER_LAYER,
            "pallas_calls": _DECODE_WRITEBACKS_PER_LAYER,  # all inside the scan
        },
    )


def build_fused_attn_decode() -> RuleContext:
    """The fused-attention paired decode step (``attn="pallas_fused"``):
    the decode-attention kernel consumes the KV cache and applies the
    paired out-projection + sublayer residual in its flush, and the q|k|v
    projections run as one concatenated subtractor launch — five HBM
    writebacks per scanned layer instead of the unfused seven, with the
    attended values never materialized in HBM."""
    import jax

    from repro.kernels.ops import perf_context
    from repro.models import lm as M

    cfg, pm, cache, tok, pos, knobs = _paired_lm_pieces()
    knobs = dc.replace(knobs, attn="pallas_fused")

    def step(p, c, t, s):
        with perf_context(knobs):
            return M.decode_step(cfg, p, c, t, s)

    with perf_context(knobs):
        jaxpr = jax.make_jaxpr(
            lambda p, c, t, s: M.decode_step(cfg, p, c, t, s)
        )(pm, cache, tok, pos)
    hlo = jax.jit(step).lower(pm, cache, tok, pos).compile().as_text()
    return RuleContext(
        target="fused_attn_decode",
        jaxpr=jaxpr,
        hlo_text=hlo,
        params=pm,
        hidden_shape=(2, 1, cfg.d_model),
        expect={
            "residual_adds": 0,
            "writebacks_per_layer": _FUSED_DECODE_WRITEBACKS_PER_LAYER,
            "pallas_calls": _FUSED_DECODE_WRITEBACKS_PER_LAYER,
        },
    )


def build_serve_step() -> RuleContext:
    """The pjit'd distributed serve step (mesh + sharding rules active)."""
    import jax

    from repro.launch.steps import build_serve_step as make_step
    from repro.parallel.rules import rules_for
    from repro.parallel.sharding import make_mesh_compat, set_mesh_compat

    cfg, pm, cache, tok, pos, knobs = _paired_lm_pieces()
    mesh = make_mesh_compat((1, jax.device_count()), ("data", "model"))
    rules = rules_for(cfg, "decode", mesh)
    step = make_step(cfg, mesh, rules, knobs)
    batch = {"tokens": tok, "pos": pos}
    with set_mesh_compat(mesh):
        jaxpr = jax.make_jaxpr(step)(pm, cache, batch)
        hlo = jax.jit(step).lower(pm, cache, batch).compile().as_text()
    return RuleContext(
        target="serve_step",
        jaxpr=jaxpr,
        hlo_text=hlo,
        params=pm,
        hidden_shape=(2, 1, cfg.d_model),
        expect={
            "residual_adds": 0,
            "writebacks_per_layer": _DECODE_WRITEBACKS_PER_LAYER,
            "pallas_calls": _DECODE_WRITEBACKS_PER_LAYER,
        },
    )


def build_serve_frontend() -> RuleContext:
    """The hardened front end's *degraded* path: the unpaired
    ``gemm="pallas"`` decode step the numeric watchdog retries quarantined
    requests on (serving.guards).  Exact arithmetic, no pairing metadata —
    but the fallback must still be a sane schedule: the seven per-layer
    GEMMs on the K-tiled Pallas kernel, the two sublayer residual adds
    standalone (no epilogue fusion to hide them in), no f64 leaks."""
    import jax

    from repro.kernels.ops import perf_context
    from repro.models import lm as M
    from repro.models.param import unzip

    cfg = _smoke_lm_cfg()
    params, _ = unzip(M.init_lm(cfg, jax.random.key(0)))
    cache, _ = unzip(M.init_cache(cfg, 2, 32))
    import jax.numpy as jnp

    tok = jnp.zeros((2, 1), jnp.int32)
    pos = jnp.asarray([5, 11], jnp.int32)
    knobs = M.PerfKnobs(q_chunk=16, k_chunk=16, remat="none", gemm="pallas")

    def step(p, c, t, s):
        with perf_context(knobs):
            return M.decode_step(cfg, p, c, t, s)

    with perf_context(knobs):
        jaxpr = jax.make_jaxpr(
            lambda p, c, t, s: M.decode_step(cfg, p, c, t, s)
        )(params, cache, tok, pos)
    hlo = jax.jit(step).lower(params, cache, tok, pos).compile().as_text()
    return RuleContext(
        target="serve_frontend",
        jaxpr=jaxpr,
        hlo_text=hlo,
        params=params,
        hidden_shape=(2, 1, cfg.d_model),
        expect={
            # unpaired fallback: same seven GEMM launches per layer as the
            # paired path (attn q/k/v/out + MLP gate/up/down on the dense
            # kernel), but the residual adds stay standalone — exactly 2
            "residual_adds": 2,
            "writebacks_per_layer": _DECODE_WRITEBACKS_PER_LAYER,
            "pallas_calls": _DECODE_WRITEBACKS_PER_LAYER,
        },
    )


def build_sharded_decode() -> RuleContext:
    """The mesh-sharded paired decode cell (launch.steps.wire_serve_cell):
    per-TP-shard pairing metadata placed beside its weight shards, pjit'd
    decode step.  Primary gate: ``hlo/pairing-resharding-in-loop`` must find
    zero copies/collectives of pairing metadata inside the decode while-loop
    — the metadata is loop-invariant sharded state, and any reshard there
    would serialize every decoded token behind a gather.

    Uses a (2, n/2) mesh when the process exposes ≥ 4 devices (CI's
    mesh-decode job sets ``XLA_FLAGS=--xla_force_host_platform_device_count``)
    and degrades to (1, n) otherwise — the rule is placement-structural, so
    it bites at any mesh size."""
    import jax
    import jax.numpy as jnp

    from repro.launch.steps import wire_serve_cell
    from repro.models import lm as M
    from repro.models.param import unzip
    from repro.parallel.sharding import make_mesh_compat, set_mesh_compat

    cfg = _smoke_lm_cfg()
    params, _ = unzip(M.init_lm(cfg, jax.random.key(0)))
    n = jax.device_count()
    shape = (2, n // 2) if n >= 4 else (1, n)
    mesh = make_mesh_compat(shape, ("data", "model"))
    knobs = _paired_knobs()
    cell = wire_serve_cell(
        cfg, params, mesh, batch_size=2, max_seq=32, knobs=knobs
    )
    cache, _ = unzip(M.init_cache(cfg, 2, 32))
    cache = jax.tree.map(jax.device_put, cache, cell.c_shard)
    batch = {
        "tokens": jnp.zeros((2, 1), jnp.int32),
        "pos": jnp.asarray([5, 11], jnp.int32),
    }
    with set_mesh_compat(mesh):
        hlo = cell.decode.lower(cell.params, cache, batch).compile().as_text()
    return RuleContext(
        target="sharded_decode",
        hlo_text=hlo,
        params=cell.params,
        expect={},
    )


def build_model_zoo() -> RuleContext:
    """Pairing metadata of the hardest zoo member (deepseek: MLA latents,
    leading-expert-axis MoE weights, shared experts, a leading dense layer)
    — gates the valid-permutation / padding / stacked-shape invariants on
    the expert-stacked metadata the MoE kernel path consumes."""
    import jax

    from repro.configs import get_smoke_config
    from repro.core.transform import pair_params
    from repro.models import lm as M
    from repro.models.param import unzip

    cfg = dc.replace(get_smoke_config("deepseek-v2-lite-16b"), dtype="float32")
    params, _ = unzip(M.init_lm(cfg, jax.random.key(0)))
    pm, _ = pair_params(
        params, 0.05, mode="per_column", leaves=cfg.paired_leaves or None
    )
    return RuleContext(target="model_zoo", params=pm, expect={})


_BUILDERS = {
    "lenet_fused": build_lenet_fused,
    "lm_decode": build_lm_decode,
    "serve_step": build_serve_step,
    "serve_frontend": build_serve_frontend,
    "fused_attn_decode": build_fused_attn_decode,
    "model_zoo": build_model_zoo,
    "sharded_decode": build_sharded_decode,
}


def build_context(target: str) -> RuleContext:
    if target not in _BUILDERS:
        raise ValueError(f"unknown target {target!r}; choose from {TARGETS}")
    return _BUILDERS[target]()
