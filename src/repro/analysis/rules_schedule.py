"""Schedule rules: does the traced program execute the claimed fusion plan?

The paper's power/area win is a *schedule* claim as much as an arithmetic
one: the subtractions must run inside the kernel lanes, the pool and the
residual adds must ride kernel epilogues, and each conv layer / decode GEMM
must write back to HBM exactly once.  These rules read the traced jaxpr and
compare it against the target's declared expectations.
"""
from __future__ import annotations

from repro.analysis.core import Finding, RuleContext, rule
from repro.analysis.jaxpr_walk import (
    count_primitives,
    count_shape_adds,
    pallas_calls_by_scan,
)


@rule("schedule/no-standalone-pool", needs=("jaxpr",))
def no_standalone_pool(ctx: RuleContext):
    """Standalone ``reduce_window_max`` is forbidden on the fused conv→pool path."""
    n = count_primitives(ctx.jaxpr, "reduce_window_max")
    fused = bool(ctx.expect.get("fused_pool"))
    if fused and n > 0:
        yield Finding(
            rule="schedule/no-standalone-pool",
            severity="error",
            location=ctx.target,
            message=f"fused path still launches {n} standalone reduce_window_max "
                    f"op(s) — pooling must happen inside the kernel epilogue",
            measured=n,
            expected=0,
        )
    else:
        yield Finding(
            rule="schedule/no-standalone-pool",
            severity="info",
            location=ctx.target,
            message=f"{n} standalone reduce_window_max op(s) in the traced program",
            measured=n,
            expected=0 if fused else None,
        )


@rule("schedule/writebacks-per-program", needs=("jaxpr",))
def writebacks_per_program(ctx: RuleContext):
    """``pallas_call`` count per traced program — one HBM writeback per kernel."""
    n = count_primitives(ctx.jaxpr, "pallas_call")
    expected = ctx.expect.get("pallas_calls")
    if expected is not None and n != expected:
        yield Finding(
            rule="schedule/writebacks-per-program",
            severity="error",
            location=ctx.target,
            message=f"expected {expected} kernel writeback(s) in the traced "
                    f"program, found {n}",
            measured=n,
            expected=expected,
        )
    else:
        yield Finding(
            rule="schedule/writebacks-per-program",
            severity="info",
            location=ctx.target,
            message=f"{n} pallas_call writeback(s) in the traced program",
            measured=n,
            expected=expected,
        )


@rule("schedule/writebacks-per-decode-layer", needs=("jaxpr",))
def writebacks_per_decode_layer(ctx: RuleContext):
    """HBM writebacks per decode layer: ``pallas_call`` launches inside one
    trip of each layer ``scan`` body — the ROADMAP prerequisite for gating
    the paired flash-attention reduction."""
    total, per_scan = pallas_calls_by_scan(ctx.jaxpr)
    expected = ctx.expect.get("writebacks_per_layer")
    if not per_scan:
        sev = "error" if expected is not None else "info"
        yield Finding(
            rule="schedule/writebacks-per-decode-layer",
            severity=sev,
            location=ctx.target,
            message="no scan encloses a pallas_call"
                    + (" (expected a layer loop with kernel launches)"
                       if expected is not None else ""),
            measured=0,
            expected=expected,
        )
        return
    for i, rec in enumerate(sorted(per_scan.values(), key=lambda r: -r["per_trip"])):
        loc = f"{ctx.target}/scan{i}"
        if expected is not None and rec["per_trip"] > expected:
            yield Finding(
                rule="schedule/writebacks-per-decode-layer",
                severity="error",
                location=loc,
                message=f"{rec['per_trip']} kernel writebacks per decode layer "
                        f"(scan over {rec['length']} layers) exceeds the "
                        f"budget of {expected}",
                measured=rec["per_trip"],
                expected=expected,
            )
        else:
            yield Finding(
                rule="schedule/writebacks-per-decode-layer",
                severity="info",
                location=loc,
                message=f"{rec['per_trip']} kernel writeback(s) per layer across "
                        f"a scan of {rec['length']} layer(s)",
                measured=rec["per_trip"],
                expected=expected,
            )


@rule("schedule/standalone-residual-adds", needs=("jaxpr", "hidden_shape"))
def standalone_residual_adds(ctx: RuleContext):
    """Standalone hidden-state residual adds — the paired path must fuse the
    ``h + attn(x)`` / ``h + mlp(x)`` skips into the kernel epilogue."""
    n = count_shape_adds(ctx.jaxpr, ctx.hidden_shape)
    expected = ctx.expect.get("residual_adds")
    if expected is not None and n != expected:
        yield Finding(
            rule="schedule/standalone-residual-adds",
            severity="error",
            location=ctx.target,
            message=f"{n} standalone residual add(s) over hidden shape "
                    f"{tuple(ctx.hidden_shape)} (expected {expected}) — skips "
                    f"must ride the kernel's residual-add epilogue",
            measured=n,
            expected=expected,
        )
    else:
        yield Finding(
            rule="schedule/standalone-residual-adds",
            severity="info",
            location=ctx.target,
            message=f"{n} standalone residual add(s) over hidden shape "
                    f"{tuple(ctx.hidden_shape)}",
            measured=n,
            expected=expected,
        )
