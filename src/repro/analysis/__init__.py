"""Static analysis over traced jaxprs, compiled HLO, and pairing artifacts.

The package has three layers:

* :mod:`repro.analysis.jaxpr_walk` — the repo's single jaxpr-walking
  implementation (``walk_eqns``, ``count_primitives``, ``count_shape_adds``);
* :mod:`repro.analysis.core` — the rule registry, :class:`Finding`,
  :class:`RuleContext`, and :func:`run_rules` → :class:`AnalysisReport`;
* ``rules_*`` modules — the registered rules (schedule, dtype, VMEM,
  pairing artifacts, HLO), imported on the first :func:`run_rules` call.

CLI: ``python -m repro.analysis --target lm_decode [--json report.json]``;
the exit code is non-zero iff an error-severity finding fires.
"""
from repro.analysis.core import (
    RULE_REGISTRY,
    AnalysisReport,
    Finding,
    Rule,
    RuleContext,
    rule,
    run_rules,
)
from repro.analysis.jaxpr_walk import (
    count_primitives,
    count_shape_adds,
    pallas_calls_by_scan,
    walk_eqns,
    walk_eqns_with_stack,
)

__all__ = [
    "RULE_REGISTRY",
    "AnalysisReport",
    "Finding",
    "Rule",
    "RuleContext",
    "count_primitives",
    "count_shape_adds",
    "pallas_calls_by_scan",
    "rule",
    "run_rules",
    "walk_eqns",
    "walk_eqns_with_stack",
]
