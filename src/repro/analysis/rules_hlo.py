"""Compiled-HLO rule: pairing metadata must stay put inside the decode loop.

The stacked ``"<name>_pairing"`` index/mask arrays are loop-invariant decode
state: the layer scan slices them per trip, and nothing else should touch
them.  A ``copy`` or a collective (resharding) of a pairing buffer *inside*
the while loop means the partitioner is moving the metadata every decode
step — per-token traffic for buffers that never change.

Anchoring: jax records the flattened argument path of every entry parameter
in its HLO metadata (``op_name="p['segments'][0]['attn']['wq_pairing']['I']"``),
so pairing buffers are identified by name at the ENTRY boundary and tracked
into the loop by their exact array type (post-SPMD, a reshard/copy of one
produces an op of the same — or sliced — pairing-metadata type; matching on
the full type string keeps the rule conservative).
"""
from __future__ import annotations

import re

from repro.analysis.core import Finding, RuleContext, rule
from repro.parallel.hlo import _SHAPE_RE, parse_hlo, while_reachable

# op kinds that move a buffer without computing anything new on it
_MOVE_OPS = {
    "copy", "copy-start", "all-gather", "all-gather-start", "all-to-all",
    "collective-permute", "collective-permute-start", "all-reduce",
    "all-reduce-start", "reduce-scatter",
}

_PAIRING_META_RE = re.compile(r"op_name=\"[^\"]*_pairing[^\"]*\"")


def _canon_type(type_str: str) -> str:
    """``f32[2,32,18]{2,1,0} `` → ``f32[2,32,18]`` (layout/space stripped)."""
    m = _SHAPE_RE.search(type_str)
    return m.group(0) if m else type_str.strip()


@rule("hlo/pairing-resharding-in-loop", needs=("hlo",))
def pairing_resharding_in_loop(ctx: RuleContext):
    """No copies/reshards of ``*_pairing`` buffers inside the decode loop."""
    comps, entry = parse_hlo(ctx.hlo_text)
    pairing_types: set[str] = set()
    n_buffers = 0
    for comp in comps.values():
        for op in comp.ops:
            if op.op == "parameter" and _PAIRING_META_RE.search(op.line):
                n_buffers += 1
                pairing_types.add(_canon_type(op.type_str))
    if not pairing_types:
        yield Finding(
            rule="hlo/pairing-resharding-in-loop",
            severity="info",
            location=ctx.target,
            message="no pairing-metadata buffers in the compiled program",
            measured=0,
            expected=None,
        )
        return

    loop_comps = while_reachable(comps)
    moved = 0
    for name in sorted(loop_comps):
        comp = comps.get(name)
        if comp is None:
            continue
        for op in comp.ops:
            if op.op in _MOVE_OPS and _canon_type(op.type_str) in pairing_types:
                moved += 1
                yield Finding(
                    rule="hlo/pairing-resharding-in-loop",
                    severity="error",
                    location=f"{ctx.target}/{name}",
                    message=f"{op.op} of a pairing-metadata-typed buffer "
                            f"({_canon_type(op.type_str)}) inside the decode "
                            f"loop — loop-invariant metadata is being moved "
                            f"per step",
                    measured=op.op,
                    expected="no copies/collectives of pairing buffers in-loop",
                )
    yield Finding(
        rule="hlo/pairing-resharding-in-loop",
        severity="info",
        location=ctx.target,
        message=f"{n_buffers} pairing buffer(s) tracked across "
                f"{len(loop_comps)} loop-interior computation(s), "
                f"{moved} moved",
        measured=moved,
        expected=0,
    )
