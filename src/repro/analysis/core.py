"""Rule registry, structured findings, and the machine-readable report.

A *rule* is a function from a :class:`RuleContext` (the traced jaxpr, the
compiled HLO text, the pairing artifacts — whatever the target provides) to
zero or more :class:`Finding`\\ s.  Rules declare what context they ``need``;
:func:`run_rules` runs every registered rule whose needs are satisfied and
records the rest as skipped, so one report always answers "which invariants
were actually checked".

Severity contract: ``error`` findings are schedule/correctness violations the
CI job must fail on (:meth:`AnalysisReport.exit_code` is non-zero iff one
fires); ``warning`` is a suspicious measurement worth a look; ``info``
findings carry the measured values themselves (writeback counts, convert
churn, VMEM high-water marks) so benches and CI artifacts can report them
without re-walking anything.
"""
from __future__ import annotations

import dataclasses
import json
from collections.abc import Callable, Iterable
from typing import Any

SEVERITIES = ("info", "warning", "error")


@dataclasses.dataclass
class Finding:
    """One structured result of one rule at one location."""

    rule: str
    severity: str  # "info" | "warning" | "error"
    location: str  # target name, artifact path, HLO computation, …
    message: str
    measured: Any = None
    expected: Any = None

    def __post_init__(self):
        assert self.severity in SEVERITIES, self.severity

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class RuleContext:
    """Everything a target exposes for the rules to inspect.

    Fields are optional: rules declare which ones they need and are skipped
    (not failed) when a target doesn't provide them — a LeNet forward has no
    decode loop HLO, a pairing-artifact check needs no trace at all.
    """

    target: str
    jaxpr: Any = None  # ClosedJaxpr of the traced program
    hlo_text: str | None = None  # compiled HLO (``compiled.as_text()``)
    params: Any = None  # LM param tree (may carry ``*_pairing`` metadata)
    pairing_artifacts: dict | None = None  # conv {name: PairedLayer}
    hidden_shape: tuple | None = None  # residual-add signature shape
    expect: dict = dataclasses.field(default_factory=dict)
    # per-target expectations, e.g. {"fused_pool": True, "pallas_calls": 3,
    # "writebacks_per_layer": 7, "residual_adds": 0, "max_converts": 40}

    def has(self, need: str) -> bool:
        if need == "jaxpr":
            return self.jaxpr is not None
        if need == "hlo":
            return self.hlo_text is not None
        if need == "hidden_shape":
            return self.hidden_shape is not None
        if need == "pairing":
            return self.pairing_artifacts is not None or self.params is not None
        raise ValueError(f"unknown rule need {need!r}")


@dataclasses.dataclass
class Rule:
    id: str
    needs: tuple[str, ...]
    fn: Callable[[RuleContext], Iterable[Finding]]
    doc: str


RULE_REGISTRY: dict[str, Rule] = {}


def rule(rule_id: str, *, needs: tuple[str, ...] = ()):
    """Register a rule under ``rule_id`` (e.g. ``"schedule/no-standalone-pool"``).

    ``needs`` lists the :class:`RuleContext` facets the rule requires:
    ``"jaxpr"``, ``"hlo"``, ``"hidden_shape"``, ``"pairing"``.
    """

    def deco(fn):
        assert rule_id not in RULE_REGISTRY, f"duplicate rule id {rule_id}"
        RULE_REGISTRY[rule_id] = Rule(
            rule_id, tuple(needs), fn, (fn.__doc__ or "").strip().splitlines()[0]
        )
        return fn

    return deco


@dataclasses.dataclass
class AnalysisReport:
    target: str
    findings: list[Finding]
    rules_run: list[str]
    rules_skipped: dict[str, str]  # rule id -> unmet need

    def errors(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    def warnings(self) -> list[Finding]:
        return [f for f in self.findings if f.severity == "warning"]

    @property
    def exit_code(self) -> int:
        return 1 if self.errors() else 0

    def measured(self, rule_id: str, location: str | None = None):
        """The ``measured`` value of the first finding of ``rule_id`` (at
        ``location`` if given) — how benches read counts out of a report."""
        for f in self.findings:
            if f.rule == rule_id and (location is None or f.location == location):
                return f.measured
        raise KeyError(f"no finding for rule {rule_id!r}"
                       + (f" at {location!r}" if location else ""))

    def as_dict(self) -> dict:
        return {
            "target": self.target,
            "errors": len(self.errors()),
            "warnings": len(self.warnings()),
            "rules_run": self.rules_run,
            "rules_skipped": self.rules_skipped,
            "findings": [f.as_dict() for f in self.findings],
        }

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        return json.dumps(self.as_dict(), default=str, **kw)

    def summary_lines(self) -> list[str]:
        lines = [
            f"[{self.target}] {len(self.rules_run)} rules run, "
            f"{len(self.rules_skipped)} skipped, "
            f"{len(self.errors())} error(s), {len(self.warnings())} warning(s)"
        ]
        for f in self.findings:
            if f.severity == "info":
                continue
            lines.append(f"  {f.severity.upper()} {f.rule} @ {f.location}: {f.message}")
        return lines


def _load_rules() -> None:
    """Import the rule modules so their ``@rule`` decorators register.

    Deferred to avoid import cycles (rules import jax / repro.kernels,
    which never import us back at module level, but keeping registration
    lazy also keeps ``from repro.analysis import count_primitives`` light).
    """
    from repro.analysis import (  # noqa: F401
        rules_dtype,
        rules_hlo,
        rules_pairing,
        rules_schedule,
        rules_vmem,
    )


def run_rules(
    ctx: RuleContext, rule_ids: Iterable[str] | None = None
) -> AnalysisReport:
    """Run every registered rule (or the given subset) against ``ctx``.

    Rules whose ``needs`` the context can't satisfy are recorded in
    ``rules_skipped`` with the first unmet need — never silently dropped.
    """
    _load_rules()

    wanted = set(rule_ids) if rule_ids is not None else None
    if wanted is not None:
        unknown = wanted - set(RULE_REGISTRY)
        assert not unknown, f"unknown rule ids: {sorted(unknown)}"

    findings: list[Finding] = []
    rules_run: list[str] = []
    skipped: dict[str, str] = {}
    for rid in sorted(RULE_REGISTRY):
        if wanted is not None and rid not in wanted:
            continue
        r = RULE_REGISTRY[rid]
        unmet = next((n for n in r.needs if not ctx.has(n)), None)
        if unmet is not None:
            skipped[rid] = unmet
            continue
        findings.extend(r.fn(ctx))
        rules_run.append(rid)
    return AnalysisReport(
        target=ctx.target,
        findings=findings,
        rules_run=rules_run,
        rules_skipped=skipped,
    )
