"""Static VMEM-budget rule.

Every ``pallas_call`` in the traced program declares its block specs at trace
time; charging them against :data:`repro.kernels.tuning.VMEM_BUDGET_BYTES`
catches an over-budget tile choice *before* anything runs — on TPU that is
the difference between a compile-time report and a Mosaic OOM mid-serve.
"""
from __future__ import annotations

from repro.analysis.core import Finding, RuleContext, rule
from repro.analysis.jaxpr_walk import walk_eqns
from repro.kernels import tuning


def _block_specs(eqn) -> tuple[list, list, list] | None:
    """(in_blocks, out_blocks, scratch_blocks) of one pallas_call eqn, each a
    list of ``(block_shape, dtype_bytes)`` — None when the eqn doesn't carry
    the jax 0.4-style grid mapping (e.g. a synthetic test jaxpr)."""
    gm = eqn.params.get("grid_mapping")
    if gm is None:
        return None
    mappings = list(getattr(gm, "block_mappings", ()))
    n_in = getattr(gm, "num_inputs", len(mappings))
    blocks = []
    for bm in mappings:
        shape = tuple(getattr(bm, "block_shape", ()))
        sds = getattr(bm, "array_shape_dtype", None)
        itemsize = getattr(getattr(sds, "dtype", None), "itemsize", 4)
        blocks.append((shape, itemsize))
    in_blocks, out_blocks = blocks[:n_in], blocks[n_in:]

    scratch = []
    kernel_jaxpr = eqn.params.get("jaxpr")
    n_scratch = getattr(gm, "num_scratch_operands", 0)
    if kernel_jaxpr is not None and n_scratch:
        for v in kernel_jaxpr.invars[len(mappings):]:
            aval = getattr(v, "aval", None)
            inner = getattr(aval, "inner_aval", aval)  # AbstractMemoryRef
            shape = tuple(getattr(inner, "shape", ()))
            itemsize = getattr(getattr(inner, "dtype", None), "itemsize", 4)
            scratch.append((shape, itemsize))
    return in_blocks, out_blocks, scratch


@rule("vmem/static-budget", needs=("jaxpr",))
def static_budget(ctx: RuleContext):
    """Every pallas_call's block-spec working set must fit the VMEM budget."""
    budget = ctx.expect.get("vmem_budget_bytes", tuning.VMEM_BUDGET_BYTES)
    n_calls = 0
    peak = 0
    for eqn in walk_eqns(ctx.jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        specs = _block_specs(eqn)
        if specs is None:
            continue
        n_calls += 1
        est = tuning.estimate_pallas_vmem_bytes(*specs)
        peak = max(peak, est)
        if est > budget:
            info = eqn.params.get("name_and_src_info")
            name = getattr(info, "name", "") or "pallas_call"
            in_blocks = [s for s, _ in specs[0]]
            yield Finding(
                rule="vmem/static-budget",
                severity="error",
                location=f"{ctx.target}/{name}",
                message=f"block specs {in_blocks} budget {est} bytes of VMEM "
                        f"per program — over the {budget}-byte budget",
                measured=est,
                expected=budget,
            )
    yield Finding(
        rule="vmem/static-budget",
        severity="info",
        location=ctx.target,
        message=f"{n_calls} pallas_call(s) checked, peak static working set "
                f"{peak} bytes (budget {budget})",
        measured=peak,
        expected=budget,
    )
