"""Dtype-discipline rules.

Approximate-arithmetic accelerators live or die on numeric drift (cf. the
approximate-multiplier literature): the bf16 subtractor path pins its
rounding semantics with an explicit ``reduce_precision`` in the kernel, f64
anywhere means a silent 2x-width fallback slipped in, and
``convert_element_type`` churn measures how often the schedule bounces
activations between widths.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.analysis.core import Finding, RuleContext, rule
from repro.analysis.jaxpr_walk import count_primitives, walk_eqns

_WIDE_DTYPES = ("float64", "complex128")


def _aval_dtype(v):
    return getattr(getattr(v, "aval", None), "dtype", None)


def _is_low_precision_float(dtype) -> bool:
    try:
        return bool(jnp.issubdtype(dtype, jnp.floating)) and dtype.itemsize < 4
    except TypeError:
        return False


def _pallas_kernel_name(eqn) -> str:
    info = eqn.params.get("name_and_src_info")
    return getattr(info, "name", "") or str(info or "")


@rule("dtype/no-f64", needs=("jaxpr",))
def no_f64(ctx: RuleContext):
    """No float64/complex128 anywhere in the traced program."""
    hits: list[str] = []
    for eqn in walk_eqns(ctx.jaxpr):
        for v in eqn.outvars:
            dt = _aval_dtype(v)
            if dt is not None and str(dt) in _WIDE_DTYPES:
                hits.append(f"{eqn.primitive.name}:{dt}")
    if hits:
        yield Finding(
            rule="dtype/no-f64",
            severity="error",
            location=ctx.target,
            message=f"{len(hits)} eqn(s) produce 64-bit values "
                    f"(e.g. {hits[0]}) — the inference paths are ≤ 32-bit",
            measured=len(hits),
            expected=0,
        )
    else:
        yield Finding(
            rule="dtype/no-f64",
            severity="info",
            location=ctx.target,
            message="no 64-bit values in the traced program",
            measured=0,
            expected=0,
        )


@rule("dtype/reduce-precision-on-bf16", needs=("jaxpr",))
def reduce_precision_on_bf16(ctx: RuleContext):
    """bf16 subtractor kernels must pin rounding with ``reduce_precision``."""
    checked = 0
    for eqn in walk_eqns(ctx.jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        name = _pallas_kernel_name(eqn)
        if "paired" not in name:
            continue  # dense/flash kernels have no subtractor lanes to pin
        low = [str(dt) for dt in map(_aval_dtype, eqn.invars)
               if dt is not None and _is_low_precision_float(dt)]
        if not low:
            continue
        checked += 1
        kernel_jaxpr = eqn.params.get("jaxpr")
        n_rp = count_primitives(kernel_jaxpr, "reduce_precision") if kernel_jaxpr else 0
        if n_rp == 0:
            yield Finding(
                rule="dtype/reduce-precision-on-bf16",
                severity="error",
                location=f"{ctx.target}/{name}",
                message=f"subtractor kernel consumes {sorted(set(low))} inputs "
                        f"but applies no reduce_precision — low-precision "
                        f"rounding semantics are unpinned",
                measured=n_rp,
                expected=">= 1",
            )
    yield Finding(
        rule="dtype/reduce-precision-on-bf16",
        severity="info",
        location=ctx.target,
        message=f"{checked} low-precision subtractor kernel(s) checked",
        measured=checked,
        expected=None,
    )


@rule("dtype/convert-churn", needs=("jaxpr",))
def convert_churn(ctx: RuleContext):
    """``convert_element_type`` churn counter — widening/narrowing bounces."""
    n = count_primitives(ctx.jaxpr, "convert_element_type")
    cap = ctx.expect.get("max_converts")
    if cap is not None and n > cap:
        yield Finding(
            rule="dtype/convert-churn",
            severity="warning",
            location=ctx.target,
            message=f"{n} convert_element_type op(s) exceed the target's "
                    f"budget of {cap} — check for width bouncing",
            measured=n,
            expected=cap,
        )
    else:
        yield Finding(
            rule="dtype/convert-churn",
            severity="info",
            location=ctx.target,
            message=f"{n} convert_element_type op(s) in the traced program",
            measured=n,
            expected=cap,
        )
