"""Pairing-artifact validation rules.

The pairing artifacts are offline preprocessing outputs (numpy, built once)
that the kernels then trust completely: a lane index list that is not a
permutation silently drops or double-counts contraction lanes, padding whose
mask doesn't zero it contracts garbage, and stacked metadata that disagrees
with the weight stack it shadows desynchronizes the layer scan.  These rules
validate the concrete artifacts — no trace required.

Both artifact families are covered:

* conv artifacts (``core.transform.build_conv_pairings`` →
  ``{name: PairedLayer}``) via ``RuleContext.pairing_artifacts``;
* LM stacked metadata (``core.transform.pair_lm_params`` → ``"<w>_pairing"``
  sibling dicts in the param tree) via ``RuleContext.params``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.analysis.core import Finding, RuleContext, rule

_META_KEYS = ("I", "J", "resid", "pair_mask", "resid_mask")


@dataclasses.dataclass
class _Artifact:
    """One per-layer (or per-layer-per-block) lane structure to validate."""

    location: str
    K: int  # contraction length the lanes must cover
    I: np.ndarray  # (P,) padded pair indices
    J: np.ndarray  # (P,)
    resid: np.ndarray  # (R,)
    pair_mask: np.ndarray | None  # (P,) 1.0 real / 0.0 padding; None → unpadded
    resid_mask: np.ndarray | None


def _conv_artifacts(arts: dict) -> list[_Artifact]:
    from repro.core.pairing import BlockedPairing, StructuredPairing

    out = []
    for name, layer in arts.items():
        p = getattr(layer, "pairing", layer)
        if isinstance(p, StructuredPairing):
            out.append(_Artifact(
                location=name, K=p.shape[0], I=p.I, J=p.J, resid=p.resid,
                pair_mask=None, resid_mask=None,
            ))
        elif isinstance(p, BlockedPairing):
            idx = p.index_arrays()
            for b in range(p.n_blocks):
                out.append(_Artifact(
                    location=f"{name}/block{b}", K=p.shape[0],
                    I=idx["I"][b], J=idx["J"][b], resid=idx["resid"][b],
                    pair_mask=idx["pair_mask"][b],
                    resid_mask=idx["resid_mask"][b],
                ))
    return out


def _walk_subs(node: dict, prefix: str = ""):
    """Yield ``(dotted sub-path, sub dict)`` for every nested layer block
    (``attn``, ``mlp``, ``moe``, ``moe.shared``, ``mamba``, …)."""
    for name, sub in node.items():
        if name.endswith("_pairing") or not isinstance(sub, dict):
            continue
        path = f"{prefix}.{name}" if prefix else name
        yield path, sub
        yield from _walk_subs(sub, path)


def _lm_metadata(params: Any) -> list[tuple[str, dict, np.ndarray, bool]]:
    """Every ``(path, meta dict, weight array, is_expert)`` pairing-metadata
    entry — decoder and encoder stacks, nested sub-blocks included.

    ``is_expert`` marks leading-expert-axis MoE weights ``(L, E, K, F)``
    whose metadata stacks ``(L, E, …)`` instead of ``(L, …)``."""
    out = []
    if not isinstance(params, dict):
        return out
    stacks = [("segments", params.get("segments", []))]
    enc = params.get("encoder")
    if isinstance(enc, dict):
        stacks.append(("encoder.segments", enc.get("segments", [])))
    for prefix, segments in stacks:
        for si, seg in enumerate(segments):
            if not isinstance(seg, dict):
                continue
            for sub_path, sub in _walk_subs(seg):
                for key, meta in sub.items():
                    if not key.endswith("_pairing") or not isinstance(meta, dict):
                        continue
                    w_name = key[: -len("_pairing")]
                    if w_name not in sub:
                        continue
                    arr = np.asarray(sub[w_name])
                    is_expert = (
                        sub_path.rsplit(".", 1)[-1] == "moe" and arr.ndim == 4
                    )
                    path = f"{prefix}[{si}].{sub_path}.{key}"
                    out.append((path, meta, arr, is_expert))
    return out


def _lm_artifacts(params: Any) -> list[_Artifact]:
    from repro.core.transform import _lm_weight_matrix_shape

    out = []
    for path, meta, arr, is_expert in _lm_metadata(params):
        w_name = path.rsplit(".", 1)[-1][: -len("_pairing")]
        lead = 2 if is_expert else 1  # (L, E, …) vs (L, …) stacking
        K, _ = _lm_weight_matrix_shape(w_name, arr.shape[lead:])
        flat = {
            k: np.asarray(meta[k]).reshape(-1, *np.asarray(meta[k]).shape[lead:])
            for k in _META_KEYS
        }
        I, J, R = flat["I"], flat["J"], flat["resid"]
        pm, rm = flat["pair_mask"], flat["resid_mask"]
        tag = "layer·expert" if is_expert else "layer"
        for layer in range(I.shape[0]):
            if I.ndim == 3:  # blocked: (stack, blocks, Pmax)
                for b in range(I.shape[1]):
                    out.append(_Artifact(
                        location=f"{path}[{tag} {layer}, block {b}]", K=K,
                        I=I[layer, b], J=J[layer, b], resid=R[layer, b],
                        pair_mask=pm[layer, b], resid_mask=rm[layer, b],
                    ))
            else:  # structured: (stack, Pmax)
                out.append(_Artifact(
                    location=f"{path}[{tag} {layer}]", K=K,
                    I=I[layer], J=J[layer], resid=R[layer],
                    pair_mask=pm[layer], resid_mask=rm[layer],
                ))
    return out


def _all_artifacts(ctx: RuleContext) -> list[_Artifact]:
    arts: list[_Artifact] = []
    if ctx.pairing_artifacts:
        arts.extend(_conv_artifacts(ctx.pairing_artifacts))
    if ctx.params is not None:
        arts.extend(_lm_artifacts(ctx.params))
    return arts


def _valid_lanes(a: _Artifact) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(I, J, resid) restricted to mask-valid entries."""
    if a.pair_mask is None:
        return a.I, a.J, a.resid
    p = a.pair_mask > 0
    r = a.resid_mask > 0
    return a.I[p], a.J[p], a.resid[r]


@rule("pairing/valid-permutation", needs=("pairing",))
def valid_permutation(ctx: RuleContext):
    """Per-block lane lists ``[I | J | resid]`` must permute ``range(K)``."""
    arts = _all_artifacts(ctx)
    bad = 0
    for a in arts:
        I, J, resid = _valid_lanes(a)
        lanes = np.concatenate([np.ravel(I), np.ravel(J), np.ravel(resid)])
        if lanes.size != a.K or not np.array_equal(np.sort(lanes), np.arange(a.K)):
            bad += 1
            yield Finding(
                rule="pairing/valid-permutation",
                severity="error",
                location=a.location,
                message=f"lane lists cover {lanes.size} lane(s) of K={a.K} and "
                        f"are not a permutation — the kernel would drop or "
                        f"double-count contraction lanes",
                measured=sorted(np.ravel(lanes).tolist())[:8],
                expected=f"permutation of range({a.K})",
            )
    yield Finding(
        rule="pairing/valid-permutation",
        severity="info",
        location=ctx.target,
        message=f"{len(arts) - bad}/{len(arts)} artifact blocks carry valid "
                f"lane permutations",
        measured=len(arts),
        expected=None,
    )


@rule("pairing/padding-consistent", needs=("pairing",))
def padding_consistent(ctx: RuleContext):
    """Padded (Pmax, Rmax) metadata: masks are prefix-shaped 0/1, padded
    lanes point at row 0, and I/J/mask shapes agree."""
    arts = [a for a in _all_artifacts(ctx) if a.pair_mask is not None]
    bad = 0
    for a in arts:
        problems = []
        if a.I.shape != a.J.shape or a.I.shape != a.pair_mask.shape:
            problems.append(
                f"pair shapes disagree: I{a.I.shape} J{a.J.shape} "
                f"mask{a.pair_mask.shape}"
            )
        if a.resid.shape != a.resid_mask.shape:
            problems.append(
                f"resid shapes disagree: resid{a.resid.shape} "
                f"mask{a.resid_mask.shape}"
            )
        for mask, idxs, tag in (
            (a.pair_mask, (a.I, a.J), "pair"),
            (a.resid_mask, (a.resid,), "resid"),
        ):
            m = np.ravel(mask)
            if not np.isin(m, (0.0, 1.0)).all():
                problems.append(f"{tag}_mask is not 0/1")
                continue
            nz = np.flatnonzero(m)
            if nz.size and (nz[-1] + 1 != nz.size):
                problems.append(f"{tag}_mask is not a prefix of ones")
            for idx in idxs:
                if idx.shape == mask.shape and np.any(np.ravel(idx)[m == 0] != 0):
                    problems.append(f"padded {tag} lanes do not point at row 0")
                    break
        if problems:
            bad += 1
            yield Finding(
                rule="pairing/padding-consistent",
                severity="error",
                location=a.location,
                message="; ".join(problems),
                measured=problems,
                expected="prefix 0/1 masks, zero-row padding, matching shapes",
            )
    yield Finding(
        rule="pairing/padding-consistent",
        severity="info",
        location=ctx.target,
        message=f"{len(arts) - bad}/{len(arts)} padded artifact blocks "
                f"consistent",
        measured=len(arts),
        expected=None,
    )


@rule("pairing/stacked-shapes", needs=("pairing",))
def stacked_shapes(ctx: RuleContext):
    """Stacked ``(layers, …)`` LM metadata must agree with the weight stack
    it shadows: same layer count, all indices inside the weight's K."""
    if ctx.params is None:
        return
    from repro.core.transform import _lm_weight_matrix_shape

    pairs = _lm_metadata(ctx.params)
    bad = 0
    for path, meta, arr, is_expert in pairs:
        w_name = path.rsplit(".", 1)[-1][: -len("_pairing")]
        lead = 2 if is_expert else 1  # (L, E, …) vs (L, …) stacking
        stack = arr.shape[:lead]
        L = arr.shape[0]
        K, _ = _lm_weight_matrix_shape(w_name, arr.shape[lead:])
        problems = []
        missing = [k for k in _META_KEYS if k not in meta]
        if missing:
            problems.append(f"metadata keys missing: {missing}")
        for k in _META_KEYS:
            if k not in meta:
                continue
            m = np.asarray(meta[k])
            if m.shape[:lead] != stack:
                got, want = (
                    (m.shape[:lead], stack) if is_expert
                    else (f"{m.shape[0]} layer(s)", L)
                )
                problems.append(f"{k} stacks {got}, weight stacks {want}")
            if k in ("I", "J", "resid") and m.size and (
                m.min() < 0 or m.max() >= K
            ):
                problems.append(
                    f"{k} indexes rows [{m.min()}, {m.max()}] outside the "
                    f"weight's K={K}"
                )
        if problems:
            bad += 1
            yield Finding(
                rule="pairing/stacked-shapes",
                severity="error",
                location=path,
                message="; ".join(problems),
                measured=problems,
                expected=f"(layers={L}, …) index arrays into K={K}",
            )
    yield Finding(
        rule="pairing/stacked-shapes",
        severity="info",
        location=ctx.target,
        message=f"{len(pairs) - bad}/{len(pairs)} stacked metadata entries "
                f"agree with their weights",
        measured=len(pairs),
        expected=None,
    )
