"""The repo's single jaxpr-walking implementation.

Every traced-program audit (the fig8 schedule gates, the residual-add audit,
the analysis rules in this package) walks jaxprs through here — there must be
exactly one definition of "what counts as an eqn of the program".

Semantics: the walk visits eqns **per call site**.  A sub-jaxpr referenced
from two *different* eqns (e.g. one jitted function called twice → two pjit
eqns sharing one ClosedJaxpr object) is walked once per eqn, because each
call site executes the computation again — `count_primitives("pallas_call")`
must count kernel launches, not distinct kernel definitions.  Within a
*single* eqn, however, the same sub-jaxpr object referenced from two params
is walked exactly once: it is one computation, whatever bookkeeping the
primitive keeps (the historical walker double-walked this case and inflated
every count).
"""
from __future__ import annotations

from collections.abc import Iterator, Sequence
from typing import Any


def _eqn_sub_jaxprs(eqn) -> Iterator[Any]:
    """Distinct sub-jaxprs carried in one eqn's params.

    Dedup is by identity of the *raw* jaxpr (a ClosedJaxpr and its ``.jaxpr``
    are the same computation), scoped to this eqn — see module docstring.
    """
    seen: set[int] = set()
    for v in eqn.params.values():
        for s in v if isinstance(v, list | tuple) else [v]:
            if hasattr(s, "jaxpr") or hasattr(s, "eqns"):
                raw = getattr(s, "jaxpr", s)
                if id(raw) in seen:
                    continue
                seen.add(id(raw))
                yield s


def walk_eqns_with_stack(jaxpr, _stack: tuple = ()) -> Iterator[tuple[Any, tuple]]:
    """Yield ``(eqn, enclosing_eqns)`` for every eqn of a (closed) jaxpr.

    ``enclosing_eqns`` is the tuple of eqns whose sub-jaxprs contain this one
    (outermost first) — e.g. a ``pallas_call`` inside a decode ``scan`` body
    carries that scan eqn on its stack, which is how the per-decode-layer
    writeback rule attributes kernel launches to layers.
    """
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    for eqn in inner.eqns:
        yield eqn, _stack
        for s in _eqn_sub_jaxprs(eqn):
            yield from walk_eqns_with_stack(s, (*_stack, eqn))


def walk_eqns(jaxpr) -> Iterator[Any]:
    """Yield every eqn of a (closed) jaxpr, descending into call / custom-vjp
    / scan / pallas sub-jaxprs carried in eqn params."""
    for eqn, _ in walk_eqns_with_stack(jaxpr):
        yield eqn


def count_primitives(jaxpr, name: str) -> int:
    """Count occurrences of a primitive across the whole traced program —
    used to audit the fused conv path's schedule (e.g. ``reduce_window_max``
    must be absent, ``pallas_call`` counts HBM writebacks of the conv
    layers)."""
    return sum(1 for eqn in walk_eqns(jaxpr) if eqn.primitive.name == name)


def count_shape_adds(jaxpr, shape: Sequence[int]) -> int:
    """Count ``add`` eqns whose output *and both operands* have ``shape``.

    An ``add`` of two full hidden-state tensors is the signature of a
    standalone residual add (``h + attn(x)`` / ``h + mlp(x)``) — bias adds
    and norm arithmetic broadcast from lower-rank operands and never match.
    Used to audit that the paired decode step executes its residual adds
    inside the kernel epilogue instead.
    """
    shape = tuple(shape)

    def is_resid_add(eqn):
        if eqn.primitive.name != "add":
            return False
        avals = [getattr(v, "aval", None) for v in (*eqn.invars, *eqn.outvars)]
        return all(getattr(a, "shape", None) == shape for a in avals)

    return sum(1 for eqn in walk_eqns(jaxpr) if is_resid_add(eqn))


def pallas_calls_by_scan(jaxpr) -> tuple[int, dict[int, dict]]:
    """(total pallas_calls, {scan position: per-trip launch stats}).

    For every ``scan`` eqn that encloses at least one ``pallas_call``, the
    value records ``{"per_trip": launches inside one body execution,
    "length": static trip count (layers)}``.  Launches are attributed to the
    *innermost* enclosing scan; launches outside any scan are only in the
    total.  The dict is keyed by an opaque per-scan integer (stable within
    one walk) purely to keep distinct scans apart.
    """
    total = 0
    per_scan: dict[int, dict] = {}
    for eqn, stack in walk_eqns_with_stack(jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        total += 1
        scans = [e for e in stack if e.primitive.name == "scan"]
        if not scans:
            continue
        innermost = scans[-1]
        rec = per_scan.setdefault(
            id(innermost),
            {"per_trip": 0, "length": int(innermost.params.get("length", 1))},
        )
        rec["per_trip"] += 1
    return total, per_scan
