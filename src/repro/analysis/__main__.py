"""``python -m repro.analysis`` — run the schedule linter over a target.

Exit code is non-zero iff any error-severity finding fires on any requested
target, which is exactly what the CI ``analysis`` job gates on.
"""
from __future__ import annotations

import argparse
import json
import sys


def main(argv: list[str] | None = None) -> int:
    from repro.analysis.core import run_rules
    from repro.analysis.targets import TARGETS, build_context

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Rule-based jaxpr/HLO schedule linter: gates fusion, "
                    "dtype, VMEM, and pairing invariants.",
    )
    ap.add_argument(
        "--target", choices=(*TARGETS, "all"),
        help="which traced program to lint ('all' runs every target)",
    )
    ap.add_argument(
        "--json", metavar="PATH", default=None,
        help="write the machine-readable report here",
    )
    ap.add_argument(
        "--rules", nargs="*", default=None, metavar="RULE_ID",
        help="run only these rule ids (default: every registered rule)",
    )
    ap.add_argument(
        "--list-rules", action="store_true",
        help="print every registered rule id and exit",
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        from repro.analysis.core import RULE_REGISTRY, _load_rules

        _load_rules()
        for rid, r in sorted(RULE_REGISTRY.items()):
            needs = f"  [needs: {', '.join(r.needs)}]" if r.needs else ""
            print(f"{rid}{needs}")
        return 0
    if args.target is None:
        ap.error("--target is required (unless --list-rules)")

    targets = TARGETS if args.target == "all" else (args.target,)
    reports = []
    for t in targets:
        report = run_rules(build_context(t), rule_ids=args.rules)
        reports.append(report)
        for line in report.summary_lines():
            print(line)
        for rid, need in sorted(report.rules_skipped.items()):
            print(f"  skipped {rid} (target provides no {need})")

    if args.json:
        payload = (
            reports[0].as_dict()
            if len(reports) == 1
            else {"targets": [r.as_dict() for r in reports]}
        )
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, default=str)
        print(f"wrote {args.json}")

    return max(r.exit_code for r in reports)


if __name__ == "__main__":
    sys.exit(main())
