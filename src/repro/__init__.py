"""repro: JAX framework for subtractor-based inference acceleration.

Reproduces and extends "Subtractor-Based CNN Inference Accelerator"
(Gao, Hammad, El-Sankary, Gu — 2023): replacing one multiplication and one
addition with a single subtraction by pairing opposite-sign weights of equal
(rounded) magnitude, trading a controllable amount of accuracy for power/area.

Package layout
--------------
core/      the paper's contribution: weight pairing (Alg. 1), ASIC cost model,
           structured (TPU-native) pairing, model-level transform pass
models/    LeNet-5 (the paper's network) + the 10 assigned LM-family archs
data/      MNIST (with deterministic synthetic fallback) + LM token pipeline
train/     pure-JAX AdamW, train loop, fault-tolerant checkpointing
serving/   KV-cache decode engine
parallel/  mesh / sharding rules (DP / FSDP / TP / EP / pod)
kernels/   Pallas TPU kernels (paired matmul) + jnp oracles
configs/   one config per assigned architecture
launch/    mesh.py, dryrun.py (multi-pod compile-only dry-run), train.py, serve.py
"""

__version__ = "0.1.0"
