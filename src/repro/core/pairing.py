"""Weight pairing — the paper's preprocessing stage (§III.A, Algorithm 1).

The paper's idea: within one convolution filter (one output channel / output
neuron), two weights K_a > 0 and K_b < 0 with |K_a| ≈ |K_b| can be merged:

    I1*K_a + I2*K_b  =  K_a * (I1 - I2)        when K_a = -K_b          (1)

so one multiply + one add is replaced by one subtract (+ the multiply that
remains).  "≈" is controlled by a *rounding size* r: the pair is combined when
| |K_a| - |K_b| | < r, and both are snapped to the common magnitude
k = (|K_a| + |K_b|) / 2.  Accuracy degrades as r grows; power/area of the
ASIC MAC array shrink (see cost_model.py).

Three implementations live here:

1. ``pair_list_twopointer``  — a direct, line-by-line transcription of the
   paper's Algorithm 1 over one weight list (one filter).  Used as the oracle.
2. ``pair_columns``          — the same greedy two-pointer, vectorised across
   all output neurons of a weight matrix at once (lock-step pointer arrays).
   Bit-identical to (1) per column; runs in O(K·N) numpy instead of python.
3. ``pair_rows_structured``  — the TPU-native *structured* variant (ours, not
   the paper's): one pairing of input channels shared by every output neuron,
   so the paired computation stays a dense GEMM with a reduced contraction
   dimension (see kernels/paired_matmul.py).  The per-column magnitude is kept
   exact; only the symmetric part of the paired rows is dropped, bounded by r.
4. ``pair_rows_blocked``     — the spectrum between (2) and (3): one shared-row
   pairing per group of ``block_n`` output neurons.  ``block_n == N`` is
   exactly (3); ``block_n == 1`` reproduces the paper's per-column pairing
   (2) index-for-index, because the structured greedy walk on a single
   column degenerates to Algorithm 1.  Smaller blocks pair more lanes at
   equal rounding (the constraint "one pairing shared by the whole block"
   weakens), at the cost of per-block kernel metadata
   (see kernels/paired_matmul.py, "Column-blocked layout").

All pairing is offline preprocessing (runs once, numpy), exactly as in the
paper ("the weights preprocessing occurs once before deploying the weights").
``core.transform.pair_params`` applies these primitives across whole param
trees — conv kernels, stacked decoder/encoder weights, and per-expert MoE
matrices (one independent pairing per ``(layer, expert)``, stacked
``(L, E, …)`` for the experts-as-blocks kernel layout).
"""
from __future__ import annotations

import dataclasses
from collections.abc import Sequence

import numpy as np

# ---------------------------------------------------------------------------
# 1. Faithful Algorithm 1 (single list — one filter / one output neuron)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PairingResult:
    """Pairing of a single weight list (indices into the original list)."""

    pair_pos: np.ndarray  # (P,) int — index of the positive member
    pair_neg: np.ndarray  # (P,) int — index of the negative member
    pair_mag: np.ndarray  # (P,) float — common magnitude k = (|a|+|b|)/2
    uncombined: np.ndarray  # (U,) int — indices left untouched

    @property
    def n_pairs(self) -> int:
        return int(self.pair_pos.shape[0])


def pair_list_twopointer(w: np.ndarray, rounding: float) -> PairingResult:
    """Algorithm 1 of the paper, verbatim, on one weight list.

    Sorts positives ascending and negatives by magnitude ascending, then walks
    both lists with two pointers; combines when the magnitudes are within
    ``rounding`` of each other, otherwise retires the pointer whose remaining
    candidates can no longer match.
    """
    w = np.asarray(w).reshape(-1)
    pos_idx = np.nonzero(w > 0)[0]
    neg_idx = np.nonzero(w < 0)[0]
    # Sort ascending by magnitude (paper sorts ascending, splits by sign).
    pos_idx = pos_idx[np.argsort(w[pos_idx], kind="stable")]
    neg_idx = neg_idx[np.argsort(-w[neg_idx], kind="stable")]  # |neg| ascending

    pp, pn = 0, 0
    pair_pos, pair_neg, pair_mag = [], [], []
    un: list[int] = []
    while pp < len(pos_idx) and pn < len(neg_idx):
        p = w[pos_idx[pp]]
        m = -w[neg_idx[pn]]
        if p >= m + rounding:  # negative too small — will never match later p
            un.append(int(neg_idx[pn]))
            pn += 1
        elif p <= m - rounding:  # positive too small
            un.append(int(pos_idx[pp]))
            pp += 1
        else:  # combine
            pair_pos.append(int(pos_idx[pp]))
            pair_neg.append(int(neg_idx[pn]))
            pair_mag.append((p + m) / 2.0)
            pp += 1
            pn += 1
    un.extend(int(i) for i in pos_idx[pp:])
    un.extend(int(i) for i in neg_idx[pn:])
    un.extend(int(i) for i in np.nonzero(w == 0)[0])  # zeros never pair
    return PairingResult(
        pair_pos=np.asarray(pair_pos, dtype=np.int64),
        pair_neg=np.asarray(pair_neg, dtype=np.int64),
        pair_mag=np.asarray(pair_mag, dtype=np.float64),
        uncombined=np.asarray(sorted(un), dtype=np.int64),
    )


# ---------------------------------------------------------------------------
# 2. Vectorised per-column pairing (lock-step two-pointer across N columns)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ColumnPairing:
    """Pairing of a (K, N) weight matrix, independently per column.

    ``pair_pos/pair_neg/pair_mag`` are (Pmax, N) arrays padded with -1 / 0;
    ``n_pairs`` is (N,) — the number of valid pairs per column.
    """

    pair_pos: np.ndarray
    pair_neg: np.ndarray
    pair_mag: np.ndarray
    n_pairs: np.ndarray
    shape: tuple[int, int]

    @property
    def total_pairs(self) -> int:
        return int(self.n_pairs.sum())


def pair_columns(W: np.ndarray, rounding: float) -> ColumnPairing:
    """Per-column Algorithm 1, vectorised across columns.

    Semantics are identical to running ``pair_list_twopointer`` on each
    column of ``W`` (tested against it); implementation runs all columns in
    lock-step so that the python loop is O(K) regardless of N.
    """
    W = np.asarray(W)
    assert W.ndim == 2, "pair_columns expects (K, N)"
    K, N = W.shape

    # --- per-column sorted positive values and |negative| values -----------
    # We sort the columns once; positives ascending, negatives by |.| asc.
    # Positions are padded to the max count with +inf sentinels.
    pos_mask = W > 0
    neg_mask = W < 0
    n_pos = pos_mask.sum(axis=0)  # (N,)
    n_neg = neg_mask.sum(axis=0)
    Pmaxp, Pmaxn = int(n_pos.max(initial=0)), int(n_neg.max(initial=0))

    INF = np.inf
    pos_vals = np.full((Pmaxp, N), INF)
    pos_rows = np.full((Pmaxp, N), -1, dtype=np.int64)
    neg_vals = np.full((Pmaxn, N), INF)
    neg_rows = np.full((Pmaxn, N), -1, dtype=np.int64)

    # argsort the full columns, then compact the signed entries to the top.
    order = np.argsort(W, axis=0, kind="stable")  # ascending values
    Ws = np.take_along_axis(W, order, axis=0)
    # positives: ascending slice of sorted column (they are at the bottom end)
    # Build scatter indices vectorised:
    col_ids = np.broadcast_to(np.arange(N), (K, N))
    is_pos = Ws > 0
    # rank of each positive within its column (0-based, ascending value)
    rank_pos = np.cumsum(is_pos, axis=0) - 1
    sel = is_pos
    pos_vals[rank_pos[sel], col_ids[sel]] = Ws[sel]
    pos_rows[rank_pos[sel], col_ids[sel]] = order[sel]
    # negatives: |.| ascending == value descending
    desc = Ws[::-1]
    order_desc = order[::-1]
    is_neg_d = desc < 0
    rank_neg = np.cumsum(is_neg_d, axis=0) - 1
    seln = is_neg_d
    neg_vals[rank_neg[seln], col_ids[seln]] = -desc[seln]  # store magnitude
    neg_rows[rank_neg[seln], col_ids[seln]] = order_desc[seln]

    # --- lock-step two-pointer walk ----------------------------------------
    Pmax = min(Pmaxp, Pmaxn)
    pair_pos = np.full((max(Pmax, 1), N), -1, dtype=np.int64)
    pair_neg = np.full((max(Pmax, 1), N), -1, dtype=np.int64)
    pair_mag = np.zeros((max(Pmax, 1), N))
    n_pairs = np.zeros(N, dtype=np.int64)

    pp = np.zeros(N, dtype=np.int64)
    pn = np.zeros(N, dtype=np.int64)
    cols = np.arange(N)
    # Each iteration advances every active column's pointer by >= 1, so the
    # loop runs at most Pmaxp + Pmaxn times in total.
    for _ in range(Pmaxp + Pmaxn):
        active = (pp < n_pos) & (pn < n_neg)
        if not active.any():
            break
        p = pos_vals[np.minimum(pp, Pmaxp - 1), cols]
        m = neg_vals[np.minimum(pn, Pmaxn - 1), cols]
        neg_small = active & (p >= m + rounding)
        pos_small = active & (p <= m - rounding)
        combine = active & ~neg_small & ~pos_small
        if combine.any():
            c = cols[combine]
            r = n_pairs[combine]
            pair_pos[r, c] = pos_rows[pp[combine], c]
            pair_neg[r, c] = neg_rows[pn[combine], c]
            pair_mag[r, c] = (p[combine] + m[combine]) / 2.0
            n_pairs[combine] += 1
        pn[neg_small | combine] += 1
        pp[pos_small | combine] += 1

    used = int(n_pairs.max(initial=0))
    return ColumnPairing(
        pair_pos=pair_pos[: max(used, 1)],
        pair_neg=pair_neg[: max(used, 1)],
        pair_mag=pair_mag[: max(used, 1)],
        n_pairs=n_pairs,
        shape=(K, N),
    )


def fold_columns(W: np.ndarray, cp: ColumnPairing) -> np.ndarray:
    """Materialise the *paired-equivalent* weight matrix W'.

    W' is the matrix that a plain dense matmul must use to produce bit-wise
    the same result as the subtractor dataflow: each combined pair (a, b) of
    column n is snapped to (+k, -k) with k = (|W[a,n]| + |W[b,n]|)/2.
    This is how accuracy of the technique is evaluated (the arithmetic
    rewrite (1) is exact once the weights are snapped).
    """
    Wf = np.array(W, copy=True)
    P, N = cp.pair_pos.shape
    valid = cp.pair_pos >= 0
    cols = np.broadcast_to(np.arange(N), (P, N))
    Wf[cp.pair_pos[valid], cols[valid]] = cp.pair_mag[valid]
    Wf[cp.pair_neg[valid], cols[valid]] = -cp.pair_mag[valid]
    return Wf


# ---------------------------------------------------------------------------
# 3. Structured pairing (TPU-native, ours): shared (i, j) pairs across columns
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StructuredPairing:
    """One pairing of *rows* (input channels) shared by all N columns.

    The paired matmul computes::

        y = (x[:, I] - x[:, J]) @ Kmat + x[:, R] @ W_res

    which is exactly ``x @ W_approx`` with W_approx[I] = +Kmat,
    W_approx[J] = -Kmat, W_approx[R] = W_res.  The contraction length drops
    from K to P + (K - 2P): every pair saves one MXU multiply-accumulate lane,
    the TPU analogue of the paper's mult+add → sub replacement.

    I, J: (P,) int row indices; Kmat: (P, N); resid: (R,) int; W_res: (R, N).
    """

    I: np.ndarray
    J: np.ndarray
    Kmat: np.ndarray
    resid: np.ndarray
    W_res: np.ndarray
    shape: tuple[int, int]

    @property
    def n_pairs(self) -> int:
        return int(self.I.shape[0])

    @property
    def weighted_pairs(self) -> int:
        """Per-column-equivalent pair count: every shared pair removes one
        contraction lane for each of the N columns it spans (the quantity
        Table I compares across pairing modes)."""
        return self.n_pairs * int(self.shape[1])

    def fold(self) -> np.ndarray:
        """Dense W_approx equivalent (for accuracy eval / oracle)."""
        K, N = self.shape
        Wf = np.zeros((K, N), dtype=self.Kmat.dtype)
        Wf[self.I] = self.Kmat
        Wf[self.J] = -self.Kmat
        Wf[self.resid] = self.W_res
        return Wf

    def perm(self) -> np.ndarray:
        """Row permutation [I | J | resid] used by the Pallas kernel."""
        return np.concatenate([self.I, self.J, self.resid])


def pair_rows_structured(
    W: np.ndarray,
    rounding: float,
    *,
    criterion: str = "rms",
) -> StructuredPairing:
    """Find one row pairing shared by every column of W (K, N).

    Greedy two-pointer on the per-row mean weight (the same sort-and-walk
    shape as Algorithm 1, lifted from scalars to row profiles), validated by
    the chosen norm of the *symmetric part* s = (W[i] + W[j]) / 2:

        criterion == "rms":  pair iff  rms(W[i] + W[j]) < rounding
        criterion == "max":  pair iff  max|W[i] + W[j]| < rounding

    For a combined pair the per-column magnitude k_n = (W[i,n] - W[j,n]) / 2
    is kept *exactly*; only s (bounded by `rounding`) is dropped.  Columns
    therefore keep individual magnitudes — only the pair structure is shared,
    which is what lets the computation stay a dense GEMM on the MXU.
    """
    W = np.asarray(W, dtype=np.float64)
    K, N = W.shape
    mean = W.mean(axis=1)
    pos_idx = np.nonzero(mean > 0)[0]
    # Exactly-zero mean rows never pair (Algorithm 1 skips zero weights);
    # retiring them here also makes the N == 1 case degenerate *exactly* to
    # ``pair_list_twopointer``, which ``pair_rows_blocked(block_n=1)`` relies
    # on to reproduce the paper's per-column ledger.
    neg_idx = np.nonzero(mean < 0)[0]
    zero_idx = np.nonzero(mean == 0)[0]
    pos_idx = pos_idx[np.argsort(mean[pos_idx], kind="stable")]
    neg_idx = neg_idx[np.argsort(-mean[neg_idx], kind="stable")]

    if criterion == "rms":
        def sym_err(i: int, j: int) -> float:
            s = (W[i] + W[j])
            return float(np.sqrt(np.mean(s * s)))
    elif criterion == "max":
        def sym_err(i: int, j: int) -> float:
            return float(np.max(np.abs(W[i] + W[j])))
    else:  # pragma: no cover
        raise ValueError(f"unknown criterion {criterion!r}")

    pp, pn = 0, 0
    I, J = [], []
    resid: list[int] = []
    while pp < len(pos_idx) and pn < len(neg_idx):
        i, j = int(pos_idx[pp]), int(neg_idx[pn])
        p, m = mean[i], -mean[j]
        if p >= m + rounding:
            resid.append(j)
            pn += 1
        elif p <= m - rounding:
            resid.append(i)
            pp += 1
        elif sym_err(i, j) < rounding:
            I.append(i)
            J.append(j)
            pp += 1
            pn += 1
        else:
            # profiles don't cancel even though means do — retire the one
            # with the smaller mean magnitude (it has fewer future partners).
            if p <= m:
                resid.append(i)
                pp += 1
            else:
                resid.append(j)
                pn += 1
    resid.extend(int(i) for i in pos_idx[pp:])
    resid.extend(int(j) for j in neg_idx[pn:])
    resid.extend(int(z) for z in zero_idx)

    I_a = np.asarray(I, dtype=np.int64)
    J_a = np.asarray(J, dtype=np.int64)
    R_a = np.asarray(sorted(resid), dtype=np.int64)
    Kmat = (W[I_a] - W[J_a]) / 2.0 if len(I) else np.zeros((0, N))
    return StructuredPairing(
        I=I_a, J=J_a, Kmat=Kmat, resid=R_a, W_res=W[R_a], shape=(K, N)
    )


# ---------------------------------------------------------------------------
# 4. Column-blocked pairing: one shared-row pairing per group of block_n cols
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BlockedPairing:
    """Independent :class:`StructuredPairing` per contiguous block of columns.

    ``blocks[b]`` pairs columns ``[b·block_n, min((b+1)·block_n, N))`` of the
    (K, N) weight matrix; only the *last* block may span fewer than
    ``block_n`` columns.  ``block_n == N`` collapses to a single structured
    pairing; ``block_n == 1`` is the paper's per-column pairing
    (one Algorithm-1 walk per output neuron).

    The kernel consumes the *packed* layout built by :meth:`index_arrays`:
    every block's ``[I | J | resid]`` lane lists padded to the common
    ``(Pmax, Rmax)`` so one ``(n_blocks, 2·Pmax + Rmax)`` index matrix (and
    one gather) covers all blocks — padded lanes point at row 0 and carry
    zero weights, so they contribute nothing to the contraction.
    """

    blocks: list[StructuredPairing]
    block_n: int
    shape: tuple[int, int]

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    @property
    def n_pairs(self) -> int:
        """Subtractions the kernel executes per output position: each block
        computes its own x[I]−x[J] differences, shared by its columns."""
        return sum(sp.n_pairs for sp in self.blocks)

    @property
    def weighted_pairs(self) -> int:
        """Per-column-equivalent pairs (MXU lanes saved per output position):
        a pair in a block of n_b columns removes one lane from each."""
        return sum(sp.n_pairs * sp.shape[1] for sp in self.blocks)

    @property
    def Pmax(self) -> int:
        return max((sp.n_pairs for sp in self.blocks), default=0)

    @property
    def Rmax(self) -> int:
        return max((len(sp.resid) for sp in self.blocks), default=0)

    def block_cols(self, b: int) -> tuple[int, int]:
        """[start, stop) column range of block ``b``."""
        start = b * self.block_n
        return start, min(start + self.block_n, self.shape[1])

    def fold(self) -> np.ndarray:
        """Dense W_approx equivalent (accuracy eval / kernel oracle)."""
        K, N = self.shape
        Wf = np.zeros((K, N))
        for b, sp in enumerate(self.blocks):
            lo, hi = self.block_cols(b)
            Wf[:, lo:hi] = sp.fold()
        return Wf

    def index_arrays(self) -> dict[str, np.ndarray]:
        """Packed per-block lane metadata for the blocked Pallas kernel.

        Returns int64 / float64 arrays:

        * ``I``, ``J`` — (n_blocks, Pmax) paired row indices, padded with 0;
        * ``resid``    — (n_blocks, Rmax) residual row indices, padded with 0;
        * ``pair_mask`` / ``resid_mask`` — (n_blocks, Pmax/Rmax) 1.0 on real
          entries, 0.0 on padding (multiplied into the packed weight
          segments, so padded lanes contract against zeros);
        * ``perm``     — (n_blocks, 2·Pmax + Rmax) = [I | J | resid] per
          block: the packed lane-permutation matrix one activation gather
          consumes.
        """
        B, P, R = self.n_blocks, self.Pmax, self.Rmax
        I_m = np.zeros((B, P), dtype=np.int64)
        J_m = np.zeros((B, P), dtype=np.int64)
        R_m = np.zeros((B, R), dtype=np.int64)
        pmask = np.zeros((B, P))
        rmask = np.zeros((B, R))
        for b, sp in enumerate(self.blocks):
            p, r = sp.n_pairs, len(sp.resid)
            I_m[b, :p] = sp.I
            J_m[b, :p] = sp.J
            R_m[b, :r] = sp.resid
            pmask[b, :p] = 1.0
            rmask[b, :r] = 1.0
        return {
            "I": I_m,
            "J": J_m,
            "resid": R_m,
            "pair_mask": pmask,
            "resid_mask": rmask,
            "perm": np.concatenate([I_m, J_m, R_m], axis=1),
        }

    def packed_weights(self) -> tuple[np.ndarray, np.ndarray]:
        """Offline (Kmat, W_res) in the kernel's packed block-major layout.

        ``Kmat`` is (n_blocks, Pmax, block_n) and ``W_res`` is
        (n_blocks, Rmax, block_n); padded rows *and* the short last block's
        padded columns are zero.  The live-weight analogue (differentiable,
        recomputed inside the trace) lives in ``kernels.paired_conv``.
        """
        B, P, R, bn = self.n_blocks, self.Pmax, self.Rmax, self.block_n
        km = np.zeros((B, max(P, 0), bn))
        wr = np.zeros((B, max(R, 0), bn))
        for b, sp in enumerate(self.blocks):
            lo, hi = self.block_cols(b)
            ncols = hi - lo
            km[b, : sp.n_pairs, :ncols] = sp.Kmat
            wr[b, : len(sp.resid), :ncols] = sp.W_res
        return km, wr


def pair_rows_blocked(
    W: np.ndarray,
    rounding: float,
    block_n: int,
    *,
    criterion: str = "rms",
) -> BlockedPairing:
    """One structured (shared-row) pairing per group of ``block_n`` columns.

    The spectrum knob between the kernel-native structured pairing and the
    paper's per-column pairing:

    * ``block_n >= N`` — a single block: identical to
      :func:`pair_rows_structured` (same I/J/resid).
    * ``block_n == 1`` — one block per column: identical pair indices and
      magnitudes to :func:`pair_columns` / Algorithm 1 (the greedy walk on a
      one-column mean profile *is* Algorithm 1, and the symmetric-error check
      coincides with the rounding window).

    Smaller blocks weaken the shared-row constraint, so the weighted pair
    count is (weakly) monotone as ``block_n`` shrinks on real weights.
    """
    W = np.asarray(W, dtype=np.float64)
    assert W.ndim == 2, "pair_rows_blocked expects (K, N)"
    K, N = W.shape
    assert block_n >= 1, f"block_n must be >= 1, got {block_n}"
    block_n = min(block_n, N)
    blocks = [
        pair_rows_structured(W[:, lo : min(lo + block_n, N)], rounding,
                             criterion=criterion)
        for lo in range(0, N, block_n)
    ]
    return BlockedPairing(blocks=blocks, block_n=block_n, shape=(K, N))


# ---------------------------------------------------------------------------
# 5. Shard-constrained pairing: rows never pair across a TP shard boundary
# ---------------------------------------------------------------------------


def concat_structured(
    parts: list[StructuredPairing],
    offsets: list[int],
    shape: tuple[int, int],
) -> StructuredPairing:
    """Concatenate per-row-shard pairings into one pairing of the full matrix.

    ``parts[s]`` pairs the rows ``[offsets[s], offsets[s] + parts[s].shape[0])``
    of the (K, N) matrix; indices are rebased to global rows.  Because every
    part's residual list is sorted and offsets increase, the concatenated
    residual list stays sorted — downstream consumers (``index_arrays``,
    ``perm``) rely only on index validity, not ordering, but keeping the
    invariant makes per-shard slices of the result bit-compare against
    independently built shard pairings.
    """
    N = shape[1]
    I = np.concatenate([p.I + o for p, o in zip(parts, offsets)]) \
        if parts else np.zeros(0, np.int64)
    J = np.concatenate([p.J + o for p, o in zip(parts, offsets)]) \
        if parts else np.zeros(0, np.int64)
    resid = np.concatenate([p.resid + o for p, o in zip(parts, offsets)]) \
        if parts else np.zeros(0, np.int64)
    Kmat = (
        np.concatenate([p.Kmat for p in parts], axis=0)
        if parts else np.zeros((0, N))
    )
    W_res = (
        np.concatenate([p.W_res for p in parts], axis=0)
        if parts else np.zeros((0, N))
    )
    return StructuredPairing(
        I=I.astype(np.int64), J=J.astype(np.int64), Kmat=Kmat,
        resid=resid.astype(np.int64), W_res=W_res, shape=shape,
    )


def pair_rows_structured_sharded(
    W: np.ndarray,
    rounding: float,
    *,
    criterion: str = "rms",
    row_shards: int = 1,
) -> StructuredPairing:
    """:func:`pair_rows_structured` constrained to ``row_shards`` row blocks.

    Tensor-parallel splits of a *contraction*-sharded weight (attention
    out-projection, MLP down-projection) give each device a contiguous slab
    of rows; a pair whose two rows live on different devices would need its
    subtrahend gathered every step.  This variant pairs each row slab
    independently (exactly what a per-device preprocessor would build from
    its local shard) and rebases indices, so slicing the result at shard
    boundaries reproduces the standalone per-shard pairings bit for bit.

    ``row_shards`` that don't divide K fall back to the unsharded pairing —
    the same degradation rule ``parallel.sharding`` applies to the weight.
    """
    W = np.asarray(W, dtype=np.float64)
    K, _ = W.shape
    if row_shards <= 1 or K % row_shards:
        return pair_rows_structured(W, rounding, criterion=criterion)
    step = K // row_shards
    offsets = [s * step for s in range(row_shards)]
    parts = [
        pair_rows_structured(W[o : o + step], rounding, criterion=criterion)
        for o in offsets
    ]
    return concat_structured(parts, offsets, shape=W.shape)


def pair_rows_blocked_sharded(
    W: np.ndarray,
    rounding: float,
    block_n: int,
    *,
    criterion: str = "rms",
    row_shards: int = 1,
) -> BlockedPairing:
    """:func:`pair_rows_blocked` with every block's rows shard-constrained.

    Column sharding needs no constraint here: blocks are column-local, so a
    column-parallel split that lands on block boundaries simply partitions
    the block list — each shard's blocks are identical to what that shard
    would build from its local columns (asserted by the mesh-decode bench).
    """
    W = np.asarray(W, dtype=np.float64)
    assert W.ndim == 2, "pair_rows_blocked_sharded expects (K, N)"
    _, N = W.shape
    assert block_n >= 1, f"block_n must be >= 1, got {block_n}"
    block_n = min(block_n, N)
    blocks = [
        pair_rows_structured_sharded(
            W[:, lo : min(lo + block_n, N)], rounding,
            criterion=criterion, row_shards=row_shards,
        )
        for lo in range(0, N, block_n)
    ]
    return BlockedPairing(blocks=blocks, block_n=block_n, shape=W.shape)


# ---------------------------------------------------------------------------
# Op accounting (Table I of the paper)
# ---------------------------------------------------------------------------


def pairing_op_counts(
    total_weights: int, n_pairs: int, positions: int = 1
) -> dict[str, int]:
    """Mult/add/sub counts for one layer under the paper's accounting.

    A layer with ``total_weights`` MAC weights applied at ``positions``
    output positions costs ``total_weights * positions`` multiplies and the
    same number of additions at baseline.  Every combined pair replaces, per
    position, one multiply and one addition with a single subtraction
    (eq. (1): two MACs become one subtract + one MAC).
    """
    base = total_weights * positions
    subs = n_pairs * positions
    return {
        "mults": base - subs,
        "adds": base - subs,
        "subs": subs,
        "total": 2 * base - subs,
        "baseline_total": 2 * base,
    }


def column_pairing_for_conv(kernel: np.ndarray, rounding: float) -> ColumnPairing:
    """Pair a conv kernel (H, W, Cin, Cout) per output channel (per filter).

    This matches the paper: combinations are sought *within one filter*, since
    both members of a pair must accumulate into the same output value for
    eq. (1) to apply.
    """
    H, Wd, Cin, Cout = kernel.shape
    return pair_columns(kernel.reshape(H * Wd * Cin, Cout), rounding)


def sweep_rounding(
    weights: Sequence[np.ndarray],
    positions: Sequence[int],
    roundings: Sequence[float],
) -> list[dict[str, float]]:
    """Table-I style sweep: op counts for a list of conv weight matrices.

    ``weights[i]`` is a (K_i, N_i) per-column weight matrix (already reshaped
    from the conv kernel), applied at ``positions[i]`` output positions.
    """
    rows = []
    for r in roundings:
        mults = adds = subs = 0
        for Wm, pos in zip(weights, positions, strict=True):
            cp = pair_columns(Wm, r)
            c = pairing_op_counts(Wm.size, cp.total_pairs, pos)
            mults += c["mults"]
            adds += c["adds"]
            subs += c["subs"]
        rows.append(
            {
                "rounding": float(r),
                "adds": int(adds),
                "subs": int(subs),
                "mults": int(mults),
                "total": int(adds + subs + mults),
            }
        )
    return rows
