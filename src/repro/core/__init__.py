"""Core: the paper's contribution — subtractor weight pairing + cost model."""

from repro.core.pairing import (  # noqa: F401
    PairingResult,
    ColumnPairing,
    StructuredPairing,
    BlockedPairing,
    pair_list_twopointer,
    pair_columns,
    fold_columns,
    pair_rows_structured,
    pair_rows_blocked,
    pairing_op_counts,
    column_pairing_for_conv,
    sweep_rounding,
)
from repro.core.cost_model import (  # noqa: F401
    AsicCostModel,
    TpuRoofline,
    TPU_V5E,
    OpCounts,
)
from repro.core.transform import (  # noqa: F401
    pair_model_params,
    PairedModelReport,
)
