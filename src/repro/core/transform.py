"""Model-level pairing pass: apply the paper's preprocessing to a whole model.

This is the framework integration of the paper's "weight preprocessor" block
(Fig. 5): it walks a parameter pytree, finds every eligible weight
contraction, runs the pairing, and returns

* the *paired-equivalent* parameters (``fold``ed weights — a drop-in
  replacement; the forward pass is unchanged and bit-identical to the
  subtractor dataflow), and
* a :class:`PairedModelReport` with per-leaf pair counts, the Table-I style
  op ledger, and the modeled ASIC power/area savings.

For the TPU fast path (structured pairing + Pallas kernel) use
``mode="structured"``; the report then also carries the per-leaf
:class:`StructuredPairing` objects that `kernels/ops.py` consumes.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable
from typing import Any

import jax
import numpy as np

from repro.core.cost_model import AsicCostModel, OpCounts
from repro.core.pairing import (
    BlockedPairing,
    ColumnPairing,
    StructuredPairing,
    fold_columns,
    pair_columns,
    pair_rows_blocked,
    pair_rows_blocked_sharded,
    pair_rows_structured,
    pair_rows_structured_sharded,
)


@dataclasses.dataclass
class LeafReport:
    path: str
    shape: tuple[int, ...]
    n_weights: int
    n_pairs: int
    pair_fraction: float  # fraction of weights absorbed into pairs (2P/K·N)
    pairing: ColumnPairing | StructuredPairing | BlockedPairing | None = None
    # shard-aware builds (pair_params(shards=…)): how the leaf's GEMM view was
    # split and the per-shard ledger — per-column-equivalent pairs owned by
    # each column shard (col_shards > 1) or each row shard (row_shards > 1),
    # summed over layers.  sum(shard_pairs) == n_pairs by construction; the
    # mesh-decode bench additionally checks each entry against a standalone
    # pairing of that shard's weight slice.
    row_shards: int = 1
    col_shards: int = 1
    shard_pairs: tuple[int, ...] | None = None


@dataclasses.dataclass
class PairedModelReport:
    rounding: float
    mode: str
    leaves: list[LeafReport]

    @property
    def total_weights(self) -> int:
        return sum(l.n_weights for l in self.leaves)

    @property
    def total_pairs(self) -> int:
        return sum(l.n_pairs for l in self.leaves)

    @property
    def pair_fraction(self) -> float:
        tw = self.total_weights
        return 2.0 * self.total_pairs / tw if tw else 0.0

    def op_counts(self) -> OpCounts:
        """Whole-model op ledger (positions=1: one application per weight,
        i.e. GEMM accounting; conv positions are handled by the LeNet bench)."""
        base = self.total_weights
        subs = self.total_pairs
        return OpCounts(mults=base - subs, adds=base - subs, subs=subs)

    def baseline_op_counts(self) -> OpCounts:
        return OpCounts(mults=self.total_weights, adds=self.total_weights, subs=0)

    def savings(self, model: AsicCostModel | None = None) -> dict[str, float]:
        m = model or AsicCostModel()
        return {
            "power_saving": m.power_saving(self.baseline_op_counts(), self.op_counts()),
            "area_saving": m.area_saving(self.baseline_op_counts(), self.op_counts()),
            "pair_fraction": self.pair_fraction,
        }


@dataclasses.dataclass
class PairedLayer:
    """Per-conv-layer deployment artifact for the Pallas paired-conv path.

    Produced offline by :func:`build_conv_pairings` (the paper's one-time
    weight preprocessing), consumed at inference by
    ``kernels.paired_conv.paired_conv`` — the pairing carries only the *index
    structure* (which patch lanes subtract); magnitudes are recomputed from
    the live weights inside the traced forward, so the artifact stays valid
    under ``jax.grad`` and after weight updates.
    """

    name: str
    kernel_shape: tuple[int, ...]  # (kh, kw, cin, cout)
    rounding: float
    pairing: StructuredPairing | BlockedPairing
    positions: int = 1  # output spatial positions per image (conv M-dim)

    @property
    def n_pairs(self) -> int:
        """Subtractions the kernel executes per output position (for a
        BlockedPairing: summed over blocks — each block subtracts its own
        x[I]−x[J] differences)."""
        return self.pairing.n_pairs

    def measured_op_counts(self) -> dict[str, int]:
        """What the paired kernel *executes* per inference image.

        Baseline MXU lanes equal the paper's multiply count for the layer
        (K·N·positions); every pair removes one contraction lane from each
        column it spans (all N for structured, its block's columns for
        column-blocked — ``weighted_pairs`` counts exactly that) and runs
        one VPU subtract per position.
        """
        kh, kw, cin, cout = self.kernel_shape
        K, N = kh * kw * cin, cout
        baseline = K * N * self.positions
        saved = self.pairing.weighted_pairs * self.positions
        return {
            "baseline_lanes": baseline,
            "paired_lanes": baseline - saved,
            "lanes_saved": saved,
            "subs_executed": self.n_pairs * self.positions,
        }


def build_conv_pairings(
    params: Any,
    rounding: float,
    *,
    positions: dict[str, int] | None = None,
    criterion: str = "rms",
    mode: str = "structured",
    block_n: int = 0,
) -> dict[str, PairedLayer]:
    """Emit a :class:`PairedLayer` artifact for every conv leaf of ``params``.

    ``params`` is a ``{layer_name: {"w": (kh, kw, cin, cout), ...}}`` tree
    (the LeNet layout); each 4-D float ``w`` is flattened to the im2col GEMM
    matrix (K, N) and paired for the Pallas kernel.  ``mode`` selects the
    pairing spectrum point: ``"structured"`` (default — one shared-row
    pairing for all N output channels), ``"column_blocked"`` (one pairing
    per ``block_n`` output channels; requires ``block_n >= 1``), or
    ``"per_column"`` (the paper's pairing — sugar for column_blocked with
    ``block_n=1``).  ``positions`` maps layer names to output spatial
    positions (e.g. ``models.lenet.LENET_CONV_POSITIONS``) so the artifacts
    can report measured per-image op counts.
    """
    if mode == "per_column":
        mode, block_n = "column_blocked", 1
    assert mode in ("structured", "column_blocked"), f"unknown mode {mode!r}"
    if mode == "column_blocked" and block_n < 1:
        raise ValueError("mode='column_blocked' needs block_n >= 1")
    arts: dict[str, PairedLayer] = {}
    for name, leaf in params.items():
        if not isinstance(leaf, dict) or "w" not in leaf:
            continue
        w = np.asarray(leaf["w"])
        if w.ndim != 4 or w.dtype.kind != "f":
            continue
        kh, kw, cin, cout = w.shape
        wm = w.reshape(kh * kw * cin, cout).astype(np.float64)
        if mode == "column_blocked":
            sp: StructuredPairing | BlockedPairing = pair_rows_blocked(
                wm, rounding, block_n, criterion=criterion
            )
        else:
            sp = pair_rows_structured(wm, rounding, criterion=criterion)
        arts[name] = PairedLayer(
            name=name,
            kernel_shape=tuple(w.shape),
            rounding=rounding,
            pairing=sp,
            positions=(positions or {}).get(name, 1),
        )
    return arts


def _path_str(path: Any) -> str:
    return jax.tree_util.keystr(path)


# ---------------------------------------------------------------------------
# LM pairing: artifacts for the decoder stack (kernel-executable, scan-ready)
# ---------------------------------------------------------------------------

# Decoder weights of the *dense GQA* families.  Keys are (sub-path, weight
# name); "wo" contracts over all-but-last axes (the attention out-projection
# einsum "bshk,hkd->bsd"), everything else over its leading axis.  Kept as a
# public name: tests and benches import it, and it seeds the model-agnostic
# superset below.
LM_PAIRED_WEIGHTS: tuple[tuple[str, str], ...] = (
    ("attn", "wq"),
    ("attn", "wk"),
    ("attn", "wv"),
    ("attn", "wo"),
    ("mlp", "w_gate"),
    ("mlp", "w_up"),
    ("mlp", "w_down"),
)

# Model-agnostic superset of pairing-eligible leaf specs across the model
# zoo: dense GQA projections, the MLA down-projections (wq/w_dkv/w_kr/wo —
# w_uk/w_uv stay absorbed in latent einsums), per-expert MoE weights (the
# leading-expert-axis batched GEMMs), shared experts (nested sub-path), the
# Mamba in/out projections, and the enc-dec cross-attention wq/wo (which
# route through ``layers.dense``; the cross wk/wv run once over the encoder
# output at prefill as plain einsums and stay unpaired).  ``pair_params``
# intersects this with what a tree actually carries unless the caller pins
# an explicit ``leaves=`` list (``ModelConfig.paired_leaves``).  Embeddings,
# norms, biases, routers, and the conv-scan kernels are deliberately absent:
# they are not plain GEMMs or never route through ``layers.dense``.
DEFAULT_PAIRED_LEAVES: tuple[tuple[str, str], ...] = LM_PAIRED_WEIGHTS + (
    ("attn", "w_dkv"),
    ("attn", "w_kr"),
    ("xattn", "wq"),
    ("xattn", "wo"),
    ("moe", "w_gate"),
    ("moe", "w_up"),
    ("moe", "w_down"),
    ("moe.shared", "w_gate"),
    ("moe.shared", "w_up"),
    ("moe.shared", "w_down"),
    ("mamba", "w_z"),
    ("mamba", "w_x"),
    ("mamba", "w_B"),
    ("mamba", "w_C"),
    ("mamba", "w_dt"),
    ("mamba", "w_out"),
)


def _resolve_sub(seg: Any, sub_path: str) -> dict | None:
    """The sub-dict at a dotted ``sub_path`` of a layer dict, or None."""
    node = seg
    for part in sub_path.split("."):
        if not isinstance(node, dict) or part not in node:
            return None
        node = node[part]
    return node if isinstance(node, dict) else None


def _set_sub(seg: dict, sub_path: str, new_sub: dict) -> None:
    """Replace the sub-dict at ``sub_path``, shallow-copying intermediates."""
    parts = sub_path.split(".")
    node = seg
    for part in parts[:-1]:
        node[part] = dict(node[part])
        node = node[part]
    node[parts[-1]] = new_sub


def _lm_weight_matrix_shape(name: str, shape: tuple[int, ...]) -> tuple[int, int]:
    """(K, N) GEMM view of one *per-layer* decoder weight shape."""
    if name == "wo":
        K = int(np.prod(shape[:-1]))
        return K, int(shape[-1])
    return int(shape[0]), int(np.prod(shape[1:]))


def _stack_structured(pairings: list[StructuredPairing]) -> dict[str, np.ndarray]:
    """Pad per-layer structured pairings to a common (Pmax, Rmax) and stack.

    Padded pair lanes point ``I == J == 0`` (their subtract is exactly
    zero) and padded residual lanes at row 0 with a zero mask, so padding
    contracts against nothing — the zero-lane trick the kernel's k-tile
    padding already relies on.
    """
    L = len(pairings)
    P = max((sp.n_pairs for sp in pairings), default=0)
    R = max((len(sp.resid) for sp in pairings), default=0)
    I_m = np.zeros((L, P), np.int32)
    J_m = np.zeros((L, P), np.int32)
    R_m = np.zeros((L, R), np.int32)
    pmask = np.zeros((L, P), np.float32)
    rmask = np.zeros((L, R), np.float32)
    for l, sp in enumerate(pairings):
        p, r = sp.n_pairs, len(sp.resid)
        I_m[l, :p] = sp.I
        J_m[l, :p] = sp.J
        R_m[l, :r] = sp.resid
        pmask[l, :p] = 1.0
        rmask[l, :r] = 1.0
    return {"I": I_m, "J": J_m, "resid": R_m,
            "pair_mask": pmask, "resid_mask": rmask}


def _stack_blocked(pairings: list[BlockedPairing]) -> dict[str, np.ndarray]:
    """Pad per-layer blocked index matrices to common (Pmax, Rmax), stack."""
    L = len(pairings)
    B = pairings[0].n_blocks
    P = max(bp.Pmax for bp in pairings)
    R = max(bp.Rmax for bp in pairings)
    I_m = np.zeros((L, B, P), np.int32)
    J_m = np.zeros((L, B, P), np.int32)
    R_m = np.zeros((L, B, R), np.int32)
    pmask = np.zeros((L, B, P), np.float32)
    rmask = np.zeros((L, B, R), np.float32)
    for l, bp in enumerate(pairings):
        idx = bp.index_arrays()
        p, r = bp.Pmax, bp.Rmax
        I_m[l, :, :p] = idx["I"]
        J_m[l, :, :p] = idx["J"]
        R_m[l, :, :r] = idx["resid"]
        pmask[l, :, :p] = idx["pair_mask"]
        rmask[l, :, :r] = idx["resid_mask"]
    return {"I": I_m, "J": J_m, "resid": R_m,
            "pair_mask": pmask, "resid_mask": rmask}


def _any_pairing(node: Any) -> bool:
    if not isinstance(node, dict):
        return False
    return any(k.endswith("_pairing") or _any_pairing(v) for k, v in node.items())


def has_lm_pairing(params: Any) -> bool:
    """True iff ``params`` already carries pair_params metadata (any depth:
    decoder segments, encoder segments, nested shared-expert blocks)."""
    if not isinstance(params, dict):
        return False
    trees = [params.get("segments", [])]
    enc = params.get("encoder")
    if isinstance(enc, dict):
        trees.append(enc.get("segments", []))
    return any(_any_pairing(seg) for segs in trees for seg in segs)


def _pair_conv_tree(
    params: Any,
    rounding: float,
    *,
    mode: str,
    block_n: int,
    criterion: str,
    min_dim: int,
) -> tuple[Any, PairedModelReport]:
    """The conv-tree arm of :func:`pair_params`: ``{name: {"w": 4-D}}``.

    Emits the same ``"w_pairing"`` metadata-sibling layout as the LM arm,
    just unstacked (no layer axis — conv trees are not scanned).  The
    executable conv path keeps consuming :func:`build_conv_pairings`
    artifacts; this arm exists so one entry point reports any tree.
    """
    leaves_report: list[LeafReport] = []
    out = dict(params)
    for name, leaf in params.items():
        if not isinstance(leaf, dict) or "w" not in leaf:
            continue
        w = np.asarray(leaf["w"])
        if w.ndim != 4 or w.dtype.kind != "f":
            continue
        kh, kw, cin, cout = w.shape
        K, N = kh * kw * cin, cout
        if K < min_dim or N < min_dim:
            continue
        wm = w.reshape(K, N).astype(np.float64)
        if mode == "column_blocked":
            bp = pair_rows_blocked(wm, rounding, block_n, criterion=criterion)
            idx = bp.index_arrays()
            meta = {
                "I": idx["I"].astype(np.int32),
                "J": idx["J"].astype(np.int32),
                "resid": idx["resid"].astype(np.int32),
                "pair_mask": idx["pair_mask"].astype(np.float32),
                "resid_mask": idx["resid_mask"].astype(np.float32),
            }
            n_pairs = bp.weighted_pairs
            pairing: StructuredPairing | BlockedPairing = bp
        else:
            sp = pair_rows_structured(wm, rounding, criterion=criterion)
            meta = {
                "I": np.asarray(sp.I, np.int32),
                "J": np.asarray(sp.J, np.int32),
                "resid": np.asarray(sp.resid, np.int32),
                "pair_mask": np.ones(sp.n_pairs, np.float32),
                "resid_mask": np.ones(len(sp.resid), np.float32),
            }
            n_pairs = sp.weighted_pairs
            pairing = sp
        new_leaf = dict(leaf)
        new_leaf["w_pairing"] = meta
        out[name] = new_leaf
        leaves_report.append(
            LeafReport(
                path=f"{name}.w",
                shape=tuple(w.shape),
                n_weights=int(w.size),
                n_pairs=int(n_pairs),
                pair_fraction=2.0 * n_pairs / w.size,
                pairing=pairing,
            )
        )
    if not leaves_report:
        raise ValueError(
            "pair_params: no pairing-eligible conv leaves — expected a "
            "{name: {'w': (kh, kw, cin, cout)}} tree with float kernels of "
            f"GEMM dims >= {min_dim}; got keys {sorted(params)!r}"
        )
    report = PairedModelReport(rounding=rounding, mode=mode, leaves=leaves_report)
    return out, report


def _structured_shard_ledger(
    pairings: list[StructuredPairing], row_shards: int
) -> tuple[int, ...]:
    """Per-row-shard weighted pair counts (both rows of a shard-constrained
    pair live in the same shard, so attribution by I is exact)."""
    out = np.zeros(row_shards, np.int64)
    for sp in pairings:
        step = sp.shape[0] // row_shards
        if len(sp.I):
            idx = np.minimum(np.asarray(sp.I, np.int64) // step, row_shards - 1)
            out += np.bincount(idx, minlength=row_shards) * sp.shape[1]
    return tuple(int(x) for x in out)


def _blocked_shard_ledger(
    pairings: list[BlockedPairing], row_shards: int, col_shards: int
) -> tuple[int, ...] | None:
    """Per-shard weighted pair counts of a blocked build, summed over layers.

    Column shards own contiguous runs of blocks (the alignment check in
    ``pair_stack`` guarantees block boundaries land on shard boundaries);
    with only row shards, pairs are attributed by which row slab they
    live in.
    """
    if col_shards > 1:
        out = np.zeros(col_shards, np.int64)
        for bp in pairings:
            per = bp.n_blocks // col_shards
            for b, sp in enumerate(bp.blocks):
                out[min(b // per, col_shards - 1)] += sp.n_pairs * sp.shape[1]
        return tuple(int(x) for x in out)
    if row_shards > 1:
        out = np.zeros(row_shards, np.int64)
        for bp in pairings:
            step = bp.shape[0] // row_shards
            for sp in bp.blocks:
                if len(sp.I):
                    idx = np.minimum(
                        np.asarray(sp.I, np.int64) // step, row_shards - 1
                    )
                    out += np.bincount(idx, minlength=row_shards) * sp.shape[1]
        return tuple(int(x) for x in out)
    return None


def tp_shard_plan(
    param_axes: Any,
    params: Any,
    mesh,
    rules,
    *,
    leaves: tuple[tuple[str, str], ...] | None = None,
) -> dict[tuple[str, str], tuple[int, int]]:
    """(row_shards, col_shards) of every paired leaf's per-layer GEMM view.

    Resolves each eligible weight's logical axes against (mesh, rules) —
    the same ``spec_for_axes`` call that will place the weight — and counts
    how many ways the GEMM's contraction rows and output columns are split.
    A split only counts when it is the *leading* dim of the flattened view
    (contiguous chunks; a sharded trailing dim like head_dim would interleave
    and cannot express a contiguous row/column split — such leaves stay at 1,
    which is always safe: unconstrained metadata is correct everywhere, it
    just loses shard locality).  Leaves that appear with conflicting splits
    (e.g. an encoder head count that doesn't divide where the decoder's does)
    degrade to (1, 1).

    Feed the result to ``pair_params(shards=…)`` so pairing never crosses a
    shard boundary of the mesh the decode will run on.
    """
    from repro.parallel.sharding import spec_for_axes

    def mesh_size(entry) -> int:
        names = (entry,) if isinstance(entry, str) else tuple(entry)
        size = 1
        for a in names:
            size *= mesh.shape[a]
        return size

    specs = tuple(leaves) if leaves is not None else DEFAULT_PAIRED_LEAVES
    plan: dict[tuple[str, str], tuple[int, int]] = {}

    def scan_segments(ax_segments: list, val_segments: list) -> None:
        for ax_seg, val_seg in zip(ax_segments, val_segments, strict=True):
            for sub_path, w_name in specs:
                ax_sub = _resolve_sub(ax_seg, sub_path)
                val_sub = _resolve_sub(val_seg, sub_path)
                if ax_sub is None or val_sub is None or w_name not in ax_sub:
                    continue
                w_axes = ax_sub[w_name]
                shape = tuple(getattr(val_sub[w_name], "shape", ()))
                if not isinstance(w_axes, tuple) or len(w_axes) != len(shape):
                    continue
                nd = len(shape)
                expert = sub_path.split(".")[-1] == "moe" and nd == 4
                mat0 = 2 if expert else 1
                if nd <= mat0:
                    continue
                spec = spec_for_axes(
                    w_axes, mesh=mesh, rules=rules, dim_sizes=shape
                )
                if w_name == "wo":
                    row_dims = list(range(mat0, nd - 1))
                    col_dims = [nd - 1]
                else:
                    row_dims = [mat0]
                    col_dims = list(range(mat0 + 1, nd))

                def split(dims, spec=spec):
                    lead = spec[dims[0]]
                    if lead is None or any(spec[d] is not None for d in dims[1:]):
                        return 1
                    return mesh_size(lead)

                rc = (split(row_dims), split(col_dims))
                key = (sub_path, w_name)
                if key in plan and plan[key] != rc:
                    plan[key] = (1, 1)
                else:
                    plan[key] = rc

    scan_segments(
        param_axes.get("segments", []), params.get("segments", [])
    )
    ax_enc, val_enc = param_axes.get("encoder"), params.get("encoder")
    if isinstance(ax_enc, dict) and isinstance(val_enc, dict):
        scan_segments(ax_enc.get("segments", []), val_enc.get("segments", []))
    return plan


def pair_params(
    params: Any,
    rounding: float,
    *,
    mode: str = "structured",
    block_n: int = 0,
    leaves: tuple[tuple[str, str], ...] | None = None,
    criterion: str = "rms",
    min_dim: int = 8,
    shards: Any = None,
) -> tuple[Any, PairedModelReport]:
    """Pairing artifacts for every eligible weight of *any* param tree.

    One model-agnostic entry point covering the whole zoo:

    * **conv trees** (``{name: {"w": 4-D}}``, no ``"segments"`` key) — each
      kernel paired as its im2col GEMM matrix, unstacked metadata;
    * **stacked decoder/encoder weights** (``params["segments"]`` and
      ``params["encoder"]["segments"]``, the lax.scan layout) — per-layer
      pairings padded to the segment-wide (Pmax, Rmax) and stacked on the
      layer axis, which a scan slices exactly like the weights themselves;
    * **leading-expert-axis batched weights** (MoE ``(L, E, K, F)`` leaves)
      — paired per layer *per expert*, metadata stacked ``(L, E, …)`` so the
      expert axis rides next to the layer axis and the blocked kernel can
      treat experts as column blocks.

    Leaf selection is by ``(sub-path, weight-name)`` specs — dotted
    sub-paths address nested blocks (``"moe.shared"``).  With ``leaves=None``
    the :data:`DEFAULT_PAIRED_LEAVES` superset is intersected with what the
    tree carries; passing an explicit list (``ModelConfig.paired_leaves``)
    additionally *requires* every spec to match at least one segment, so a
    renamed or mistyped weight fails loudly instead of silently falling off
    the paired path.  Either way a tree yielding *no* pairing metadata at
    all raises, listing what was looked for and what the tree carries.

    Returns ``(params', report)``: the same tree with a sibling
    ``"<name>_pairing"`` metadata entry next to each paired weight.  Weights
    are **not** folded — magnitudes are recomputed live inside the trace
    (``kernels.ops.fused_paired_dense`` / ``fused_paired_expert_dense``), so
    the artifact survives ``jax.grad`` and weight updates.

    ``mode`` picks the pairing-spectrum point: ``"structured"`` (one
    shared-row pairing per matrix), ``"column_blocked"`` (one per
    ``block_n`` output columns), or ``"per_column"`` (sugar for
    ``block_n=1`` — the paper's Algorithm 1).

    ``shards`` (optional) makes the build *shard-aware*: a mapping from
    ``(sub_path, weight_name)`` to ``(row_shards, col_shards)`` of the
    leaf's per-layer GEMM view (:func:`tp_shard_plan` derives one from a
    mesh + rule table).  Row shards constrain the pairing so no pair spans a
    contraction-shard boundary (each tensor-parallel device's metadata is
    exactly what it would build from its local rows); column shards are
    checked for block alignment (a shard boundary must not split a pairing
    block — misaligned leaves degrade to an unsharded build) and drive the
    per-shard ledger in each :class:`LeafReport`.  Shard counts that don't
    divide the leaf's dims degrade to 1, mirroring the replication fallback
    of ``parallel.sharding.spec_for_axes``.
    """
    if mode == "per_column":
        mode, block_n = "column_blocked", 1
    assert mode in ("structured", "column_blocked"), f"unknown mode {mode!r}"
    if mode == "column_blocked" and block_n < 1:
        raise ValueError("mode='column_blocked' needs block_n >= 1")
    if isinstance(params, dict) and "segments" not in params:
        return _pair_conv_tree(
            params, rounding, mode=mode, block_n=block_n,
            criterion=criterion, min_dim=min_dim,
        )

    specs = tuple(leaves) if leaves is not None else DEFAULT_PAIRED_LEAVES
    matched: set[tuple[str, str]] = set()
    leaves_report: list[LeafReport] = []

    def pair_stack(
        mats: np.ndarray, row_shards: int = 1, col_shards: int = 1
    ) -> tuple[dict[str, np.ndarray], int, tuple[int, ...] | None, int, int]:
        """Pair a (n, K, N) stack → (stacked metadata, weighted pair count,
        per-shard ledger, effective row/col shards)."""
        K, N = int(mats.shape[1]), int(mats.shape[2])
        rs = row_shards if row_shards > 1 and K % row_shards == 0 else 1
        cs = col_shards if col_shards > 1 and N % col_shards == 0 else 1
        if mode == "column_blocked":
            bn = min(block_n, N)
            if cs > 1 and (N // cs) % bn:
                cs = 1  # a shard boundary would split a block — keep whole
            ps_b = [
                pair_rows_blocked_sharded(
                    m, rounding, bn, criterion=criterion, row_shards=rs
                )
                for m in mats
            ]
            return (
                _stack_blocked(ps_b),
                sum(p.weighted_pairs for p in ps_b),
                _blocked_shard_ledger(ps_b, rs, cs),
                rs, cs,
            )
        # structured: pairs are whole rows, so a *column* split never cuts
        # them — only the contraction (row) axis needs the shard constraint
        ps_s = [
            pair_rows_structured_sharded(
                m, rounding, criterion=criterion, row_shards=rs
            )
            for m in mats
        ]
        return (
            _stack_structured(ps_s),
            sum(p.weighted_pairs for p in ps_s),
            _structured_shard_ledger(ps_s, rs) if rs > 1 else None,
            rs, 1,
        )

    def pair_segments(segments: list, prefix: str) -> list:
        new_segs = []
        for si, seg in enumerate(segments):
            new_seg = dict(seg)
            for sub_path, w_name in specs:
                sub = _resolve_sub(new_seg, sub_path)
                if sub is None or w_name not in sub:
                    continue
                matched.add((sub_path, w_name))
                arr = np.asarray(sub[w_name])
                if arr.dtype.kind != "f" or arr.ndim < 3:
                    continue  # stacked (layers, …) float matrices only
                L = arr.shape[0]
                # MoE expert weights carry a second leading (expert) axis:
                # pair each expert's (K, F) matrix separately.
                expert = sub_path.split(".")[-1] == "moe" and arr.ndim == 4
                mat_shape = arr.shape[2:] if expert else arr.shape[1:]
                K, N = _lm_weight_matrix_shape(w_name, mat_shape)
                if K < min_dim or N < min_dim:
                    continue
                want_rs, want_cs = (1, 1)
                if shards is not None:
                    want_rs, want_cs = shards.get((sub_path, w_name), (1, 1))
                mats = arr.reshape(-1, K, N).astype(np.float64)
                meta, n_pairs, shard_pairs, rs, cs = pair_stack(
                    mats, want_rs, want_cs
                )
                if expert:
                    E = arr.shape[1]
                    meta = {
                        k: v.reshape(L, E, *v.shape[1:]) for k, v in meta.items()
                    }
                new_sub = dict(_resolve_sub(new_seg, sub_path))
                new_sub[w_name + "_pairing"] = meta
                _set_sub(new_seg, sub_path, new_sub)
                leaves_report.append(
                    LeafReport(
                        path=f"{prefix}[{si}].{sub_path}.{w_name}",
                        shape=tuple(arr.shape),
                        n_weights=int(mats.size),
                        n_pairs=int(n_pairs),
                        pair_fraction=2.0 * n_pairs / mats.size,
                        row_shards=rs,
                        col_shards=cs,
                        shard_pairs=shard_pairs,
                    )
                )
            new_segs.append(new_seg)
        return new_segs

    out = dict(params)
    out["segments"] = pair_segments(params.get("segments", []), "segments")
    enc = params.get("encoder")
    if isinstance(enc, dict) and isinstance(enc.get("segments"), list):
        enc = dict(enc)
        enc["segments"] = pair_segments(enc["segments"], "encoder.segments")
        out["encoder"] = enc

    unmatched = [s for s in specs if s not in matched]
    if leaves is not None and unmatched:
        raise ValueError(
            "pair_params: no weight matched leaf spec(s) "
            + ", ".join(f"{sp}.{wn}" for sp, wn in unmatched)
            + " — check the config's paired_leaves declaration against the "
            "param tree (sub-blocks present: "
            + ", ".join(sorted({
                k for seg in params.get("segments", [])
                for k, v in seg.items() if isinstance(v, dict)
            }))
            + ")"
        )
    if not leaves_report:
        raise ValueError(
            "pair_params: no pairing-eligible weights found — looked for "
            + ", ".join(f"{sp}.{wn}" for sp, wn in specs)
            + " among stacked float matrices with GEMM dims >= "
            f"{min_dim}; tree carries sub-blocks "
            + ", ".join(sorted({
                k for seg in params.get("segments", [])
                for k, v in seg.items() if isinstance(v, dict)
            }))
        )
    report = PairedModelReport(rounding=rounding, mode=mode, leaves=leaves_report)
    return out, report


def pair_lm_params(
    params: Any,
    rounding: float,
    *,
    mode: str = "structured",
    block_n: int = 0,
    criterion: str = "rms",
    min_dim: int = 8,
    shards: Any = None,
) -> tuple[Any, PairedModelReport]:
    """Backward-compatible LM entry point: :func:`pair_params` in auto mode.

    Pairs whatever subset of :data:`DEFAULT_PAIRED_LEAVES` the tree carries
    (a plain GQA tree yields exactly the :data:`LM_PAIRED_WEIGHTS` seven);
    raises if nothing matches at all.
    """
    return pair_params(
        params, rounding, mode=mode, block_n=block_n,
        criterion=criterion, min_dim=min_dim, shards=shards,
    )


def pair_model_params(
    params: Any,
    rounding: float,
    *,
    mode: str = "per_column",
    block_n: int = 0,
    min_dim: int = 8,
    predicate: Callable[[str, np.ndarray], bool] | None = None,
    keep_pairings: bool = False,
) -> tuple[Any, PairedModelReport]:
    """Pair every eligible weight leaf of ``params``.

    Eligible = float array, ndim in (2, 4), both contraction dims >= min_dim,
    and ``predicate(path, leaf)`` (if given) returns True.  4-D leaves are
    treated as conv kernels (H, W, Cin, Cout) and paired per filter, exactly
    as the paper does for LeNet-5; 2-D leaves (K, N) are paired per column
    (= per output neuron).

    ``mode`` picks the pairing spectrum point: ``"per_column"`` (the paper's
    Algorithm 1, default), ``"structured"`` (one shared-row pairing per
    leaf — the original TPU kernel layout), or ``"column_blocked"`` (one
    shared-row pairing per ``block_n`` output columns — the kernel-executable
    mode that closes most of the structured-vs-per-column pairing gap;
    requires ``block_n >= 1``).

    Returns (paired_params, report).  ``paired_params`` has the same treedef;
    only eligible leaves are replaced by their folded equivalents.
    """
    if mode == "column_blocked" and block_n < 1:
        raise ValueError("mode='column_blocked' needs block_n >= 1")
    leaves_report: list[LeafReport] = []

    def handle(path, leaf):
        if not isinstance(leaf, np.ndarray | jax.Array):
            return leaf
        arr = np.asarray(leaf)
        if arr.dtype.kind != "f" or arr.ndim not in (2, 4):
            return leaf
        if arr.ndim == 4:
            H, Wd, Cin, Cout = arr.shape
            mat = arr.reshape(H * Wd * Cin, Cout)
        else:
            mat = arr
        if mat.shape[0] < min_dim or mat.shape[1] < min_dim:
            return leaf
        pstr = _path_str(path)
        if predicate is not None and not predicate(pstr, arr):
            return leaf

        mat64 = mat.astype(np.float64)
        if mode == "per_column":
            cp = pair_columns(mat64, rounding)
            folded = fold_columns(mat64, cp)
            n_pairs = cp.total_pairs
            pairing: ColumnPairing | StructuredPairing | BlockedPairing = cp
        elif mode == "structured":
            sp = pair_rows_structured(mat64, rounding)
            folded = sp.fold()
            n_pairs = sp.weighted_pairs  # one pair row spans N columns
            pairing = sp
        elif mode == "column_blocked":
            bp = pair_rows_blocked(mat64, rounding, block_n)
            folded = bp.fold()
            n_pairs = bp.weighted_pairs  # per-column-equivalent count
            pairing = bp
        else:
            raise ValueError(f"unknown mode {mode!r}")

        leaves_report.append(
            LeafReport(
                path=pstr,
                shape=tuple(arr.shape),
                n_weights=int(mat.size),
                n_pairs=int(n_pairs),
                pair_fraction=2.0 * n_pairs / mat.size,
                pairing=pairing if keep_pairings else None,
            )
        )
        return folded.reshape(arr.shape).astype(arr.dtype)

    paired = jax.tree_util.tree_map_with_path(handle, params)
    report = PairedModelReport(rounding=rounding, mode=mode, leaves=leaves_report)
    return paired, report
