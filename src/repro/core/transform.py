"""Model-level pairing pass: apply the paper's preprocessing to a whole model.

This is the framework integration of the paper's "weight preprocessor" block
(Fig. 5): it walks a parameter pytree, finds every eligible weight
contraction, runs the pairing, and returns

* the *paired-equivalent* parameters (``fold``ed weights — a drop-in
  replacement; the forward pass is unchanged and bit-identical to the
  subtractor dataflow), and
* a :class:`PairedModelReport` with per-leaf pair counts, the Table-I style
  op ledger, and the modeled ASIC power/area savings.

For the TPU fast path (structured pairing + Pallas kernel) use
``mode="structured"``; the report then also carries the per-leaf
:class:`StructuredPairing` objects that `kernels/ops.py` consumes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np

from repro.core.cost_model import AsicCostModel, OpCounts
from repro.core.pairing import (
    BlockedPairing,
    ColumnPairing,
    StructuredPairing,
    fold_columns,
    pair_columns,
    pair_rows_blocked,
    pair_rows_structured,
)


@dataclasses.dataclass
class LeafReport:
    path: str
    shape: tuple[int, ...]
    n_weights: int
    n_pairs: int
    pair_fraction: float  # fraction of weights absorbed into pairs (2P/K·N)
    pairing: ColumnPairing | StructuredPairing | BlockedPairing | None = None


@dataclasses.dataclass
class PairedModelReport:
    rounding: float
    mode: str
    leaves: list[LeafReport]

    @property
    def total_weights(self) -> int:
        return sum(l.n_weights for l in self.leaves)

    @property
    def total_pairs(self) -> int:
        return sum(l.n_pairs for l in self.leaves)

    @property
    def pair_fraction(self) -> float:
        tw = self.total_weights
        return 2.0 * self.total_pairs / tw if tw else 0.0

    def op_counts(self) -> OpCounts:
        """Whole-model op ledger (positions=1: one application per weight,
        i.e. GEMM accounting; conv positions are handled by the LeNet bench)."""
        base = self.total_weights
        subs = self.total_pairs
        return OpCounts(mults=base - subs, adds=base - subs, subs=subs)

    def baseline_op_counts(self) -> OpCounts:
        return OpCounts(mults=self.total_weights, adds=self.total_weights, subs=0)

    def savings(self, model: AsicCostModel | None = None) -> dict[str, float]:
        m = model or AsicCostModel()
        return {
            "power_saving": m.power_saving(self.baseline_op_counts(), self.op_counts()),
            "area_saving": m.area_saving(self.baseline_op_counts(), self.op_counts()),
            "pair_fraction": self.pair_fraction,
        }


@dataclasses.dataclass
class PairedLayer:
    """Per-conv-layer deployment artifact for the Pallas paired-conv path.

    Produced offline by :func:`build_conv_pairings` (the paper's one-time
    weight preprocessing), consumed at inference by
    ``kernels.paired_conv.paired_conv`` — the pairing carries only the *index
    structure* (which patch lanes subtract); magnitudes are recomputed from
    the live weights inside the traced forward, so the artifact stays valid
    under ``jax.grad`` and after weight updates.
    """

    name: str
    kernel_shape: tuple[int, ...]  # (kh, kw, cin, cout)
    rounding: float
    pairing: StructuredPairing | BlockedPairing
    positions: int = 1  # output spatial positions per image (conv M-dim)

    @property
    def n_pairs(self) -> int:
        """Subtractions the kernel executes per output position (for a
        BlockedPairing: summed over blocks — each block subtracts its own
        x[I]−x[J] differences)."""
        return self.pairing.n_pairs

    def measured_op_counts(self) -> dict[str, int]:
        """What the paired kernel *executes* per inference image.

        Baseline MXU lanes equal the paper's multiply count for the layer
        (K·N·positions); every pair removes one contraction lane from each
        column it spans (all N for structured, its block's columns for
        column-blocked — ``weighted_pairs`` counts exactly that) and runs
        one VPU subtract per position.
        """
        kh, kw, cin, cout = self.kernel_shape
        K, N = kh * kw * cin, cout
        baseline = K * N * self.positions
        saved = self.pairing.weighted_pairs * self.positions
        return {
            "baseline_lanes": baseline,
            "paired_lanes": baseline - saved,
            "lanes_saved": saved,
            "subs_executed": self.n_pairs * self.positions,
        }


def build_conv_pairings(
    params: Any,
    rounding: float,
    *,
    positions: dict[str, int] | None = None,
    criterion: str = "rms",
    mode: str = "structured",
    block_n: int = 0,
) -> dict[str, PairedLayer]:
    """Emit a :class:`PairedLayer` artifact for every conv leaf of ``params``.

    ``params`` is a ``{layer_name: {"w": (kh, kw, cin, cout), ...}}`` tree
    (the LeNet layout); each 4-D float ``w`` is flattened to the im2col GEMM
    matrix (K, N) and paired for the Pallas kernel.  ``mode`` selects the
    pairing spectrum point: ``"structured"`` (default — one shared-row
    pairing for all N output channels), ``"column_blocked"`` (one pairing
    per ``block_n`` output channels; requires ``block_n >= 1``), or
    ``"per_column"`` (the paper's pairing — sugar for column_blocked with
    ``block_n=1``).  ``positions`` maps layer names to output spatial
    positions (e.g. ``models.lenet.LENET_CONV_POSITIONS``) so the artifacts
    can report measured per-image op counts.
    """
    if mode == "per_column":
        mode, block_n = "column_blocked", 1
    assert mode in ("structured", "column_blocked"), f"unknown mode {mode!r}"
    if mode == "column_blocked" and block_n < 1:
        raise ValueError("mode='column_blocked' needs block_n >= 1")
    arts: dict[str, PairedLayer] = {}
    for name, leaf in params.items():
        if not isinstance(leaf, dict) or "w" not in leaf:
            continue
        w = np.asarray(leaf["w"])
        if w.ndim != 4 or w.dtype.kind != "f":
            continue
        kh, kw, cin, cout = w.shape
        wm = w.reshape(kh * kw * cin, cout).astype(np.float64)
        if mode == "column_blocked":
            sp: StructuredPairing | BlockedPairing = pair_rows_blocked(
                wm, rounding, block_n, criterion=criterion
            )
        else:
            sp = pair_rows_structured(wm, rounding, criterion=criterion)
        arts[name] = PairedLayer(
            name=name,
            kernel_shape=tuple(w.shape),
            rounding=rounding,
            pairing=sp,
            positions=(positions or {}).get(name, 1),
        )
    return arts


def _path_str(path: Any) -> str:
    return jax.tree_util.keystr(path)


def pair_model_params(
    params: Any,
    rounding: float,
    *,
    mode: str = "per_column",
    block_n: int = 0,
    min_dim: int = 8,
    predicate: Callable[[str, np.ndarray], bool] | None = None,
    keep_pairings: bool = False,
) -> tuple[Any, PairedModelReport]:
    """Pair every eligible weight leaf of ``params``.

    Eligible = float array, ndim in (2, 4), both contraction dims >= min_dim,
    and ``predicate(path, leaf)`` (if given) returns True.  4-D leaves are
    treated as conv kernels (H, W, Cin, Cout) and paired per filter, exactly
    as the paper does for LeNet-5; 2-D leaves (K, N) are paired per column
    (= per output neuron).

    ``mode`` picks the pairing spectrum point: ``"per_column"`` (the paper's
    Algorithm 1, default), ``"structured"`` (one shared-row pairing per
    leaf — the original TPU kernel layout), or ``"column_blocked"`` (one
    shared-row pairing per ``block_n`` output columns — the kernel-executable
    mode that closes most of the structured-vs-per-column pairing gap;
    requires ``block_n >= 1``).

    Returns (paired_params, report).  ``paired_params`` has the same treedef;
    only eligible leaves are replaced by their folded equivalents.
    """
    if mode == "column_blocked" and block_n < 1:
        raise ValueError("mode='column_blocked' needs block_n >= 1")
    leaves_report: list[LeafReport] = []

    def handle(path, leaf):
        if not isinstance(leaf, (np.ndarray, jax.Array)):
            return leaf
        arr = np.asarray(leaf)
        if arr.dtype.kind != "f" or arr.ndim not in (2, 4):
            return leaf
        if arr.ndim == 4:
            H, Wd, Cin, Cout = arr.shape
            mat = arr.reshape(H * Wd * Cin, Cout)
        else:
            mat = arr
        if mat.shape[0] < min_dim or mat.shape[1] < min_dim:
            return leaf
        pstr = _path_str(path)
        if predicate is not None and not predicate(pstr, arr):
            return leaf

        mat64 = mat.astype(np.float64)
        if mode == "per_column":
            cp = pair_columns(mat64, rounding)
            folded = fold_columns(mat64, cp)
            n_pairs = cp.total_pairs
            pairing: ColumnPairing | StructuredPairing | BlockedPairing = cp
        elif mode == "structured":
            sp = pair_rows_structured(mat64, rounding)
            folded = sp.fold()
            n_pairs = sp.weighted_pairs  # one pair row spans N columns
            pairing = sp
        elif mode == "column_blocked":
            bp = pair_rows_blocked(mat64, rounding, block_n)
            folded = bp.fold()
            n_pairs = bp.weighted_pairs  # per-column-equivalent count
            pairing = bp
        else:
            raise ValueError(f"unknown mode {mode!r}")

        leaves_report.append(
            LeafReport(
                path=pstr,
                shape=tuple(arr.shape),
                n_weights=int(mat.size),
                n_pairs=int(n_pairs),
                pair_fraction=2.0 * n_pairs / mat.size,
                pairing=pairing if keep_pairings else None,
            )
        )
        return folded.reshape(arr.shape).astype(arr.dtype)

    paired = jax.tree_util.tree_map_with_path(handle, params)
    report = PairedModelReport(rounding=rounding, mode=mode, leaves=leaves_report)
    return paired, report
