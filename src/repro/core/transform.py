"""Model-level pairing pass: apply the paper's preprocessing to a whole model.

This is the framework integration of the paper's "weight preprocessor" block
(Fig. 5): it walks a parameter pytree, finds every eligible weight
contraction, runs the pairing, and returns

* the *paired-equivalent* parameters (``fold``ed weights — a drop-in
  replacement; the forward pass is unchanged and bit-identical to the
  subtractor dataflow), and
* a :class:`PairedModelReport` with per-leaf pair counts, the Table-I style
  op ledger, and the modeled ASIC power/area savings.

For the TPU fast path (structured pairing + Pallas kernel) use
``mode="structured"``; the report then also carries the per-leaf
:class:`StructuredPairing` objects that `kernels/ops.py` consumes.
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable
from typing import Any

import jax
import numpy as np

from repro.core.cost_model import AsicCostModel, OpCounts
from repro.core.pairing import (
    BlockedPairing,
    ColumnPairing,
    StructuredPairing,
    fold_columns,
    pair_columns,
    pair_rows_blocked,
    pair_rows_structured,
)


@dataclasses.dataclass
class LeafReport:
    path: str
    shape: tuple[int, ...]
    n_weights: int
    n_pairs: int
    pair_fraction: float  # fraction of weights absorbed into pairs (2P/K·N)
    pairing: ColumnPairing | StructuredPairing | BlockedPairing | None = None


@dataclasses.dataclass
class PairedModelReport:
    rounding: float
    mode: str
    leaves: list[LeafReport]

    @property
    def total_weights(self) -> int:
        return sum(l.n_weights for l in self.leaves)

    @property
    def total_pairs(self) -> int:
        return sum(l.n_pairs for l in self.leaves)

    @property
    def pair_fraction(self) -> float:
        tw = self.total_weights
        return 2.0 * self.total_pairs / tw if tw else 0.0

    def op_counts(self) -> OpCounts:
        """Whole-model op ledger (positions=1: one application per weight,
        i.e. GEMM accounting; conv positions are handled by the LeNet bench)."""
        base = self.total_weights
        subs = self.total_pairs
        return OpCounts(mults=base - subs, adds=base - subs, subs=subs)

    def baseline_op_counts(self) -> OpCounts:
        return OpCounts(mults=self.total_weights, adds=self.total_weights, subs=0)

    def savings(self, model: AsicCostModel | None = None) -> dict[str, float]:
        m = model or AsicCostModel()
        return {
            "power_saving": m.power_saving(self.baseline_op_counts(), self.op_counts()),
            "area_saving": m.area_saving(self.baseline_op_counts(), self.op_counts()),
            "pair_fraction": self.pair_fraction,
        }


@dataclasses.dataclass
class PairedLayer:
    """Per-conv-layer deployment artifact for the Pallas paired-conv path.

    Produced offline by :func:`build_conv_pairings` (the paper's one-time
    weight preprocessing), consumed at inference by
    ``kernels.paired_conv.paired_conv`` — the pairing carries only the *index
    structure* (which patch lanes subtract); magnitudes are recomputed from
    the live weights inside the traced forward, so the artifact stays valid
    under ``jax.grad`` and after weight updates.
    """

    name: str
    kernel_shape: tuple[int, ...]  # (kh, kw, cin, cout)
    rounding: float
    pairing: StructuredPairing | BlockedPairing
    positions: int = 1  # output spatial positions per image (conv M-dim)

    @property
    def n_pairs(self) -> int:
        """Subtractions the kernel executes per output position (for a
        BlockedPairing: summed over blocks — each block subtracts its own
        x[I]−x[J] differences)."""
        return self.pairing.n_pairs

    def measured_op_counts(self) -> dict[str, int]:
        """What the paired kernel *executes* per inference image.

        Baseline MXU lanes equal the paper's multiply count for the layer
        (K·N·positions); every pair removes one contraction lane from each
        column it spans (all N for structured, its block's columns for
        column-blocked — ``weighted_pairs`` counts exactly that) and runs
        one VPU subtract per position.
        """
        kh, kw, cin, cout = self.kernel_shape
        K, N = kh * kw * cin, cout
        baseline = K * N * self.positions
        saved = self.pairing.weighted_pairs * self.positions
        return {
            "baseline_lanes": baseline,
            "paired_lanes": baseline - saved,
            "lanes_saved": saved,
            "subs_executed": self.n_pairs * self.positions,
        }


def build_conv_pairings(
    params: Any,
    rounding: float,
    *,
    positions: dict[str, int] | None = None,
    criterion: str = "rms",
    mode: str = "structured",
    block_n: int = 0,
) -> dict[str, PairedLayer]:
    """Emit a :class:`PairedLayer` artifact for every conv leaf of ``params``.

    ``params`` is a ``{layer_name: {"w": (kh, kw, cin, cout), ...}}`` tree
    (the LeNet layout); each 4-D float ``w`` is flattened to the im2col GEMM
    matrix (K, N) and paired for the Pallas kernel.  ``mode`` selects the
    pairing spectrum point: ``"structured"`` (default — one shared-row
    pairing for all N output channels), ``"column_blocked"`` (one pairing
    per ``block_n`` output channels; requires ``block_n >= 1``), or
    ``"per_column"`` (the paper's pairing — sugar for column_blocked with
    ``block_n=1``).  ``positions`` maps layer names to output spatial
    positions (e.g. ``models.lenet.LENET_CONV_POSITIONS``) so the artifacts
    can report measured per-image op counts.
    """
    if mode == "per_column":
        mode, block_n = "column_blocked", 1
    assert mode in ("structured", "column_blocked"), f"unknown mode {mode!r}"
    if mode == "column_blocked" and block_n < 1:
        raise ValueError("mode='column_blocked' needs block_n >= 1")
    arts: dict[str, PairedLayer] = {}
    for name, leaf in params.items():
        if not isinstance(leaf, dict) or "w" not in leaf:
            continue
        w = np.asarray(leaf["w"])
        if w.ndim != 4 or w.dtype.kind != "f":
            continue
        kh, kw, cin, cout = w.shape
        wm = w.reshape(kh * kw * cin, cout).astype(np.float64)
        if mode == "column_blocked":
            sp: StructuredPairing | BlockedPairing = pair_rows_blocked(
                wm, rounding, block_n, criterion=criterion
            )
        else:
            sp = pair_rows_structured(wm, rounding, criterion=criterion)
        arts[name] = PairedLayer(
            name=name,
            kernel_shape=tuple(w.shape),
            rounding=rounding,
            pairing=sp,
            positions=(positions or {}).get(name, 1),
        )
    return arts


def _path_str(path: Any) -> str:
    return jax.tree_util.keystr(path)


# ---------------------------------------------------------------------------
# LM pairing: artifacts for the decoder stack (kernel-executable, scan-ready)
# ---------------------------------------------------------------------------

# Decoder weights the paired LM path routes through the subtractor kernel.
# Keys are (sub-dict, weight name); "wo" contracts over all-but-last axes
# (the attention out-projection einsum "bshk,hkd->bsd"), everything else
# over its leading axis.  Embeddings, norms, biases and the MLA latent
# projections are deliberately absent: norms/biases are not GEMMs, the
# embedding/lm_head gather-shaped matmuls never go through layers.dense,
# and MLA blocks absorb their projections into the latent-space einsums.
LM_PAIRED_WEIGHTS: tuple[tuple[str, str], ...] = (
    ("attn", "wq"),
    ("attn", "wk"),
    ("attn", "wv"),
    ("attn", "wo"),
    ("mlp", "w_gate"),
    ("mlp", "w_up"),
    ("mlp", "w_down"),
)


def _lm_weight_matrix_shape(name: str, shape: tuple[int, ...]) -> tuple[int, int]:
    """(K, N) GEMM view of one *per-layer* decoder weight shape."""
    if name == "wo":
        K = int(np.prod(shape[:-1]))
        return K, int(shape[-1])
    return int(shape[0]), int(np.prod(shape[1:]))


def _stack_structured(pairings: list[StructuredPairing]) -> dict[str, np.ndarray]:
    """Pad per-layer structured pairings to a common (Pmax, Rmax) and stack.

    Padded pair lanes point ``I == J == 0`` (their subtract is exactly
    zero) and padded residual lanes at row 0 with a zero mask, so padding
    contracts against nothing — the zero-lane trick the kernel's k-tile
    padding already relies on.
    """
    L = len(pairings)
    P = max((sp.n_pairs for sp in pairings), default=0)
    R = max((len(sp.resid) for sp in pairings), default=0)
    I_m = np.zeros((L, P), np.int32)
    J_m = np.zeros((L, P), np.int32)
    R_m = np.zeros((L, R), np.int32)
    pmask = np.zeros((L, P), np.float32)
    rmask = np.zeros((L, R), np.float32)
    for l, sp in enumerate(pairings):
        p, r = sp.n_pairs, len(sp.resid)
        I_m[l, :p] = sp.I
        J_m[l, :p] = sp.J
        R_m[l, :r] = sp.resid
        pmask[l, :p] = 1.0
        rmask[l, :r] = 1.0
    return {"I": I_m, "J": J_m, "resid": R_m,
            "pair_mask": pmask, "resid_mask": rmask}


def _stack_blocked(pairings: list[BlockedPairing]) -> dict[str, np.ndarray]:
    """Pad per-layer blocked index matrices to common (Pmax, Rmax), stack."""
    L = len(pairings)
    B = pairings[0].n_blocks
    P = max(bp.Pmax for bp in pairings)
    R = max(bp.Rmax for bp in pairings)
    I_m = np.zeros((L, B, P), np.int32)
    J_m = np.zeros((L, B, P), np.int32)
    R_m = np.zeros((L, B, R), np.int32)
    pmask = np.zeros((L, B, P), np.float32)
    rmask = np.zeros((L, B, R), np.float32)
    for l, bp in enumerate(pairings):
        idx = bp.index_arrays()
        p, r = bp.Pmax, bp.Rmax
        I_m[l, :, :p] = idx["I"]
        J_m[l, :, :p] = idx["J"]
        R_m[l, :, :r] = idx["resid"]
        pmask[l, :, :p] = idx["pair_mask"]
        rmask[l, :, :r] = idx["resid_mask"]
    return {"I": I_m, "J": J_m, "resid": R_m,
            "pair_mask": pmask, "resid_mask": rmask}


def has_lm_pairing(params: Any) -> bool:
    """True iff ``params`` already carries pair_lm_params metadata."""
    segments = params.get("segments", []) if isinstance(params, dict) else []
    return any(
        isinstance(sub, dict) and any(k.endswith("_pairing") for k in sub)
        for seg in segments
        for sub in seg.values()
    )


def pair_lm_params(
    params: Any,
    rounding: float,
    *,
    mode: str = "structured",
    block_n: int = 0,
    criterion: str = "rms",
    min_dim: int = 8,
) -> tuple[Any, PairedModelReport]:
    """Pairing artifacts for every dense decoder weight of an LM param tree.

    The LM analogue of :func:`build_conv_pairings`: walks the stacked
    decoder segments (``params["segments"]``, the lax.scan layout) and runs
    the paper's preprocessing per layer on each eligible weight —
    attention qkv/out projections and the MLP up/gate/down matrices
    (:data:`LM_PAIRED_WEIGHTS`); embeddings, norms and biases are skipped.
    MLA attention sub-dicts are skipped whole (their projections live in
    latent-space einsums, not ``layers.dense``).

    Returns ``(params', report)`` where ``params'`` is the same tree with a
    sibling ``"<name>_pairing"`` metadata entry next to each paired weight:
    stacked ``(layers, …)`` index/mask arrays (per-layer pairings padded to
    the segment-wide (Pmax, Rmax)), which a ``lax.scan`` over the segment
    slices per layer exactly like the weights themselves.  The weights are
    **not** folded — magnitudes are recomputed live inside the trace
    (``kernels.ops.fused_paired_dense``), so the artifact survives
    ``jax.grad`` and weight updates, same contract as ``paired_conv``.

    ``mode`` picks the pairing-spectrum point: ``"structured"`` (one
    shared-row pairing per layer), ``"column_blocked"`` (one per
    ``block_n`` output columns — kernel-executable down to the paper's
    per-column pairing), or ``"per_column"`` (sugar for ``block_n=1``).
    """
    if mode == "per_column":
        mode, block_n = "column_blocked", 1
    assert mode in ("structured", "column_blocked"), f"unknown mode {mode!r}"
    if mode == "column_blocked" and block_n < 1:
        raise ValueError("mode='column_blocked' needs block_n >= 1")

    leaves_report: list[LeafReport] = []
    out = dict(params)
    new_segs = []
    for si, seg in enumerate(params.get("segments", [])):
        new_seg = dict(seg)
        for sub_name, w_name in LM_PAIRED_WEIGHTS:
            sub = new_seg.get(sub_name)
            if not isinstance(sub, dict) or w_name not in sub:
                continue
            if sub_name == "attn" and "w_dkv" in sub:
                continue  # MLA: projections don't route through layers.dense
            arr = np.asarray(sub[w_name])
            if arr.dtype.kind != "f" or arr.ndim < 3:
                continue  # stacked (layers, …) float matrices only
            L = arr.shape[0]
            K, N = _lm_weight_matrix_shape(w_name, arr.shape[1:])
            if K < min_dim or N < min_dim:
                continue
            mats = arr.reshape(L, K, N).astype(np.float64)
            if mode == "column_blocked":
                pairings_b = [
                    pair_rows_blocked(mats[l], rounding, block_n,
                                      criterion=criterion)
                    for l in range(L)
                ]
                meta = _stack_blocked(pairings_b)
                n_pairs = sum(bp.weighted_pairs for bp in pairings_b)
            else:
                pairings_s = [
                    pair_rows_structured(mats[l], rounding, criterion=criterion)
                    for l in range(L)
                ]
                meta = _stack_structured(pairings_s)
                n_pairs = sum(sp.weighted_pairs for sp in pairings_s)
            new_sub = dict(sub)
            new_sub[w_name + "_pairing"] = meta
            new_seg[sub_name] = new_sub
            leaves_report.append(
                LeafReport(
                    path=f"segments[{si}].{sub_name}.{w_name}",
                    shape=tuple(arr.shape),
                    n_weights=int(mats.size),
                    n_pairs=int(n_pairs),
                    pair_fraction=2.0 * n_pairs / mats.size,
                )
            )
        new_segs.append(new_seg)
    out["segments"] = new_segs
    report = PairedModelReport(rounding=rounding, mode=mode, leaves=leaves_report)
    return out, report


def pair_model_params(
    params: Any,
    rounding: float,
    *,
    mode: str = "per_column",
    block_n: int = 0,
    min_dim: int = 8,
    predicate: Callable[[str, np.ndarray], bool] | None = None,
    keep_pairings: bool = False,
) -> tuple[Any, PairedModelReport]:
    """Pair every eligible weight leaf of ``params``.

    Eligible = float array, ndim in (2, 4), both contraction dims >= min_dim,
    and ``predicate(path, leaf)`` (if given) returns True.  4-D leaves are
    treated as conv kernels (H, W, Cin, Cout) and paired per filter, exactly
    as the paper does for LeNet-5; 2-D leaves (K, N) are paired per column
    (= per output neuron).

    ``mode`` picks the pairing spectrum point: ``"per_column"`` (the paper's
    Algorithm 1, default), ``"structured"`` (one shared-row pairing per
    leaf — the original TPU kernel layout), or ``"column_blocked"`` (one
    shared-row pairing per ``block_n`` output columns — the kernel-executable
    mode that closes most of the structured-vs-per-column pairing gap;
    requires ``block_n >= 1``).

    Returns (paired_params, report).  ``paired_params`` has the same treedef;
    only eligible leaves are replaced by their folded equivalents.
    """
    if mode == "column_blocked" and block_n < 1:
        raise ValueError("mode='column_blocked' needs block_n >= 1")
    leaves_report: list[LeafReport] = []

    def handle(path, leaf):
        if not isinstance(leaf, np.ndarray | jax.Array):
            return leaf
        arr = np.asarray(leaf)
        if arr.dtype.kind != "f" or arr.ndim not in (2, 4):
            return leaf
        if arr.ndim == 4:
            H, Wd, Cin, Cout = arr.shape
            mat = arr.reshape(H * Wd * Cin, Cout)
        else:
            mat = arr
        if mat.shape[0] < min_dim or mat.shape[1] < min_dim:
            return leaf
        pstr = _path_str(path)
        if predicate is not None and not predicate(pstr, arr):
            return leaf

        mat64 = mat.astype(np.float64)
        if mode == "per_column":
            cp = pair_columns(mat64, rounding)
            folded = fold_columns(mat64, cp)
            n_pairs = cp.total_pairs
            pairing: ColumnPairing | StructuredPairing | BlockedPairing = cp
        elif mode == "structured":
            sp = pair_rows_structured(mat64, rounding)
            folded = sp.fold()
            n_pairs = sp.weighted_pairs  # one pair row spans N columns
            pairing = sp
        elif mode == "column_blocked":
            bp = pair_rows_blocked(mat64, rounding, block_n)
            folded = bp.fold()
            n_pairs = bp.weighted_pairs  # per-column-equivalent count
            pairing = bp
        else:
            raise ValueError(f"unknown mode {mode!r}")

        leaves_report.append(
            LeafReport(
                path=pstr,
                shape=tuple(arr.shape),
                n_weights=int(mat.size),
                n_pairs=int(n_pairs),
                pair_fraction=2.0 * n_pairs / mat.size,
                pairing=pairing if keep_pairings else None,
            )
        )
        return folded.reshape(arr.shape).astype(arr.dtype)

    paired = jax.tree_util.tree_map_with_path(handle, params)
    report = PairedModelReport(rounding=rounding, mode=mode, leaves=leaves_report)
    return paired, report
