"""Cost models: (a) the paper's 65 nm ASIC power/area model, (b) TPU roofline.

(a) ASIC model — reproduces §IV of the paper
--------------------------------------------
The paper synthesises IEEE-754 FP multiply / add / subtract units with
Synopsys Design Compiler @ 1 GHz on TSMC 65 nm and reports, for LeNet-5 with
rounding = 0.05 (Table I: 242 153 mult, 242 153 add, 163 447 sub vs. baseline
405 600 mult + 405 600 add):

        power saving = 32.03 %,   area saving = 24.59 %.

The paper does not publish the per-unit numbers, so we calibrate the two free
ratios of the linear model from its own headline results (sub and add cost
the same — a subtractor is an adder with negated input):

    power:  242153·(e+1) + 163447 = (1-0.3203)·405600·(e+1)
            →  E_mul / E_add = 3.874
    area:   242153·(a+1) + 163447 = (1-0.2459)·405600·(a+1)
            →  A_mul / A_add = 1.566

Cross-check vs. public literature (Horowitz, ISSCC'14, 45 nm): FP32 add
0.9 pJ vs mult 3.7 pJ → ratio 4.1; area 4184 µm² vs 7700 µm² → ratio 1.84.
Our calibrated 3.87 / 1.57 are the same ballpark, so the model is physically
sensible, and by construction it reproduces the paper's numbers exactly.

(b) TPU roofline — used by the §Roofline analysis
-------------------------------------------------
TPU v5e per chip: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI (values
fixed by the task statement).  ``TpuRoofline`` turns the dry-run's
``cost_analysis()`` + HLO collective bytes into the three roofline terms.
"""
from __future__ import annotations

import dataclasses


# ---------------------------------------------------------------------------
# (a) ASIC op-level cost model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OpCounts:
    mults: int
    adds: int
    subs: int

    @property
    def total(self) -> int:
        return self.mults + self.adds + self.subs


@dataclasses.dataclass(frozen=True)
class AsicCostModel:
    """Linear energy/area model over op counts (units of one FP adder)."""

    e_add: float = 1.0
    e_sub: float = 1.0  # subtractor == adder with one operand negated
    e_mul: float = 3.8742
    a_add: float = 1.0
    a_sub: float = 1.0
    a_mul: float = 1.5655

    def energy(self, ops: OpCounts) -> float:
        return ops.mults * self.e_mul + ops.adds * self.e_add + ops.subs * self.e_sub

    def area(self, ops: OpCounts) -> float:
        """Area of a MAC array provisioned proportionally to the op mix.

        The paper sizes the accelerator datapath to the operation profile of
        the workload (dedicated multiplier/adder/subtractor banks), so area
        scales with the same linear combination as energy but with area
        coefficients.
        """
        return ops.mults * self.a_mul + ops.adds * self.a_add + ops.subs * self.a_sub

    def power_saving(self, base: OpCounts, new: OpCounts) -> float:
        """Fractional power saving (1GHz fixed clock → power ∝ energy/op-mix)."""
        return 1.0 - self.energy(new) / self.energy(base)

    def area_saving(self, base: OpCounts, new: OpCounts) -> float:
        return 1.0 - self.area(new) / self.area(base)


def paper_table1() -> list[dict[str, int | float]]:
    """Table I of the paper, verbatim (LeNet-5, conv layers only)."""
    rows = [
        (0.0, 405600, 0, 405600),
        (0.0001, 399372, 6228, 399372),
        (0.005, 313545, 92055, 313545),
        (0.01, 288887, 116713, 288887),
        (0.015, 276692, 128908, 276692),
        (0.02, 265480, 140120, 265480),
        (0.025, 259789, 145811, 259789),
        (0.05, 242153, 163447, 242153),
        (0.1, 233698, 171902, 233698),
        (0.15, 228752, 176848, 228752),
        (0.2, 225988, 179612, 225988),
        (0.25, 223630, 181970, 223630),
        (0.3, 222742, 182858, 222742),
    ]
    return [
        {"rounding": r, "adds": a, "subs": s, "mults": m, "total": a + s + m}
        for (r, a, s, m) in rows
    ]


# ---------------------------------------------------------------------------
# (b) TPU roofline model
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TpuRoofline:
    """Per-chip peak numbers + the three-term roofline evaluation."""

    name: str
    peak_flops: float  # FLOP/s (bf16)
    hbm_bw: float  # B/s
    ici_bw: float  # B/s per link

    def terms(
        self,
        hlo_flops: float,
        hlo_bytes: float,
        collective_bytes: float,
    ) -> dict[str, float]:
        """Roofline terms in seconds. Inputs are PER-CHIP quantities
        (jax cost_analysis is post-SPMD-partitioning, i.e. per device)."""
        t_compute = hlo_flops / self.peak_flops
        t_memory = hlo_bytes / self.hbm_bw
        t_collective = collective_bytes / self.ici_bw
        bound = max(
            ("compute", t_compute),
            ("memory", t_memory),
            ("collective", t_collective),
            key=lambda kv: kv[1],
        )[0]
        return {
            "t_compute_s": t_compute,
            "t_memory_s": t_memory,
            "t_collective_s": t_collective,
            "bound": bound,  # type: ignore[dict-item]
            "t_bound_s": max(t_compute, t_memory, t_collective),
        }


TPU_V5E = TpuRoofline(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
)


def model_flops_train(n_params: int, n_tokens: int) -> float:
    """Classic 6·N·D estimate for one training step (fwd+bwd)."""
    return 6.0 * n_params * n_tokens


def model_flops_decode(n_params: int, n_tokens: int) -> float:
    """2·N per token for one forward (decode) step."""
    return 2.0 * n_params * n_tokens
