"""hymba-1.5b — hybrid-head: every layer runs attention ∥ Mamba heads in
parallel on the same input; 128 learned meta-tokens are prepended; 3 layers
(first/middle/last) use full attention, the rest sliding-window.
32L d=1600 25H (GQA kv=5) d_ff=5504 ssm_state=16. [arXiv:2411.13676; hf]
"""
from repro.configs.base import ModelConfig, SsmConfig, default_paired_leaves


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b",
        family="hybrid",
        n_layers=32,
        d_model=1600,
        n_heads=25,
        n_kv_heads=5,
        d_ff=5504,
        vocab=32001,
        d_head=64,
        sliding_window=1024,
        full_attn_layers=(0, 15, 31),
        meta_tokens=128,
        ssm=SsmConfig(d_state=16, head_dim=64, expand=2, n_groups=1, chunk=256),
        tie_embeddings=True,
        paired_leaves=default_paired_leaves(ssm=True),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke",
        family="hybrid",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        d_head=16,
        sliding_window=16,
        full_attn_layers=(0, 2),
        meta_tokens=8,
        ssm=SsmConfig(d_state=8, head_dim=16, expand=2, n_groups=1, chunk=16),
        tie_embeddings=True,
        paired_leaves=default_paired_leaves(ssm=True),
    )
