"""internvl2-2b — VLM: InternLM2-1.8B backbone (24L d=2048 16H kv=8) with the
InternViT frontend STUBBED: the first `vision_prefix` positions take
precomputed patch embeddings (input_specs supply them). [arXiv:2404.16821; hf]
"""
from repro.configs.base import ModelConfig, default_paired_leaves


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-2b",
        family="vlm",
        n_layers=24,
        d_model=2048,
        n_heads=16,
        n_kv_heads=8,
        d_ff=8192,
        vocab=92553,
        vision_prefix=256,  # one 448x448 tile → 256 patch embeddings
        rope_theta=1e6,
        paired_leaves=default_paired_leaves(),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        vision_prefix=8,
        paired_leaves=default_paired_leaves(),
    )
