"""deepseek-v2-lite-16b — MoE with Multi-head Latent Attention (MLA).

27L, d=2048, 16H, MLA kv_lora_rank=512 (qk_nope 128 + qk_rope 64, v 128),
MoE: 64 routed experts top-6 + 2 shared, expert d_ff=1408, first layer dense
(d_ff 10944). [arXiv:2405.04434; hf]

NOTE (also in DESIGN.md): the assignment line says both "64e top-6" and
"2 shared+160 routed"; the published V2-Lite config is 64 routed + 2 shared,
top-6 — we follow the publication.
"""
from repro.configs.base import MlaConfig, ModelConfig, MoeConfig, default_paired_leaves


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=10944,  # dense first layer
        vocab=102400,
        mla=MlaConfig(kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
        moe=MoeConfig(
            n_experts=64,
            top_k=6,
            d_ff_expert=1408,
            n_shared=2,
            first_k_dense=1,
            d_ff_dense=10944,
        ),
        paired_leaves=default_paired_leaves(mla=True, moe=True, moe_shared=True),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke",
        family="moe",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=192,
        vocab=256,
        mla=MlaConfig(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
        moe=MoeConfig(
            n_experts=8,
            top_k=2,
            d_ff_expert=48,
            n_shared=1,
            first_k_dense=1,
            d_ff_dense=192,
            capacity_factor=4.0,  # smoke: no capacity drops
        ),
        paired_leaves=default_paired_leaves(mla=True, moe=True, moe_shared=True),
    )
