"""qwen2-1.5b — dense, GQA (kv=2), QKV bias. [arXiv:2407.10671; hf]"""
from repro.configs.base import ModelConfig, default_paired_leaves


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-1.5b",
        family="dense",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab=151936,
        qkv_bias=True,
        rope_theta=1e6,
        tie_embeddings=True,  # Qwen2-1.5B ties embeddings
        paired_leaves=default_paired_leaves(),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        qkv_bias=True,
        tie_embeddings=True,
        paired_leaves=default_paired_leaves(),
    )
