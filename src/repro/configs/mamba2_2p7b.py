"""mamba2-2.7b — attention-free SSM (SSD / state-space duality).
64L d=2560, d_state=128, head_dim=64, expand=2. [arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig, SsmConfig, default_paired_leaves


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-2.7b",
        family="ssm",
        n_layers=64,
        d_model=2560,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,  # mamba blocks have no separate FFN
        vocab=50280,
        ssm=SsmConfig(d_state=128, head_dim=64, expand=2, n_groups=1, chunk=256),
        tie_embeddings=True,
        paired_leaves=default_paired_leaves(attn=False, mlp=False, ssm=True),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab=256,
        ssm=SsmConfig(d_state=16, head_dim=16, expand=2, n_groups=1, chunk=32),
        tie_embeddings=True,
        paired_leaves=default_paired_leaves(attn=False, mlp=False, ssm=True),
    )
