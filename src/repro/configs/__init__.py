"""Architecture configs: one module per assigned architecture.

``get_config(name)`` resolves any of the ten assigned architectures (plus
``lenet5`` for the paper's own network); ``ALL_ARCHS`` lists them.
"""
from __future__ import annotations

import importlib

ALL_ARCHS = [
    "qwen2-1.5b",
    "mistral-large-123b",
    "granite-3-2b",
    "qwen3-4b",
    "whisper-base",
    "internvl2-2b",
    "mamba2-2.7b",
    "deepseek-v2-lite-16b",
    "olmoe-1b-7b",
    "hymba-1.5b",
]

_MODULES = {name: name.replace("-", "_").replace(".", "p") for name in ALL_ARCHS}


def get_config(name: str):
    """Full-size config for an assigned architecture."""
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.config()


def get_smoke_config(name: str):
    """Reduced config of the same family for CPU smoke tests."""
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.smoke_config()
