"""whisper-base — encoder-decoder, 6L each, d=512, 8H MHA, GELU+LayerNorm.
Conv frontend is a STUB: input_specs provide precomputed frame embeddings.
[arXiv:2212.04356; unverified]"""
from repro.configs.base import EncoderConfig, ModelConfig, default_paired_leaves


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base",
        family="encdec",
        n_layers=6,  # decoder layers
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        d_ff=2048,
        vocab=51865,
        encoder=EncoderConfig(n_layers=6, frames=1500),
        norm="layernorm",
        act="gelu",
        rope_theta=0.0,  # whisper uses absolute (sinusoidal) positions, no rope
        tie_embeddings=True,
        paired_leaves=default_paired_leaves(xattn=True),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke",
        family="encdec",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab=256,
        encoder=EncoderConfig(n_layers=2, frames=30),
        norm="layernorm",
        act="gelu",
        rope_theta=0.0,
        tie_embeddings=True,
        paired_leaves=default_paired_leaves(xattn=True),
    )
