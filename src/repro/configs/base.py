"""ModelConfig: a single declarative description covering all ten assigned
architecture families (dense / MoE / SSM / hybrid / enc-dec / VLM).

The decoder stack is expressed as *segments* — maximal runs of identical
layers — so each segment lowers to one ``lax.scan`` over stacked parameters
(compile time stays flat in depth) while still allowing per-layer
heterogeneity (DeepSeek's first dense layer, Hymba's three full-attention
layers, …).
"""
from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class MlaConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""

    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MoeConfig:
    n_experts: int = 64
    top_k: int = 6
    d_ff_expert: int = 1408
    n_shared: int = 2
    first_k_dense: int = 0  # leading layers with a dense FFN instead of MoE
    d_ff_dense: int = 0  # d_ff of those dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SsmConfig:
    """Mamba-2 (SSD) block geometry."""

    d_state: int = 128
    head_dim: int = 64
    expand: int = 2  # d_inner = expand * d_model
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256  # SSD chunk length
    dt_min: float = 0.001
    dt_max: float = 0.1


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style audio encoder. The conv frontend is a stub: inputs are
    precomputed frame embeddings (B, frames, d_model), per the task spec."""

    n_layers: int = 6
    frames: int = 1500


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 → d_model // n_heads

    # attention flavour
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: int = 0  # 0 → full attention
    full_attn_layers: tuple[int, ...] = ()  # hybrid: layers using full attn
    rope_theta: float = 10000.0

    mla: MlaConfig | None = None
    moe: MoeConfig | None = None
    ssm: SsmConfig | None = None
    encoder: EncoderConfig | None = None

    # hybrid (hymba): every layer runs attention ∥ SSM heads in parallel
    meta_tokens: int = 0

    # vlm (internvl2): first `vision_prefix` positions take precomputed patch
    # embeddings instead of token embeddings (frontend stub per task spec)
    vision_prefix: int = 0
    vision_embed_dim: int = 1024  # dim of the (stub) precomputed patch embeds

    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    act: Literal["silu", "gelu"] = "silu"
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # Pairing-eligible weight leaves as (sub-path, weight-name) pairs — the
    # spec list consumed by ``core.transform.pair_params(..., leaves=...)``.
    # Empty () means "no declaration": pair_params then falls back to its
    # model-agnostic default superset.  Declare via default_paired_leaves()
    # so a family that renames a weight fails loudly instead of silently
    # dropping it from the paired path.
    paired_leaves: tuple[tuple[str, str], ...] = ()

    # -- derived -----------------------------------------------------------
    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def attention_kind(self) -> str:
        if self.family == "ssm":
            return "none"
        if self.mla is not None:
            return "mla"
        return "gqa"

    def layer_kind(self, i: int) -> str:
        """Kind string for decoder layer i."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid":
            return "hybrid_full" if i in self.full_attn_layers else "hybrid_swa"
        if self.moe is not None:
            return "dense" if i < self.moe.first_k_dense else "moe"
        return "dense"

    def segments(self) -> tuple[tuple[str, int], ...]:
        """Maximal runs of identical layer kinds — one lax.scan each."""
        segs: list[tuple[str, int]] = []
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            if segs and segs[-1][0] == kind:
                segs[-1] = (kind, segs[-1][1] + 1)
            else:
                segs.append((kind, 1))
        return tuple(segs)

    # -- parameter / FLOP bookkeeping (for roofline "useful compute") -------
    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count (embeddings included once)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim
        n = 0
        n += V * d  # embed
        if not self.tie_embeddings:
            n += V * d  # lm head
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            # attention
            if self.family == "ssm":
                att = 0
            elif self.mla is not None:
                m = self.mla
                att = (
                    d * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)  # W_q
                    + d * (m.kv_lora_rank + m.qk_rope_dim)  # W_dkv + W_kr
                    + m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d  # W_o
                )
            else:
                att = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
            # mlp
            if kind == "moe":
                mo = self.moe
                per_expert = 3 * d * mo.d_ff_expert
                total_e = mo.n_experts * per_expert + mo.n_shared * per_expert + d * mo.n_experts
                active_e = (mo.top_k + mo.n_shared) * per_expert + d * mo.n_experts
                mlp = active_e if active_only else total_e
            elif kind == "dense" and self.moe is not None and i < self.moe.first_k_dense:
                mlp = 3 * d * self.moe.d_ff_dense
            elif kind in ("ssm", "hybrid_full", "hybrid_swa"):
                mlp = 3 * d * ff if ff else 0
            else:
                mlp = 3 * d * ff
            # ssm head params
            ssm = 0
            if kind in ("ssm", "hybrid_full", "hybrid_swa"):
                s = self.ssm
                d_in = s.expand * d
                ssm = (
                    d * (2 * d_in + 2 * s.n_groups * s.d_state + d_in // s.head_dim)
                    + d_in * d
                )
            n += att + mlp + ssm
        return n

    def flops_per_token(self, training: bool = True) -> float:
        """6·N_active (train) or 2·N_active (decode) matmul FLOPs/token."""
        n = self.param_count(active_only=True)
        return (6.0 if training else 2.0) * n


def default_paired_leaves(
    *,
    attn: bool = True,
    mla: bool = False,
    mlp: bool = True,
    moe: bool = False,
    moe_shared: bool = False,
    ssm: bool = False,
    xattn: bool = False,
) -> tuple[tuple[str, str], ...]:
    """The pairing-eligible leaf specs for a family, by block type.

    Each entry is ``(sub-path, weight-name)`` into a decoder/encoder layer
    dict; dotted sub-paths (``"moe.shared"``) address nested blocks.  Router,
    embedding, conv-scan, and the MLA up-projections (``w_uk``/``w_uv`` —
    absorbed into latent einsums, never a plain GEMM) are deliberately not
    pairing-eligible.  ``xattn`` (enc-dec families) declares the
    cross-attention wq/wo projections, which route through ``layers.dense``;
    the cross wk/wv run once over the encoder output at prefill and stay
    plain einsums, so they are not declared.
    """
    leaves: list[tuple[str, str]] = []
    if mla:
        leaves += [("attn", "wq"), ("attn", "w_dkv"), ("attn", "w_kr"), ("attn", "wo")]
    elif attn:
        leaves += [("attn", "wq"), ("attn", "wk"), ("attn", "wv"), ("attn", "wo")]
    if xattn:
        leaves += [("xattn", "wq"), ("xattn", "wo")]
    if mlp:
        leaves += [("mlp", "w_gate"), ("mlp", "w_up"), ("mlp", "w_down")]
    if moe:
        leaves += [("moe", "w_gate"), ("moe", "w_up"), ("moe", "w_down")]
    if moe_shared:
        leaves += [
            ("moe.shared", "w_gate"),
            ("moe.shared", "w_up"),
            ("moe.shared", "w_down"),
        ]
    if ssm:
        leaves += [
            ("mamba", "w_z"),
            ("mamba", "w_x"),
            ("mamba", "w_B"),
            ("mamba", "w_C"),
            ("mamba", "w_dt"),
            ("mamba", "w_out"),
        ]
    return tuple(leaves)


# The four assigned input shapes (identical for every LM-family arch).
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """The mandated skips: long_500k needs sub-quadratic attention."""
    if shape.name == "long_500k":
        if cfg.family == "ssm":
            return True, "ssm: O(1) state decode"
        if cfg.family == "hybrid":
            return True, "hybrid: sliding-window attn + ssm state"
        return (
            False,
            "full quadratic attention at 524k context — skipped per task spec "
            "(noted in DESIGN.md)",
        )
    return True, ""
