"""mistral-large-123b — dense, GQA (kv=8).
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]"""
from repro.configs.base import ModelConfig, default_paired_leaves


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b",
        family="dense",
        n_layers=88,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        d_ff=28672,
        vocab=32768,
        d_head=128,
        rope_theta=1e6,
        paired_leaves=default_paired_leaves(),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-smoke",
        family="dense",
        n_layers=2,
        d_model=96,
        n_heads=6,
        n_kv_heads=2,
        d_ff=192,
        vocab=256,
        d_head=16,
        paired_leaves=default_paired_leaves(),
    )
