"""qwen3-4b — dense, GQA (kv=8), per-head QK-norm. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig, default_paired_leaves


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b",
        family="dense",
        n_layers=36,
        d_model=2560,
        n_heads=32,
        n_kv_heads=8,
        d_ff=9728,
        vocab=151936,
        d_head=128,
        qk_norm=True,
        rope_theta=1e6,
        tie_embeddings=True,
        paired_leaves=default_paired_leaves(),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        d_head=16,
        qk_norm=True,
        tie_embeddings=True,
        paired_leaves=default_paired_leaves(),
    )
