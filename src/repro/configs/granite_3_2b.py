"""granite-3-2b — dense, GQA (kv=8). [hf:ibm-granite/granite-3.0-2b-base; hf]"""
from repro.configs.base import ModelConfig, default_paired_leaves


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b",
        family="dense",
        n_layers=40,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        d_ff=8192,
        vocab=49155,
        rope_theta=1e4,
        tie_embeddings=True,
        paired_leaves=default_paired_leaves(),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-smoke",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=8,
        n_kv_heads=2,
        d_ff=128,
        vocab=256,
        tie_embeddings=True,
        paired_leaves=default_paired_leaves(),
    )
