"""olmoe-1b-7b — MoE: 16L d=2048 16H (MHA kv=16), 64 experts top-8,
expert d_ff=1024. [arXiv:2409.02060; hf]"""
from repro.configs.base import ModelConfig, MoeConfig, default_paired_leaves


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1024,
        vocab=50304,
        qk_norm=True,  # OLMoE uses QK-norm
        moe=MoeConfig(
            n_experts=64,
            top_k=8,
            d_ff_expert=1024,
            n_shared=0,
            first_k_dense=0,
        ),
        paired_leaves=default_paired_leaves(mlp=False, moe=True),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-smoke",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=96,
        vocab=256,
        qk_norm=True,
        moe=MoeConfig(n_experts=8, top_k=2, d_ff_expert=96, n_shared=0, capacity_factor=4.0),
        paired_leaves=default_paired_leaves(mlp=False, moe=True),
    )
