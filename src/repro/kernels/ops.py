"""Public jit'd wrappers around the Pallas kernels.

On a real TPU backend ``interpret=False`` compiles the Mosaic kernel; in this
CPU container the kernels run (and are tested) in interpret mode.  The
wrapper also owns the *deployment* plumbing:

* applying a :class:`repro.core.pairing.StructuredPairing` to activations,
  including the input permutation (which in production folds into the
  previous layer);
* resolving tile sizes — pass ``block_* = 0`` and the heuristic in
  :mod:`repro.kernels.tuning` picks VMEM-safe tiles for the shape;
* the **GEMM policy**: :func:`pallas_gemm` installs a thread-local policy
  that makes :func:`repro.models.layers.dense` (and everything built on it —
  MLP blocks, the serving engine, the pjit'd step builders) route its
  matmuls through the fused kernels instead of XLA einsums.  Activating the
  policy around a ``jax.jit`` trace bakes the kernels into the compiled
  step.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pairing import BlockedPairing, StructuredPairing
from repro.kernels import tuning
from repro.kernels.paired_matmul import (
    dense_matmul_pallas,
    paired_matmul_blocked_pallas,
    paired_matmul_pallas,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit,
    static_argnames=(
        "block_m", "block_n", "block_k", "activation", "pool", "interpret",
    ),
)
def paired_matmul(
    x: jax.Array,
    kmat: jax.Array,
    w_res: jax.Array,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    *,
    block_m: int = 0,
    block_n: int = 0,
    block_k: int = 0,
    activation: str = "none",
    pool: str = "none",
    interpret: bool | None = None,
) -> jax.Array:
    """(…, K) @ paired weights → (…, N). x pre-permuted to [I|J|residual].

    ``block_* = 0`` → tiles from :mod:`repro.kernels.tuning` (a warm
    :class:`~repro.kernels.tuning.TileCache` hit wins over the heuristic).
    ``bias``/``activation`` fuse into the kernel epilogue, and ``residual``
    (an output-shaped ``(…, N)`` skip connection) fuses into the flush
    after them.  With ``pool="max2"``/``"avg2"`` ``x`` must be window-major
    ``(4, M, K)`` and the fused 2×2 reduction happens in VMEM (see
    paired_matmul_pallas); ``residual`` is then the pooled ``(M, N)`` map.
    """
    interp = (not _on_tpu()) if interpret is None else interpret
    has_pool = pool != "none"
    if has_pool:
        assert x.ndim == 3, f"pool={pool!r} expects (4, M, K) x, got {x.shape}"
        lead, x2 = (), x
    else:
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
    res2 = None
    if residual is not None:
        res2 = residual.reshape(-1, residual.shape[-1])
    tiles = tuning.resolve_blocks(
        x2.shape[-2], kmat.shape[1], kmat.shape[0], w_res.shape[0],
        block_m=block_m, block_n=block_n, block_k=block_k,
        dtype_bytes=x.dtype.itemsize, dtype=x.dtype.name, pool=pool,
        residual=residual is not None,
    )
    y = paired_matmul_pallas(
        x2, kmat, w_res, bias, residual=res2,
        block_m=tiles.block_m, block_n=tiles.block_n, block_k=tiles.block_k,
        activation=activation, pool=pool, interpret=interp,
    )
    # pooled output is already (M_pooled, N); otherwise restore the lead dims
    # (incl. the 1-D (K,) → (N,) case, where lead == ())
    return y if has_pool else y.reshape(*lead, y.shape[-1])


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "activation", "interpret"),
)
def dense_matmul(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    *,
    block_m: int = 0,
    block_n: int = 0,
    block_k: int = 0,
    activation: str = "none",
    interpret: bool | None = None,
) -> jax.Array:
    """Plain K-tiled GEMM with the same tiling/epilogue as the paired kernel."""
    interp = (not _on_tpu()) if interpret is None else interpret
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    res2 = None if residual is None else residual.reshape(-1, residual.shape[-1])
    tiles = tuning.resolve_blocks(
        x2.shape[0], w.shape[1], 0, w.shape[0],
        block_m=block_m, block_n=block_n, block_k=block_k,
        dtype_bytes=x.dtype.itemsize, dtype=x.dtype.name,
        residual=residual is not None,
    )
    y = dense_matmul_pallas(
        x2, w, bias, residual=res2,
        block_m=tiles.block_m, block_n=tiles.block_n, block_k=tiles.block_k,
        activation=activation, interpret=interp,
    )
    return y.reshape(*lead, y.shape[-1])


@functools.partial(
    jax.jit,
    static_argnames=(
        "n_cols", "block_m", "block_k", "activation", "pool", "interpret",
    ),
)
def paired_matmul_blocked(
    x: jax.Array,
    kmat: jax.Array,
    w_res: jax.Array,
    bias: jax.Array | None = None,
    residual: jax.Array | None = None,
    *,
    n_cols: int,
    block_m: int = 0,
    block_k: int = 0,
    activation: str = "none",
    pool: str = "none",
    interpret: bool | None = None,
) -> jax.Array:
    """Column-blocked paired GEMM → (M, n_cols).

    ``x`` is block-gathered ``(B, M, K')`` (window-major ``(B, 4, M, K')``
    with pooling), ``kmat``/``w_res`` the packed per-block weight segments —
    see :func:`repro.kernels.paired_matmul.paired_matmul_blocked_pallas`.
    ``residual`` is an output-space ``(M, n_cols)`` fused skip connection.
    The lane tile is pinned to the pairing block size; ``block_m``/
    ``block_k = 0`` resolve through the tile cache / heuristic under a
    blocked cache key.
    """
    interp = (not _on_tpu()) if interpret is None else interpret
    B, P, bn = kmat.shape
    R = w_res.shape[1]
    tiles = tuning.resolve_blocks(
        x.shape[-2], bn, P, R,
        block_m=block_m, block_n=bn, block_k=block_k,
        dtype_bytes=x.dtype.itemsize, dtype=x.dtype.name, pool=pool,
        blocks=B, residual=residual is not None,
    )
    return paired_matmul_blocked_pallas(
        x, kmat, w_res, bias, residual=residual,
        n_cols=n_cols, block_m=tiles.block_m, block_k=tiles.block_k,
        activation=activation, pool=pool, interpret=interp,
    )


def apply_blocked_pairing(
    x: jax.Array, bp: BlockedPairing, **kw
) -> jax.Array:
    """Evaluate x @ W through the blocked kernel given a BlockedPairing.

    The blocked analogue of :func:`apply_structured_pairing`: gathers the
    activations through the packed ``(n_blocks, K')`` index matrix (one XLA
    gather covering every block's ``[I | J | resid]`` permutation) and packs
    the offline per-block weight segments.  For the live-weight
    (differentiable) variant see ``kernels.paired_conv``.
    """
    lead = x.shape[:-1]
    idx = bp.index_arrays()
    xg = jnp.take(x.reshape(-1, x.shape[-1]), jnp.asarray(idx["perm"]), axis=-1)
    xg = jnp.moveaxis(xg, 1, 0)  # (B, M, K')
    kmat, w_res = bp.packed_weights()
    y = paired_matmul_blocked(
        xg, jnp.asarray(kmat, x.dtype), jnp.asarray(w_res, x.dtype),
        n_cols=bp.shape[1], **kw,
    )
    return y.reshape(*lead, y.shape[-1])


def apply_structured_pairing(
    x: jax.Array, sp: StructuredPairing, *, fold_perm: bool = False, **kw
) -> jax.Array:
    """Evaluate x @ W through the paired kernel given a StructuredPairing.

    ``fold_perm=False`` applies the [I|J|residual] permutation here (one
    gather); in production the permutation folds into the previous layer's
    output weights and the gather disappears.
    """
    perm = jnp.asarray(sp.perm())
    xp = x if fold_perm else jnp.take(x, perm, axis=-1)
    kmat = jnp.asarray(sp.Kmat, dtype=x.dtype)
    w_res = jnp.asarray(sp.W_res, dtype=x.dtype)
    return paired_matmul(xp, kmat, w_res, **kw)


# ---------------------------------------------------------------------------
# differentiable fused dense: Pallas forward, XLA backward
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _fused_dense_grad(activation, block_m, block_n, block_k, interpret):
    """custom_vjp wrapper: forward through the fused kernel, backward as
    plain XLA dots (pallas_call has no transpose rule; the backward of a
    GEMM is two GEMMs XLA already schedules well, with the pre-activation
    rematerialised — standard remat trade)."""
    from repro.kernels.paired_matmul import ACTIVATIONS

    def primal(x, w, b):
        return dense_matmul(
            x, w, b, activation=activation,
            block_m=block_m, block_n=block_n, block_k=block_k,
            interpret=interpret,
        )

    @jax.custom_vjp
    def f(x, w, b):
        return primal(x, w, b)

    def fwd(x, w, b):
        return primal(x, w, b), (x, w, b)

    def bwd(res, dy):
        x, w, b = res
        z = jnp.einsum("...d,df->...f", x, w)
        if b is not None:
            z = z + b
        _, act_vjp = jax.vjp(ACTIVATIONS[activation], z)
        (dz,) = act_vjp(dy)
        dx = jnp.einsum("...f,df->...d", dz, w)
        dw = jnp.einsum("...d,...f->df", x, dz)
        db = None if b is None else dz.reshape(-1, dz.shape[-1]).sum(0)
        return dx, dw.astype(w.dtype), db

    f.defvjp(fwd, bwd)
    return f


def fused_dense(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array | None = None,
    *,
    activation: str = "none",
    block_m: int = 0,
    block_n: int = 0,
    block_k: int = 0,
    interpret: bool | None = None,
) -> jax.Array:
    """Differentiable fused GEMM: what layers.dense calls under the policy."""
    grad_fn = _fused_dense_grad(activation, block_m, block_n, block_k, interpret)
    return grad_fn(x, w, bias)


# ---------------------------------------------------------------------------
# differentiable fused *paired* dense: live-weight subtractor GEMM for the LM
# ---------------------------------------------------------------------------
#
# The LM analogue of kernels.paired_conv: the pairing artifact
# (core.transform.pair_lm_params) carries only the frozen *index structure*
# of which contraction lanes subtract — as stacked arrays with a leading
# layers axis, so a lax.scan over a decoder segment slices each layer's
# metadata like any other scanned operand.  Pair magnitudes are recomputed
# from the live weights inside the trace (Kmat = (W[I] − W[J]) / 2), so the
# same artifact serves inference and jax.grad.  Lane lists are padded to a
# segment-wide (Pmax, Rmax): padded pair lanes point I == J == 0 (the
# subtract is exactly zero) and every padded weight row is masked to zero,
# so padding contracts against nothing — the same zero-lane trick the
# k-tile padding and the column-blocked packing already use.


def _lm_structured_segments(w2: jax.Array, meta: dict):
    """Live (kmat, w_res) for a structured LM pairing (traced indices)."""
    I, J, Rm = meta["I"], meta["J"], meta["resid"]
    kmat = (jnp.take(w2, I, axis=0) - jnp.take(w2, J, axis=0)) * 0.5
    kmat = kmat * meta["pair_mask"][:, None].astype(w2.dtype)
    w_res = jnp.take(w2, Rm, axis=0) * meta["resid_mask"][:, None].astype(w2.dtype)
    return kmat, w_res


def _lm_blocked_weights(w2: jax.Array, n_blocks: int, bn: int) -> jax.Array:
    """(K, N) live weights → block-major (n_blocks, K, bn), zero-padded cols."""
    K, N = w2.shape
    pad = n_blocks * bn - N
    w_p = jnp.pad(w2, ((0, 0), (0, pad))) if pad else w2
    return w_p.reshape(K, n_blocks, bn).transpose(1, 0, 2)


def _take_block_segments(wm_t: jax.Array, meta: dict):
    """Live per-block (kmat, w_res) from block-major (B, K, bn) weights and
    (B, Pmax/Rmax) lane lists."""
    take = lambda ind: jnp.take_along_axis(wm_t, ind[:, :, None], axis=1)
    pmask = meta["pair_mask"][:, :, None].astype(wm_t.dtype)
    rmask = meta["resid_mask"][:, :, None].astype(wm_t.dtype)
    kmat = (take(meta["I"]) - take(meta["J"])) * 0.5 * pmask  # (B, Pmax, bn)
    w_res = take(meta["resid"]) * rmask  # (B, Rmax, bn)
    return kmat, w_res


def _lm_blocked_segments(w2: jax.Array, meta: dict, bn: int):
    """Packed per-block live (kmat, w_res) for a blocked LM pairing."""
    wm_t = _lm_blocked_weights(w2, meta["I"].shape[0], bn)  # (B, K, bn)
    return _take_block_segments(wm_t, meta)


def fold_lm_weight(w2: jax.Array, meta: dict, pair_block_n: int = 0) -> jax.Array:
    """Dense W_approx (K, N) the paired LM GEMM is equivalent to.

    The live-weight fold under a frozen pairing structure (the backward-pass
    function and test oracle): paired rows snap to ±Kmat, residual rows pass
    through.  Scatter-*add* because padded lanes all point at row 0 with
    exactly-zero masked contributions.
    """
    if meta["I"].ndim == 2:  # blocked: (B, Pmax)-shaped lane lists
        B = meta["I"].shape[0]
        K, N = w2.shape
        bn = pair_block_n
        assert bn >= 1 and B == -(-N // bn), (B, N, bn)
        kmat, w_res = _lm_blocked_segments(w2, meta, bn)
        bar = jnp.arange(B)[:, None]
        wf_t = (
            jnp.zeros((B, K, bn), w2.dtype)
            .at[bar, meta["I"]].add(kmat)
            .at[bar, meta["J"]].add(-kmat)
            .at[bar, meta["resid"]].add(w_res)
        )
        return wf_t.transpose(1, 0, 2).reshape(K, B * bn)[:, :N]
    kmat, w_res = _lm_structured_segments(w2, meta)
    return (
        jnp.zeros_like(w2)
        .at[meta["I"]].add(kmat)
        .at[meta["J"]].add(-kmat)
        .at[meta["resid"]].add(w_res)
    )


@functools.lru_cache(maxsize=None)
def _fused_paired_dense_grad(
    activation, blocked, pair_block_n, block_m, block_n, block_k, interpret
):
    """custom_vjp factory: forward through the paired kernel (live-weight
    segments, fused bias/activation/residual epilogue), backward as the VJP
    of the folded dense equivalent — the same Pallas-forward / folded-XLA-
    backward split as paired_conv and fused_dense.  The pairing metadata is
    a primal argument (its leaves are traced scan slices), with float0 /
    zero cotangents: only the *structure* is frozen, weights stay live."""
    from repro.kernels.paired_matmul import ACTIVATIONS

    def primal(x, w2, b, res, meta):
        N = w2.shape[1]
        perm = jnp.concatenate([meta["I"], meta["J"], meta["resid"]], axis=-1)
        if blocked:
            x2 = x.reshape(-1, x.shape[-1])
            xg = jnp.moveaxis(jnp.take(x2, perm, axis=-1), -2, 0)  # (B, M, K')
            kmat, w_res = _lm_blocked_segments(w2, meta, pair_block_n)
            res2 = None if res is None else res.reshape(-1, N)
            y = paired_matmul_blocked(
                xg, kmat.astype(x.dtype), w_res.astype(x.dtype), b, res2,
                n_cols=N, activation=activation,
                block_m=block_m, block_k=block_k, interpret=interpret,
            )
            return y.reshape(*x.shape[:-1], N)
        xg = jnp.take(x, perm, axis=-1)
        kmat, w_res = _lm_structured_segments(w2, meta)
        return paired_matmul(
            xg, kmat.astype(x.dtype), w_res.astype(x.dtype), b, res,
            activation=activation,
            block_m=block_m, block_n=block_n, block_k=block_k,
            interpret=interpret,
        )

    def ref(x, w2, b, res, meta):
        wf = fold_lm_weight(w2, meta, pair_block_n)
        z = jnp.einsum("...d,df->...f", x, wf)
        if b is not None:
            z = z + b
        z = ACTIVATIONS[activation](z)
        return z + res.astype(z.dtype) if res is not None else z

    @jax.custom_vjp
    def f(x, w2, b, res, meta):
        return primal(x, w2, b, res, meta)

    def fwd(x, w2, b, res, meta):
        return primal(x, w2, b, res, meta), (x, w2, b, res, meta)

    def bwd(saved, dy):
        x, w2, b, res, meta = saved
        _, vjp = jax.vjp(lambda x, w2, b, res: ref(x, w2, b, res, meta),
                         x, w2, b, res)
        dx, dw, db, dres = vjp(dy)
        dmeta = {
            k: np.zeros(jnp.shape(a), jax.dtypes.float0)
            if jnp.issubdtype(jnp.result_type(a), jnp.integer)
            else jnp.zeros_like(a)
            for k, a in meta.items()
        }
        return dx, dw.astype(w2.dtype), db, dres, dmeta

    f.defvjp(fwd, bwd)
    return f


def fused_paired_dense(
    x: jax.Array,
    w: jax.Array,  # (K, N) live weights (reshape conv/attn weights first)
    meta: dict,  # stacked pairing metadata (core.transform.pair_lm_params)
    bias: jax.Array | None = None,
    *,
    activation: str = "none",
    residual: jax.Array | None = None,
    pair_block_n: int = 0,
    block_m: int = 0,
    block_n: int = 0,
    block_k: int = 0,
    interpret: bool | None = None,
) -> jax.Array:
    """Differentiable paired GEMM from live weights + frozen LM pairing.

    ``meta`` holds the per-layer lane structure (``I``/``J``/``resid`` +
    masks); 1-D lane lists select the structured kernel, 2-D ``(B, Pmax)``
    lists the column-blocked one (``pair_block_n`` is then the pairing
    block size the metadata was built with).  ``residual`` fuses the
    sublayer skip connection into the kernel flush.
    """
    blocked = meta["I"].ndim == 2
    if blocked and pair_block_n < 1:
        raise ValueError("blocked pairing metadata needs pair_block_n >= 1")
    fn = _fused_paired_dense_grad(
        activation, blocked, pair_block_n if blocked else 0,
        block_m, block_n, block_k, interpret,
    )
    return fn(x, w, bias, residual, dict(meta))


# ---------------------------------------------------------------------------
# differentiable fused paired dense over a leading expert axis (MoE)
# ---------------------------------------------------------------------------
#
# Per-expert pairing executes on the *existing* column-blocked Pallas kernel
# by mapping experts onto the kernel's block grid: structured-per-expert
# metadata (E, Pmax) makes each expert one block of bn = F output columns;
# blocked-within-expert metadata (E, Bc, Pmax) flattens to E·Bc blocks of
# pair_block_n columns each.  Either way the result is (M, E, F) — the
# einsum "tk,ekf->tef" (shared activations) or "etk,ekf->tef" (per-expert
# activations; the kernel contracts each expert's token rows against that
# expert's weight segments only, so nothing is wasted on the batching).


def _expert_blocked_weights(w: jax.Array, n_blocks: int, bn: int) -> jax.Array:
    """(E, K, F) live expert weights → block-major (E·n_blocks, K, bn),
    zero-padding the short last block of each expert."""
    E, K, F = w.shape
    pad = n_blocks * bn - F
    w_p = jnp.pad(w, ((0, 0), (0, 0), (0, pad))) if pad else w
    return (
        w_p.reshape(E, K, n_blocks, bn)
        .transpose(0, 2, 1, 3)
        .reshape(E * n_blocks, K, bn)
    )


def fold_lm_expert_weight(
    w: jax.Array, meta: dict, pair_block_n: int = 0
) -> jax.Array:
    """Dense (E, K, F) equivalent of the per-expert paired weights.

    The expert-axis analogue of :func:`fold_lm_weight` (backward-pass
    function and test oracle); same scatter-add zero-lane trick."""
    E, K, F = w.shape
    if meta["I"].ndim == 3:  # blocked-within-expert: (E, Bc, Pmax) lanes
        bn = pair_block_n
        Bc = meta["I"].shape[1]
        assert bn >= 1 and Bc == -(-F // bn), (Bc, F, bn)
        m = {k: v.reshape(E * Bc, *v.shape[2:]) for k, v in meta.items()}
        kmat, w_res = _take_block_segments(_expert_blocked_weights(w, Bc, bn), m)
        bar = jnp.arange(E * Bc)[:, None]
        wf_t = (
            jnp.zeros((E * Bc, K, bn), w.dtype)
            .at[bar, m["I"]].add(kmat)
            .at[bar, m["J"]].add(-kmat)
            .at[bar, m["resid"]].add(w_res)
        )
        return (
            wf_t.reshape(E, Bc, K, bn)
            .transpose(0, 2, 1, 3)
            .reshape(E, K, Bc * bn)[:, :, :F]
        )
    kmat, w_res = _take_block_segments(w, meta)  # expert = one block of F cols
    bar = jnp.arange(E)[:, None]
    return (
        jnp.zeros_like(w)
        .at[bar, meta["I"]].add(kmat)
        .at[bar, meta["J"]].add(-kmat)
        .at[bar, meta["resid"]].add(w_res)
    )


@functools.lru_cache(maxsize=None)
def _fused_paired_expert_dense_grad(
    activation, blocked, pair_block_n, x_per_expert, block_m, block_k, interpret
):
    """custom_vjp factory for the expert-axis paired GEMM — the same
    Pallas-forward / folded-XLA-backward split as _fused_paired_dense_grad,
    with the expert axis riding the blocked kernel's grid dimension."""
    from repro.kernels.paired_matmul import ACTIVATIONS

    def primal(x, w, meta):
        E, K, F = w.shape
        if blocked:
            Bc = meta["I"].shape[1]
            bn = pair_block_n
            m = {k: v.reshape(E * Bc, *v.shape[2:]) for k, v in meta.items()}
            kmat, w_res = _take_block_segments(
                _expert_blocked_weights(w, Bc, bn), m
            )
        else:
            Bc, bn = 1, F
            m = meta
            kmat, w_res = _take_block_segments(w, meta)
        perm = jnp.concatenate([m["I"], m["J"], m["resid"]], axis=-1)
        if x_per_expert:
            gather = lambda xe, pe: jnp.moveaxis(jnp.take(xe, pe, axis=-1), -2, 0)
            xg = jax.vmap(gather)(x, perm.reshape(E, Bc, -1))  # (E, Bc, M, K')
            xg = xg.reshape(E * Bc, x.shape[-2], -1)
        else:
            xg = jnp.moveaxis(jnp.take(x, perm, axis=-1), -2, 0)  # (E·Bc, M, K')
        y = paired_matmul_blocked(
            xg, kmat.astype(x.dtype), w_res.astype(x.dtype),
            n_cols=E * Bc * bn, activation=activation,
            block_m=block_m, block_k=block_k, interpret=interpret,
        )
        return y.reshape(x.shape[-2], E, Bc * bn)[..., :F]

    def ref(x, w, meta):
        wf = fold_lm_expert_weight(w, meta, pair_block_n)
        eq = "etk,ekf->tef" if x_per_expert else "tk,ekf->tef"
        return ACTIVATIONS[activation](jnp.einsum(eq, x, wf))

    @jax.custom_vjp
    def f(x, w, meta):
        return primal(x, w, meta)

    def fwd(x, w, meta):
        return primal(x, w, meta), (x, w, meta)

    def bwd(saved, dy):
        x, w, meta = saved
        _, vjp = jax.vjp(lambda x, w: ref(x, w, meta), x, w)
        dx, dw = vjp(dy)
        dmeta = {
            k: np.zeros(jnp.shape(a), jax.dtypes.float0)
            if jnp.issubdtype(jnp.result_type(a), jnp.integer)
            else jnp.zeros_like(a)
            for k, a in meta.items()
        }
        return dx, dw.astype(w.dtype), dmeta

    f.defvjp(fwd, bwd)
    return f


def fused_paired_expert_dense(
    x: jax.Array,  # (M, K) shared or (E, M, K) per-expert activations
    w: jax.Array,  # (E, K, F) live expert weights (one layer's scan slice)
    meta: dict,  # (E, …) pairing metadata (core.transform.pair_params)
    *,
    activation: str = "none",
    x_per_expert: bool = False,
    pair_block_n: int = 0,
    block_m: int = 0,
    block_k: int = 0,
    interpret: bool | None = None,
) -> jax.Array:
    """Differentiable per-expert paired GEMM → (M, E, F).

    The MoE analogue of :func:`fused_paired_dense`: ``meta`` holds one frozen
    lane structure *per expert* — ``(E, Pmax)`` lane lists select the
    structured-per-expert layout (each expert = one kernel block of all F
    columns), ``(E, Bc, Pmax)`` the blocked-within-expert one
    (``pair_block_n`` columns per block, as the metadata was built).
    ``x_per_expert=True`` contracts expert ``e``'s rows ``x[e]`` against
    expert ``e``'s weights (the "etk,ekf->tef" einsum); otherwise every
    expert sees the same (M, K) activations ("tk,ekf->tef").
    """
    blocked = meta["I"].ndim == 3
    if blocked and pair_block_n < 1:
        raise ValueError("blocked expert pairing metadata needs pair_block_n >= 1")
    fn = _fused_paired_expert_dense_grad(
        activation, blocked, pair_block_n if blocked else 0,
        bool(x_per_expert), block_m, block_k, interpret,
    )
    return fn(x, w, dict(meta))


# ---------------------------------------------------------------------------
# differentiable fused decode attention feeding the paired out-projection
# ---------------------------------------------------------------------------
#
# The decode-attention kernel (kernels.decode_attention) performs the paired
# out-projection in its flush step, so the attended values never reach HBM.
# The wrapper below normalizes whatever out-projection metadata the layer has
# — blocked (B, Pmax) lane lists, structured 1-D lists, or no pairing at all
# — into the kernel's column-blocked segment form:
#
#   * structured metadata lifts to one block of bn = N columns (``[None]`` on
#     every leaf) — the same layout ``fold_lm_weight`` treats as B == 1;
#   * an unpaired weight synthesizes a pure-residual block (one zero pair
#     lane with mask 0, ``resid = arange(K)``) so ``(o[I]-o[J])·kmat`` is
#     exactly zero and ``o[resid]·w_res == o @ W`` — the zero-lane trick;
#   * empty pair/residual segments (e.g. r=0 pairs nothing) pad to one zero
#     lane for the same reason, keeping every kernel operand non-empty.


def _attn_outproj_segments(w2: jax.Array, meta: dict | None, pair_block_n: int):
    """Normalized (idx_i, idx_j, idx_r, kmat, w_res) blocked segments of the
    out-projection for the fused decode-attention kernel."""
    K, N = w2.shape
    if meta is None:
        idx_i = idx_j = jnp.zeros((1, 1), jnp.int32)
        idx_r = jnp.arange(K, dtype=jnp.int32)[None]
        return idx_i, idx_j, idx_r, jnp.zeros((1, 1, N), w2.dtype), w2[None]
    if meta["I"].ndim == 1:
        meta = {k: v[None] for k, v in meta.items()}
        bn = N
    else:
        bn = pair_block_n
        assert bn >= 1, "blocked pairing metadata needs pair_block_n >= 1"
    kmat, w_res = _lm_blocked_segments(w2, meta, bn)
    idx_i, idx_j, idx_r = meta["I"], meta["J"], meta["resid"]
    B = idx_i.shape[0]
    if idx_i.shape[1] == 0:
        idx_i = idx_j = jnp.zeros((B, 1), jnp.int32)
        kmat = jnp.zeros((B, 1, bn), kmat.dtype)
    if idx_r.shape[1] == 0:
        idx_r = jnp.zeros((B, 1), jnp.int32)
        w_res = jnp.zeros((B, 1, bn), w_res.dtype)
    return idx_i, idx_j, idx_r, kmat, w_res


@functools.lru_cache(maxsize=None)
def _fused_attn_decode_grad(
    pair_block_n, window, n_sink, k_chunk, interpret, has_meta, has_residual
):
    """custom_vjp factory for the fused decode-attention + out-projection op.

    Pallas forward, XLA-reference backward (the same split as the paired
    GEMMs): the backward differentiates ``decode_attention_ref`` composed
    with the *folded* dense out-projection equivalent — decode attention is
    inference-only today, so the VJP exists to keep the op safely
    differentiable (a grad probe, a perplexity eval) rather than to be a
    training-speed path.  ``pos`` and the integer metadata get float0
    cotangents."""
    from repro.kernels.decode_attention import (
        decode_attention_ref,
        fused_decode_attention,
    )

    def primal(q, k_cache, v_cache, pos, w2, res, meta):
        N = w2.shape[1]
        idx_i, idx_j, idx_r, kmat, w_res = _attn_outproj_segments(
            w2, meta if has_meta else None, pair_block_n
        )
        res2 = None if res is None else res.reshape(-1, N)
        y = fused_decode_attention(
            q, k_cache, v_cache, pos, idx_i, idx_j, idx_r,
            kmat.astype(q.dtype), w_res.astype(q.dtype), res2,
            n_cols=N, window=window, n_sink=n_sink, k_chunk=k_chunk,
            interpret=True if interpret is None else interpret,
        )
        return y[:, None]  # (B, 1, N)

    def ref(q, k_cache, v_cache, pos, w2, res, meta):
        out = decode_attention_ref(
            q, k_cache, v_cache, pos, window=window, n_sink=n_sink
        )
        wf = fold_lm_weight(w2, meta, pair_block_n) if has_meta else w2
        o2 = out.reshape(*out.shape[:2], -1)
        z = jnp.einsum("bsk,kn->bsn", o2, wf.astype(o2.dtype))
        return z + res.astype(z.dtype) if res is not None else z

    @jax.custom_vjp
    def f(q, k_cache, v_cache, pos, w2, res, meta):
        return primal(q, k_cache, v_cache, pos, w2, res, meta)

    def fwd(q, k_cache, v_cache, pos, w2, res, meta):
        return primal(q, k_cache, v_cache, pos, w2, res, meta), (
            q, k_cache, v_cache, pos, w2, res, meta
        )

    def bwd(saved, dy):
        q, k_cache, v_cache, pos, w2, res, meta = saved
        _, vjp = jax.vjp(
            lambda q, kc, vc, w2, res: ref(q, kc, vc, pos, w2, res, meta),
            q, k_cache, v_cache, w2, res,
        )
        dq, dk, dv, dw, dres = vjp(dy)
        dpos = np.zeros(jnp.shape(pos), jax.dtypes.float0)
        dmeta = {
            k: np.zeros(jnp.shape(a), jax.dtypes.float0)
            if jnp.issubdtype(jnp.result_type(a), jnp.integer)
            else jnp.zeros_like(a)
            for k, a in meta.items()
        }
        return dq, dk, dv, dpos, dw.astype(w2.dtype), dres, dmeta

    f.defvjp(fwd, bwd)
    return f


def fused_attn_decode(
    q: jax.Array,  # (B, 1, H, D) one post-rope query row per slot
    k_cache: jax.Array,  # (B, S, KH, D)
    v_cache: jax.Array,
    pos: jax.Array,  # (B,) int32
    w: jax.Array,  # (K=H·D, N) live out-projection weights
    meta: dict | None = None,  # out-proj pairing metadata (any layout)
    *,
    residual: jax.Array | None = None,  # (B, 1, N) fused skip connection
    pair_block_n: int = 0,
    window: int = 0,
    n_sink: int = 0,
    k_chunk: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Differentiable fused decode attention + paired out-projection.

    One Pallas launch per decode step: online-softmax attention over the KV
    cache with the out-projection (and the sublayer residual) applied in the
    kernel flush — the attended values never round-trip HBM.  ``meta`` is
    the out-projection's frozen pairing structure in any of the LM layouts
    (1-D structured, 2-D blocked with ``pair_block_n``) or ``None`` for an
    unpaired weight (exact dense projection via a synthesized pure-residual
    block).  Returns (B, 1, N).
    """
    has_meta = meta is not None
    blocked = has_meta and meta["I"].ndim == 2
    if blocked and pair_block_n < 1:
        raise ValueError("blocked pairing metadata needs pair_block_n >= 1")
    fn = _fused_attn_decode_grad(
        pair_block_n if blocked else 0, window, n_sink, k_chunk, interpret,
        has_meta, residual is not None,
    )
    return fn(q, k_cache, v_cache, pos, w, residual,
              dict(meta) if has_meta else {})


# ---------------------------------------------------------------------------
# GEMM policy: route model-layer matmuls through the fused kernels
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GemmPolicy:
    """Tile sizes + backend choice for layer GEMMs (0 → tuning heuristic)."""

    block_m: int = 0
    block_n: int = 0
    block_k: int = 0
    interpret: bool | None = None


_policy_state = threading.local()


def current_gemm_policy() -> GemmPolicy | None:
    return getattr(_policy_state, "policy", None)


@contextlib.contextmanager
def pallas_gemm(
    block_m: int = 0,
    block_n: int = 0,
    block_k: int = 0,
    interpret: bool | None = None,
):
    """Route :func:`repro.models.layers.dense` through the Pallas kernels.

    Thread-local, like :func:`repro.parallel.sharding.activate`; wrap the
    ``jax.jit`` trace (or the eager call) of a step to take effect.
    """
    prev = current_gemm_policy()
    _policy_state.policy = GemmPolicy(block_m, block_n, block_k, interpret)
    try:
        yield
    finally:
        _policy_state.policy = prev


@dataclasses.dataclass(frozen=True)
class PairedGemmPolicy:
    """Routing for the *paired* LM GEMM path (``gemm="pallas_paired"``).

    When active, :func:`repro.models.layers.dense` routes every GEMM whose
    weight carries pairing metadata (``core.transform.pair_lm_params``)
    through :func:`fused_paired_dense` — the subtractor kernel with the
    residual-add epilogue.  ``pair_block_n`` is the pairing block size the
    metadata was built with (0 → structured; it must match, the blocked
    kernel needs it to reassemble the packed column layout).
    """

    pair_block_n: int = 0
    block_m: int = 0
    block_n: int = 0
    block_k: int = 0
    interpret: bool | None = None


def current_paired_gemm_policy() -> PairedGemmPolicy | None:
    return getattr(_policy_state, "paired_gemm", None)


@contextlib.contextmanager
def pallas_paired_gemm(
    pair_block_n: int = 0,
    block_m: int = 0,
    block_n: int = 0,
    block_k: int = 0,
    interpret: bool | None = None,
):
    """Route pairing-annotated layer GEMMs through the subtractor kernel.

    Thread-local and trace-time, like :func:`pallas_gemm`; weights without
    pairing metadata keep their XLA einsum path.
    """
    prev = current_paired_gemm_policy()
    _policy_state.paired_gemm = PairedGemmPolicy(
        pair_block_n, block_m, block_n, block_k, interpret
    )
    try:
        yield
    finally:
        _policy_state.paired_gemm = prev


def gemm_context(knobs):
    """Context manager for a PerfKnobs-like object (``gemm``/``block_*``).

    ``knobs.gemm == "pallas"`` activates :func:`pallas_gemm` with the knob
    tile sizes; ``"pallas_paired"`` activates :func:`pallas_paired_gemm`
    (the subtractor path for pairing-annotated LM weights, honouring
    ``knobs.pair_block_n``); anything else is a no-op (XLA einsum path).
    """
    gemm = getattr(knobs, "gemm", "xla")
    if gemm == "pallas":
        return pallas_gemm(
            block_m=getattr(knobs, "block_m", 0),
            block_n=getattr(knobs, "block_n", 0),
            block_k=getattr(knobs, "block_k", 0),
        )
    if gemm == "pallas_paired":
        return pallas_paired_gemm(
            pair_block_n=getattr(knobs, "pair_block_n", 0),
            block_m=getattr(knobs, "block_m", 0),
            block_n=getattr(knobs, "block_n", 0),
            block_k=getattr(knobs, "block_k", 0),
        )
    return contextlib.nullcontext()


# ---------------------------------------------------------------------------
# conv policy: route model convolutions through im2col / the paired kernel
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ConvPolicy:
    """Conv lowering choice + artifacts for :func:`repro.models.lenet.lenet_apply`.

    ``impl`` is one of ``"xla"`` (lax.conv), ``"im2col"`` (patch GEMM via
    XLA) or ``"pallas_paired"`` (patch GEMM through the paired kernel, which
    additionally needs the per-layer ``paired`` artifacts from
    :func:`repro.core.transform.build_conv_pairings`).  ``fuse_pool`` makes
    the ``"pallas_paired"`` path absorb a following 2×2 max-pool into the
    kernel epilogue (the conv→pool megakernel: one HBM writeback, no
    standalone pooling op).  ``pair_block_n`` records the pairing mode the
    artifacts should be built with (0 → structured shared-row pairing;
    ``n >= 1`` → column-blocked pairing with that block size, ``1`` being
    the paper's per-column pairing) — :func:`conv_pairings_from_knobs`
    builds artifacts honouring it, via :func:`paired_mode_of`.
    """

    impl: str = "xla"
    paired: object = None  # {layer_name: PairedLayer} for "pallas_paired"
    fuse_pool: bool = False
    pair_block_n: int = 0
    block_m: int = 0
    block_n: int = 0
    block_k: int = 0
    interpret: bool | None = None


def current_conv_policy() -> ConvPolicy | None:
    return getattr(_policy_state, "conv", None)


@contextlib.contextmanager
def pallas_conv(
    impl: str = "pallas_paired",
    paired=None,
    fuse_pool: bool = False,
    pair_block_n: int = 0,
    block_m: int = 0,
    block_n: int = 0,
    block_k: int = 0,
    interpret: bool | None = None,
):
    """Thread-local conv policy, symmetric with :func:`pallas_gemm`.

    Model forwards that take ``conv_impl=None`` (lenet_apply) consult it at
    trace time; wrap the jit trace, not the jit call.
    """
    prev = current_conv_policy()
    _policy_state.conv = ConvPolicy(
        impl, paired, fuse_pool, pair_block_n,
        block_m, block_n, block_k, interpret
    )
    try:
        yield
    finally:
        _policy_state.conv = prev


def paired_mode_of(knobs_or_policy) -> tuple[str, int]:
    """(pairing mode, block_n) a ``pair_block_n`` knob encodes.

    ``0`` → ``("structured", 0)`` — today's shared-row pairing.  ``n >= 1``
    → ``("column_blocked", n)``: per-block shared-row pairing, ``n == 1``
    being the paper's per-column pairing.  Feed the result straight into
    ``build_conv_pairings(mode=…, block_n=…)`` / ``pair_model_params``.
    """
    n = int(getattr(knobs_or_policy, "pair_block_n", 0) or 0)
    return ("column_blocked", n) if n >= 1 else ("structured", 0)


def conv_pairings_from_knobs(params, rounding: float, knobs, *, positions=None):
    """Per-layer conv pairing artifacts honouring ``knobs.pair_block_n``.

    The offline half of the ``pair_block_n`` knob: build the
    ``build_conv_pairings`` artifacts in the mode the knob encodes
    (structured at 0, column-blocked at ``n >= 1``), ready to hand to
    ``conv_context(knobs, paired=…)`` / ``pallas_conv(paired=…)``.  Runs on
    concrete weights (numpy), like all pairing preprocessing.
    """
    from repro.core.transform import build_conv_pairings

    mode, block_n = paired_mode_of(knobs)
    return build_conv_pairings(
        params, rounding, positions=positions, mode=mode, block_n=block_n
    )


def conv_context(knobs, paired=None):
    """ConvPolicy context from a PerfKnobs-like object (``conv``/``block_*``).

    ``knobs.conv`` other than ``"xla"`` activates :func:`pallas_conv` with
    that implementation; ``paired`` supplies the per-layer artifacts the
    ``"pallas_paired"`` choice consumes, ``knobs.fuse_pool`` turns on the
    conv→pool megakernel epilogue, and ``knobs.pair_block_n`` records the
    pairing mode the artifacts were (or should be) built with.
    """
    impl = getattr(knobs, "conv", "xla")
    if impl != "xla":
        return pallas_conv(
            impl,
            paired=paired,
            fuse_pool=getattr(knobs, "fuse_pool", False),
            pair_block_n=getattr(knobs, "pair_block_n", 0),
            block_m=getattr(knobs, "block_m", 0),
            block_n=getattr(knobs, "block_n", 0),
            block_k=getattr(knobs, "block_k", 0),
        )
    return contextlib.nullcontext()


@dataclasses.dataclass(frozen=True)
class AttnPolicy:
    """Routing for decode attention (``attn="pallas_fused"``).

    When active, :func:`repro.models.layers.attention_decode_block` routes
    the single-token attention + out-projection through
    :func:`fused_attn_decode` — one Pallas launch whose flush applies the
    out-projection's subtractor segments and the sublayer residual in VMEM,
    so the attended values never round-trip HBM.  ``k_chunk`` is the KV-cache
    chunk the online softmax streams over.  Prefill paths are unaffected.
    """

    impl: str = "pallas_fused"
    k_chunk: int = 128
    interpret: bool | None = None


def current_attn_policy() -> AttnPolicy | None:
    return getattr(_policy_state, "attn", None)


@contextlib.contextmanager
def pallas_attn(
    impl: str = "pallas_fused",
    k_chunk: int = 128,
    interpret: bool | None = None,
):
    """Route single-token decode attention through the fused Pallas kernel.

    Thread-local and trace-time, like :func:`pallas_gemm`; wrap the jit
    trace of the decode step, not the jit call.
    """
    prev = current_attn_policy()
    _policy_state.attn = AttnPolicy(impl, k_chunk, interpret)
    try:
        yield
    finally:
        _policy_state.attn = prev


def attn_context(knobs):
    """AttnPolicy context from a PerfKnobs-like object (``attn``/``k_chunk``).

    ``knobs.attn == "pallas_fused"`` activates :func:`pallas_attn` with the
    knob's KV chunk; anything else is a no-op (the XLA decode-attention
    einsums + the standalone out-projection GEMM).
    """
    impl = getattr(knobs, "attn", "xla")
    if impl == "pallas_fused":
        return pallas_attn(impl, k_chunk=getattr(knobs, "k_chunk", 128) or 128)
    return contextlib.nullcontext()


def tile_cache_context(knobs):
    """``knobs.tile_cache`` (a path) installs a persisted TileCache so the
    kernels' tile selection prefers measured winners over the heuristic;
    empty/absent is a no-op (heuristic only).  Trace-time, like the other
    policies: choose_blocks runs while the step is being traced."""
    path = getattr(knobs, "tile_cache", "")
    if path:
        return tuning.use_tile_cache(path)
    return contextlib.nullcontext()


@contextlib.contextmanager
def perf_context(knobs, paired=None):
    """Activate every kernel policy a PerfKnobs asks for (gemm + conv +
    attn + tile cache)."""
    with tile_cache_context(knobs), gemm_context(knobs), conv_context(
        knobs, paired=paired
    ), attn_context(knobs):
        yield
