"""Public jit'd wrappers around the Pallas kernels.

On a real TPU backend ``interpret=False`` compiles the Mosaic kernel; in this
CPU container the kernels run (and are tested) in interpret mode.  The
wrapper also owns the *deployment* plumbing: applying a
:class:`repro.core.pairing.StructuredPairing` to activations, including the
input permutation (which in production folds into the previous layer).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pairing import StructuredPairing
from repro.kernels.paired_matmul import dense_matmul_pallas, paired_matmul_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def paired_matmul(
    x: jax.Array,
    kmat: jax.Array,
    w_res: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """(…, K) @ paired weights → (…, N). x pre-permuted to [I|J|residual]."""
    interp = (not _on_tpu()) if interpret is None else interpret
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = paired_matmul_pallas(
        x2, kmat, w_res, block_m=block_m, block_n=block_n, interpret=interp
    )
    return y.reshape(*lead, y.shape[-1])


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "interpret"))
def dense_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    interp = (not _on_tpu()) if interpret is None else interpret
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = dense_matmul_pallas(x2, w, block_m=block_m, block_n=block_n, interpret=interp)
    return y.reshape(*lead, y.shape[-1])


def apply_structured_pairing(
    x: jax.Array, sp: StructuredPairing, *, fold_perm: bool = False, **kw
) -> jax.Array:
    """Evaluate x @ W through the paired kernel given a StructuredPairing.

    ``fold_perm=False`` applies the [I|J|residual] permutation here (one
    gather); in production the permutation folds into the previous layer's
    output weights and the gather disappears.
    """
    perm = jnp.asarray(sp.perm())
    xp = x if fold_perm else jnp.take(x, perm, axis=-1)
    kmat = jnp.asarray(sp.Kmat, dtype=x.dtype)
    w_res = jnp.asarray(sp.W_res, dtype=x.dtype)
    return paired_matmul(xp, kmat, w_res, **kw)
