"""im2col: lower a convolution to the GEMM the paired kernel understands.

The paper's accelerator applies the subtractor datapath *during convolution*
(eq. 1 operates on two input pixels feeding the same output value).  On the
TPU the analogous lowering is im2col: extract every (kh, kw, cin) receptive
field as one row of a patch matrix, so the conv becomes

    y[n, oh, ow, :] = patches[n, oh, ow, :] @ W.reshape(kh*kw*cin, cout)

and the paired GEMM kernel (kernels/paired_matmul.py) runs unchanged on the
patch rows — pairs of *patch lanes* subtract exactly like pairs of input
channels do for a dense layer.

Layout contract: NHWC activations, HWIO weights.  Stride and padding are
general: ``stride`` is an int or (sh, sw); ``padding`` is ``"VALID"``,
``"SAME"`` (XLA/TF split: low = total // 2), or explicit
``((ph_lo, ph_hi), (pw_lo, pw_hi))``.  The patch axis is ordered
(kh, kw, cin) row-major, i.e. exactly the order of
``w.reshape(kh*kw*cin, cout)`` — so conv weights flatten to the GEMM weight
matrix with a plain reshape, no transpose.

The extraction itself is ``kh*kw`` strided views of the (zero-)padded input
concatenated on the channel axis: pure strided slices, which XLA fuses and
Pallas BlockSpecs can index — no scatter/gather tables.  ``col2im`` is the
exact adjoint (strided overlap-add into the padded frame, then un-pad),
which is what makes the conv path differentiable end to end at every
stride/padding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Stride = int | tuple[int, int]
Padding = str | tuple[tuple[int, int], tuple[int, int]]


def _stride_hw(stride: Stride) -> tuple[int, int]:
    if isinstance(stride, int):
        assert stride >= 1, f"stride must be >= 1, got {stride}"
        return stride, stride
    sh, sw = stride
    assert sh >= 1 and sw >= 1, f"stride must be >= 1, got {stride}"
    return int(sh), int(sw)


def _same_pad(size: int, k: int, s: int) -> tuple[int, int]:
    """TF/XLA SAME: out = ceil(size / s), low pad gets the smaller half."""
    out = -(-size // s)
    total = max((out - 1) * s + k - size, 0)
    return total // 2, total - total // 2


def resolve_padding(
    h: int, w: int, kh: int, kw: int, stride: Stride, padding: Padding
) -> tuple[tuple[int, int], tuple[int, int]]:
    """Normalise ``padding`` to explicit ((ph_lo, ph_hi), (pw_lo, pw_hi))."""
    sh, sw = _stride_hw(stride)
    if padding == "VALID":
        return (0, 0), (0, 0)
    if padding == "SAME":
        return _same_pad(h, kh, sh), _same_pad(w, kw, sw)
    (ph, pw) = padding  # explicit pairs
    return (int(ph[0]), int(ph[1])), (int(pw[0]), int(pw[1]))


def conv_output_hw(
    h: int,
    w: int,
    kh: int,
    kw: int,
    stride: Stride = 1,
    padding: Padding = "VALID",
) -> tuple[int, int]:
    """Output spatial dims of a conv at the given stride/padding."""
    sh, sw = _stride_hw(stride)
    (ph0, ph1), (pw0, pw1) = resolve_padding(h, w, kh, kw, stride, padding)
    oh = (h + ph0 + ph1 - kh) // sh + 1
    ow = (w + pw0 + pw1 - kw) // sw + 1
    assert oh > 0 and ow > 0, (
        f"kernel ({kh},{kw}) stride {(sh, sw)} padding {padding} yields empty "
        f"output for input ({h},{w})"
    )
    return oh, ow


def im2col(
    x: jax.Array,
    kh: int,
    kw: int,
    *,
    stride: Stride = 1,
    padding: Padding = "VALID",
) -> jax.Array:
    """Extract patches: (N, H, W, C) → (N, OH, OW, kh*kw*C).

    Row layout of the last axis is (kh, kw, cin) row-major, matching
    ``w.reshape(kh*kw*cin, cout)`` for HWIO conv weights.  Defaults
    (stride 1, VALID) reproduce the original LeNet-only extraction.
    """
    n, h, w, c = x.shape
    sh, sw = _stride_hw(stride)
    (ph0, ph1), (pw0, pw1) = resolve_padding(h, w, kh, kw, stride, padding)
    oh, ow = conv_output_hw(h, w, kh, kw, stride, padding)
    del n, c
    if ph0 or ph1 or pw0 or pw1:
        x = jnp.pad(x, ((0, 0), (ph0, ph1), (pw0, pw1), (0, 0)))
    views = [
        x[:, i : i + sh * (oh - 1) + 1 : sh, j : j + sw * (ow - 1) + 1 : sw, :]
        for i in range(kh)
        for j in range(kw)
    ]
    return jnp.concatenate(views, axis=-1)


def col2im(
    cols: jax.Array,
    x_shape: tuple[int, int, int, int],
    kh: int,
    kw: int,
    *,
    stride: Stride = 1,
    padding: Padding = "VALID",
) -> jax.Array:
    """Adjoint of :func:`im2col`: overlap-add patches back to image shape.

    cols: (N, OH, OW, kh*kw*C) → (N, H, W, C).  Satisfies
    ``<im2col(x), y> == <x, col2im(y)>`` exactly at every stride/padding
    (scatter-add into the padded frame, then slice the padding off — the
    transpose of pad-then-strided-slice), so it is the VJP of the patch
    extraction (used by the paired-conv backward pass).
    """
    n, h, w, c = x_shape
    sh, sw = _stride_hw(stride)
    (ph0, ph1), (pw0, pw1) = resolve_padding(h, w, kh, kw, stride, padding)
    oh, ow = conv_output_hw(h, w, kh, kw, stride, padding)
    out = jnp.zeros((n, h + ph0 + ph1, w + pw0 + pw1, c), cols.dtype)
    idx = 0
    for i in range(kh):
        for j in range(kw):
            out = out.at[
                :,
                i : i + sh * (oh - 1) + 1 : sh,
                j : j + sw * (ow - 1) + 1 : sw,
                :,
            ].add(cols[..., idx * c : (idx + 1) * c])
            idx += 1
    return out[:, ph0 : ph0 + h, pw0 : pw0 + w, :]


def overlap_counts(
    x_shape: tuple[int, int, int, int],
    kh: int,
    kw: int,
    *,
    stride: Stride = 1,
    padding: Padding = "VALID",
) -> jax.Array:
    """How many patches cover each input pixel: col2im(im2col(1)) == counts.

    Dividing by this normalises the round-trip back to the original image
    where coverage is nonzero (strided extractions can skip pixels
    entirely; padding makes border coverage asymmetric).
    """
    ones = jnp.ones(x_shape, jnp.float32)
    return col2im(
        im2col(ones, kh, kw, stride=stride, padding=padding),
        x_shape,
        kh,
        kw,
        stride=stride,
        padding=padding,
    )
