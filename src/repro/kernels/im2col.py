"""im2col: lower a convolution to the GEMM the paired kernel understands.

The paper's accelerator applies the subtractor datapath *during convolution*
(eq. 1 operates on two input pixels feeding the same output value).  On the
TPU the analogous lowering is im2col: extract every (kh, kw, cin) receptive
field as one row of a patch matrix, so the conv becomes

    y[n, oh, ow, :] = patches[n, oh, ow, :] @ W.reshape(kh*kw*cin, cout)

and the paired GEMM kernel (kernels/paired_matmul.py) runs unchanged on the
patch rows — pairs of *patch lanes* subtract exactly like pairs of input
channels do for a dense layer.

Layout contract: NHWC activations, HWIO weights, VALID padding, stride 1
(LeNet-5's convs; the only conv geometry the paper evaluates).  The patch
axis is ordered (kh, kw, cin) row-major, i.e. exactly the order of
``w.reshape(kh*kw*cin, cout)`` — so conv weights flatten to the GEMM weight
matrix with a plain reshape, no transpose.

The extraction itself is ``kh*kw`` shifted views concatenated on the channel
axis: pure strided slices, which XLA fuses and Pallas BlockSpecs can index —
no scatter/gather tables.  ``col2im`` is the exact adjoint (overlap-add),
which is what makes the conv path differentiable end to end.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def conv_output_hw(h: int, w: int, kh: int, kw: int) -> tuple[int, int]:
    """Output spatial dims of a VALID, stride-1 conv."""
    oh, ow = h - kh + 1, w - kw + 1
    assert oh > 0 and ow > 0, f"kernel ({kh},{kw}) larger than input ({h},{w})"
    return oh, ow


def im2col(x: jax.Array, kh: int, kw: int) -> jax.Array:
    """Extract patches: (N, H, W, C) → (N, OH, OW, kh*kw*C).

    Row layout of the last axis is (kh, kw, cin) row-major, matching
    ``w.reshape(kh*kw*cin, cout)`` for HWIO conv weights.
    """
    n, h, w, c = x.shape
    oh, ow = conv_output_hw(h, w, kh, kw)
    del n, c
    views = [
        x[:, i : i + oh, j : j + ow, :] for i in range(kh) for j in range(kw)
    ]
    return jnp.concatenate(views, axis=-1)


def col2im(
    cols: jax.Array, x_shape: tuple[int, int, int, int], kh: int, kw: int
) -> jax.Array:
    """Adjoint of :func:`im2col`: overlap-add patches back to image shape.

    cols: (N, OH, OW, kh*kw*C) → (N, H, W, C).  Satisfies
    ``<im2col(x), y> == <x, col2im(y)>`` exactly, so it is the VJP of the
    patch extraction (used by the paired-conv backward pass).
    """
    n, h, w, c = x_shape
    oh, ow = conv_output_hw(h, w, kh, kw)
    del n
    out = jnp.zeros(x_shape, cols.dtype)
    idx = 0
    for i in range(kh):
        for j in range(kw):
            out = out.at[:, i : i + oh, j : j + ow, :].add(
                cols[..., idx * c : (idx + 1) * c]
            )
            idx += 1
    return out


def overlap_counts(
    x_shape: tuple[int, int, int, int], kh: int, kw: int
) -> jax.Array:
    """How many patches cover each input pixel: col2im(im2col(1)) == counts.

    Dividing by this normalises the round-trip back to the original image
    (interior pixels are covered kh·kw times, borders fewer).
    """
    ones = jnp.ones(x_shape, jnp.float32)
    return col2im(im2col(ones, kh, kw), x_shape, kh, kw)
