"""Pallas TPU kernel: single-token decode attention fused into the paired
out-projection.

The decode step is the memory-bound half of serving: one query row per slot
attends over the KV cache, and before this kernel existed the attended
values round-tripped HBM between the attention einsums and the paired
out-projection subtractor kernel — the one non-paired gap left in the decode
schedule (ROADMAP "Close the attention gap").  This kernel closes it: the
online softmax runs in fp32 VMEM scratch, and the flush step applies the
out-projection's column-blocked subtractor arithmetic *inside the kernel* —
``y[b] = (o[I]-o[J])·kmat + o[resid]·w_res (+ residual)`` — so the attended
vector is never materialized in HBM.  The ``residual=`` epilogue matches the
paired GEMM kernel's: an fp32 add in VMEM, no standalone residual add op.

Geometry: grid ``(B, nk)`` with the KV-chunk axis innermost (sequential —
the (m, l, acc) scratch carries across chunks of one slot's cache).  GQA is
handled by reshaping the query row to ``(KH, G, D)`` in-kernel; scores and
probabilities batch over KV heads on the MXU.  Masking matches
``layers._block_mask`` decode semantics exactly: keys at ``pk <= pos``,
restricted to the sliding window ``pk > pos - window`` when one is set, with
the first ``n_sink`` (meta-token) positions always visible.  Chunks fully
outside the mask are skipped via ``pl.when`` without touching the MXU.

The subtractor difference ``o[I] - o[J]`` here operates on the fp32
VMEM-resident attended values: unlike the standalone paired GEMM (whose
activations arrive from HBM at input precision, pinned with
``reduce_precision``), the attended vector never exists at storage precision,
so the kernel casts it once to the I/O dtype before the projection to keep
the arithmetic aligned with the unfused reference path.

The index gather in the flush uses ``jnp.take`` on the flattened attended
vector; on real hardware this folds into a one-hot MXU contraction (the same
trick the im2col path uses), which interpret mode models exactly.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.flash_attention import _SCRATCH, _pad_axis


def decode_attention_ref(
    q: jax.Array,  # (B, 1, H, D)
    k_cache: jax.Array,  # (B, S, KH, D)
    v_cache: jax.Array,
    pos: jax.Array,  # (B,) int32
    *,
    window: int = 0,
    n_sink: int = 0,
) -> jax.Array:
    """XLA reference for the attention half (mirrors
    ``layers.decode_attention``) — the custom-VJP backward differentiates
    this instead of the kernel."""
    B, _, H, D = q.shape
    _, S, KH, _ = k_cache.shape
    G = H // KH
    qg = q[:, 0].reshape(B, KH, G, D).astype(jnp.float32)
    k = k_cache.astype(jnp.float32)
    v = v_cache.astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k) * (1.0 / math.sqrt(D))
    pk = jnp.arange(S)[None, None, None, :]
    p_ = pos[:, None, None, None]
    ok = pk <= p_
    if window:
        in_w = pk > p_ - window
        if n_sink:
            in_w = in_w | (pk < n_sink)
        ok = ok & in_w
    s = jnp.where(ok, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v)
    return out.reshape(B, 1, H, D).astype(q.dtype)


def _decode_attn_kernel(
    *refs,
    scale: float,
    window: int,
    n_sink: int,
    k_chunk: int,
    nk: int,
    KH: int,
    G: int,
    n_cols: int,
    proj: bool,
    has_residual: bool,
    io_dtype,
):
    it = iter(refs)
    q_ref, k_ref, v_ref, pos_ref = next(it), next(it), next(it), next(it)
    if proj:
        i_ref, j_ref, r_ref = next(it), next(it), next(it)
        km_ref, wr_ref = next(it), next(it)
    resid_ref = next(it) if has_residual else None
    o_ref, m_ref, l_ref, acc_ref = next(it), next(it), next(it), next(it)

    ki = pl.program_id(1)
    pos = pos_ref[0]
    base = ki * k_chunk

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # chunk liveness: some key in [base, base+k_chunk) passes the mask
    live = base <= pos
    if window:
        hi = base + k_chunk - 1 > pos - window
        if n_sink:
            hi |= base < n_sink
        live &= hi

    @pl.when(live)
    def _compute():
        D = q_ref.shape[-1]
        q = q_ref[0].astype(jnp.float32).reshape(KH, G, D)
        kt = k_ref[0].astype(jnp.float32).transpose(1, 0, 2)  # (KH, C, D)
        vt = v_ref[0].astype(jnp.float32).transpose(1, 0, 2)
        s = jax.lax.dot_general(
            q, kt, (((2,), (2,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        ) * scale  # (KH, G, C)
        pk = base + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        ok = pk <= pos
        if window:
            in_w = pk > pos - window
            if n_sink:
                in_w |= pk < n_sink
            ok &= in_w
        s = jnp.where(ok, s, -jnp.inf)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_ref[...] = l_ref[...] * corr + p.sum(-1)
        acc_ref[...] = acc_ref[...] * corr[..., None] + jax.lax.dot_general(
            p, vt, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o = acc_ref[...] / l[..., None]  # (KH, G, D) fp32
        if not proj:
            o_ref[0] = o.reshape(o_ref.shape[1:]).astype(o_ref.dtype)
            return
        # paired out-projection, still in VMEM: gather the flattened
        # attended vector by the frozen [I | J | resid] metadata and
        # contract each column block against its subtractor segments
        of = o.reshape(-1).astype(io_dtype).astype(jnp.float32)  # (H·D,)
        oi = jnp.take(of, i_ref[...], axis=0)  # (Bw, Pmax)
        oj = jnp.take(of, j_ref[...], axis=0)
        orr = jnp.take(of, r_ref[...], axis=0)  # (Bw, Rmax)
        km = km_ref[...].astype(jnp.float32)  # (Bw, Pmax, bn)
        wr = wr_ref[...].astype(jnp.float32)  # (Bw, Rmax, bn)
        y = jnp.einsum("bp,bpn->bn", oi - oj, km,
                       preferred_element_type=jnp.float32)
        y += jnp.einsum("br,brn->bn", orr, wr,
                        preferred_element_type=jnp.float32)
        y = y.reshape(-1)[:n_cols]
        if has_residual:
            # fused skip connection: fp32 add in VMEM, no standalone add op
            y += resid_ref[0].astype(jnp.float32)
        o_ref[0] = y.astype(o_ref.dtype)


def _grid_pieces(q, k_cache, v_cache, pos, k_chunk):
    B, _, H, D = q.shape
    _, S, KH, _ = k_cache.shape
    if H % KH != 0:
        raise ValueError(
            f"GQA requires query heads to divide evenly over kv heads: "
            f"H={H}, KH={KH}"
        )
    k_chunk = min(k_chunk, S)
    nk = -(-S // k_chunk)
    k_cache = _pad_axis(k_cache, 1, nk * k_chunk)
    v_cache = _pad_axis(v_cache, 1, nk * k_chunk)
    pos = pos.astype(jnp.int32)
    return B, H, D, KH, H // KH, S, k_chunk, nk, k_cache, v_cache, pos


def decode_attention_fwd(
    q: jax.Array,  # (B, 1, H, D) one post-rope query row per slot
    k_cache: jax.Array,  # (B, S, KH, D)
    v_cache: jax.Array,
    pos: jax.Array,  # (B,) int32 current position of each slot
    *,
    window: int = 0,
    n_sink: int = 0,
    k_chunk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Bare fused decode attention: returns the attended (B, 1, H, D) rows.

    Same kernel as :func:`fused_decode_attention` minus the out-projection —
    the parity surface the tests pin against ``layers.decode_attention``.
    """
    (B, H, D, KH, G, S, k_chunk, nk,
     k_cache, v_cache, pos) = _grid_pieces(q, k_cache, v_cache, pos, k_chunk)
    kernel = functools.partial(
        _decode_attn_kernel,
        scale=1.0 / math.sqrt(D), window=window, n_sink=n_sink,
        k_chunk=k_chunk, nk=nk, KH=KH, G=G, n_cols=0,
        proj=False, has_residual=False, io_dtype=q.dtype,
    )
    out = pl.pallas_call(
        kernel,
        name="fused_attn_decode",
        grid=(B, nk),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, ki: (b, 0, 0)),
            pl.BlockSpec((1, k_chunk, KH, D), lambda b, ki: (b, ki, 0, 0)),
            pl.BlockSpec((1, k_chunk, KH, D), lambda b, ki: (b, ki, 0, 0)),
            pl.BlockSpec((1,), lambda b, ki: (b,)),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b, ki: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            _SCRATCH((KH, G), jnp.float32),
            _SCRATCH((KH, G), jnp.float32),
            _SCRATCH((KH, G, D), jnp.float32),
        ],
        interpret=interpret,
    )(q[:, 0], k_cache, v_cache, pos)
    return out[:, None]


def fused_decode_attention(
    q: jax.Array,  # (B, 1, H, D)
    k_cache: jax.Array,  # (B, S, KH, D)
    v_cache: jax.Array,
    pos: jax.Array,  # (B,) int32
    idx_i: jax.Array,  # (Bw, Pmax) int32 blocked pair lanes of the out-proj
    idx_j: jax.Array,  # (Bw, Pmax) int32
    idx_r: jax.Array,  # (Bw, Rmax) int32 residual lanes
    kmat: jax.Array,  # (Bw, Pmax, bn) masked pair magnitudes (W[I]-W[J])/2
    w_res: jax.Array,  # (Bw, Rmax, bn) masked residual weights
    residual: jax.Array | None,  # (B, n_cols) fused skip connection
    *,
    n_cols: int,
    out_dtype=None,
    window: int = 0,
    n_sink: int = 0,
    k_chunk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Decode attention + paired out-projection in one launch → (B, n_cols).

    The attended values live only in VMEM scratch; the single HBM writeback
    per slot is the projected (and residual-added) output row.
    """
    (B, H, D, KH, G, S, k_chunk, nk,
     k_cache, v_cache, pos) = _grid_pieces(q, k_cache, v_cache, pos, k_chunk)
    Bw, Pmax = idx_i.shape
    Rmax = idx_r.shape[1]
    bn = kmat.shape[-1]
    assert Bw * bn >= n_cols, (Bw, bn, n_cols)
    has_residual = residual is not None
    if out_dtype is None:
        out_dtype = residual.dtype if has_residual else q.dtype
    kernel = functools.partial(
        _decode_attn_kernel,
        scale=1.0 / math.sqrt(D), window=window, n_sink=n_sink,
        k_chunk=k_chunk, nk=nk, KH=KH, G=G, n_cols=n_cols,
        proj=True, has_residual=has_residual, io_dtype=q.dtype,
    )
    full2 = pl.BlockSpec((Bw, Pmax), lambda b, ki: (0, 0))
    full2r = pl.BlockSpec((Bw, Rmax), lambda b, ki: (0, 0))
    in_specs = [
        pl.BlockSpec((1, H, D), lambda b, ki: (b, 0, 0)),
        pl.BlockSpec((1, k_chunk, KH, D), lambda b, ki: (b, ki, 0, 0)),
        pl.BlockSpec((1, k_chunk, KH, D), lambda b, ki: (b, ki, 0, 0)),
        pl.BlockSpec((1,), lambda b, ki: (b,)),
        full2, full2, full2r,
        pl.BlockSpec((Bw, Pmax, bn), lambda b, ki: (0, 0, 0)),
        pl.BlockSpec((Bw, Rmax, bn), lambda b, ki: (0, 0, 0)),
    ]
    operands = [q[:, 0], k_cache, v_cache, pos,
                idx_i.astype(jnp.int32), idx_j.astype(jnp.int32),
                idx_r.astype(jnp.int32), kmat, w_res]
    if has_residual:
        in_specs.append(pl.BlockSpec((1, n_cols), lambda b, ki: (b, 0)))
        operands.append(residual)
    return pl.pallas_call(
        kernel,
        name="fused_attn_decode",
        grid=(B, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, n_cols), lambda b, ki: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n_cols), out_dtype),
        scratch_shapes=[
            _SCRATCH((KH, G), jnp.float32),
            _SCRATCH((KH, G), jnp.float32),
            _SCRATCH((KH, G, D), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)
