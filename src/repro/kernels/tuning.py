"""Tile-size selection for the paired / dense Pallas GEMMs.

Selection is layered, strongest signal first:

1. **Measured** — a :class:`TileCache` entry, keyed by
   ``(M, N, K, dtype, segments, pool)``.  Entries are produced by the
   :func:`autotune_blocks` search (driven by ``benchmarks/roofline.py``'s
   sweep, or any caller with a runner) and persisted to a versioned on-disk
   JSON, so a tuned machine keeps its winners across processes.  A warm
   cache hit always wins over the heuristic.
2. **Heuristic** — the VMEM-budget model below: the kernel's working set
   per program is

       xi (bm·bk) + xj (bm·bk)            [paired segment]
     + xr (bm·bk)                         [residual segment]
     + kmat / w_res (bk·bn)               [weight tile per live segment]
     + acc (bm·bn fp32) + out (bm·bn)

   all times the element size, with double-buffering on the streamed inputs
   and the activation streams / accumulator scaled ×4 when the fused 2×2
   pooling epilogue is active (window-major layout).  ``choose_blocks``
   clamps ``block_m``/``block_n`` to the actual problem dims (a LeNet conv
   GEMM of M=100, N=16 must not budget a 128×128 tile) and then picks the
   largest ``block_k`` that fits.

The heuristic is the safe fallback for any shape never measured; the
autotuner is what closes the gap the ROADMAP flagged between the static
VMEM model and real hardware behaviour.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import threading
import time
from pathlib import Path

# Usable VMEM budget per core: ~16 MB physical, keep headroom for the
# compiler's own buffers and semaphores.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024
# Lane/sublane-friendly candidates, largest first.
_BLOCK_K_CANDIDATES = (2048, 1024, 512, 256, 128)
# 2×2 fused pooling streams 4 GEMM rows per pooled output row.
_POOL_WINDOW = 4


@dataclasses.dataclass(frozen=True)
class TileConfig:
    block_m: int
    block_n: int
    block_k: int

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def kernel_vmem_bytes(
    bm: int,
    bn: int,
    bk: int,
    *,
    dtype_bytes: int = 2,
    has_pairs: bool = True,
    has_resid: bool = True,
    double_buffer: bool = True,
    pool_window: int = 1,
    residual: bool = False,
) -> int:
    """Estimated VMEM working set of one program of the paired kernel.

    ``pool_window > 1`` models the fused-pooling megakernel: every
    activation stream and the fp32 accumulator carry the window axis; the
    weight tiles and the (pooled) output tile do not.  ``residual`` models
    the fused skip-connection epilogue: one extra output-shaped ``(bm, bn)``
    operand streamed (double-buffered) alongside the activations.
    """
    x_streams = 0
    w_streams = 0
    if has_pairs:
        x_streams += 2 * bm * bk  # xi, xj tiles
        w_streams += bk * bn  # kmat tile
    if has_resid:
        x_streams += bm * bk  # xr tile
        w_streams += bk * bn  # w_res tile
    if residual:
        w_streams += bm * bn  # fused-residual tile (output-shaped stream)
    buf = 2 if double_buffer else 1
    streams = pool_window * x_streams + w_streams
    fixed = pool_window * bm * bn * 4 + bm * bn * dtype_bytes  # acc + out
    return buf * streams * dtype_bytes + fixed


def estimate_pallas_vmem_bytes(
    in_blocks,
    out_blocks,
    scratch_blocks=(),
    *,
    double_buffer: bool = True,
) -> int:
    """Static VMEM working set of one ``pallas_call`` program from its block specs.

    The generic counterpart of :func:`kernel_vmem_bytes` (which models the
    paired kernel's named streams): each argument is an iterable of
    ``(block_shape, dtype_bytes)``.  Streamed inputs are double-buffered,
    outputs and scratch are resident once.  ``None`` entries in a block shape
    (squeezed grid dims) occupy one element.  This is what the static
    analysis pass charges against :data:`VMEM_BUDGET_BYTES` before anything
    runs.
    """

    def tile(shape, nbytes) -> int:
        n = 1
        for d in shape:
            n *= int(d) if d is not None else 1
        return n * nbytes

    buf = 2 if double_buffer else 1
    total = sum(buf * tile(s, b) for s, b in in_blocks)
    total += sum(tile(s, b) for s, b in out_blocks)
    total += sum(tile(s, b) for s, b in scratch_blocks)
    return total


def _round_up_pow2(x: int, cap: int) -> int:
    p = 1
    while p < x and p < cap:
        p *= 2
    return min(p, cap)


# ---------------------------------------------------------------------------
# persisted tile cache (measured winners beat the heuristic)
# ---------------------------------------------------------------------------

CACHE_VERSION = 1
DEFAULT_CACHE_PATH = Path(".cache") / "tile_cache.json"


def cache_key(
    M: int,
    N: int,
    P: int,
    R: int,
    *,
    dtype: str = "",
    dtype_bytes: int = 2,
    pool: str = "none",
    blocks: int = 1,
    residual: bool = False,
) -> str:
    """Stable key for one kernel problem: (M, N, K, dtype, segments, pool).

    ``segments`` is the (P, R) split of the contraction — the same K tiles
    differently depending on how many lanes pair off, so it is part of the
    problem identity, not just K.  ``blocks > 1`` marks the column-blocked
    layout (per-n-block segment metadata; N/P/R are then the *per-block*
    lane counts) and ``residual`` the fused skip-connection epilogue (one
    extra output-shaped stream competing for VMEM) — each suffix is only
    appended when active so existing persisted caches keep their keys.
    """
    K = 2 * P + R
    dt = dtype or f"b{dtype_bytes}"
    suffix = f"-x{blocks}" if blocks > 1 else ""
    if residual:
        suffix += "-res"
    return f"M{M}-N{N}-K{K}-{dt}-p{P}r{R}-{pool}{suffix}"


class TileCache:
    """Versioned on-disk map from :func:`cache_key` to a measured TileConfig.

    The JSON layout is ``{"version": 1, "entries": {key: {"block_m": …,
    "block_n": …, "block_k": …, "time_s": …, "source": …}}}``.  A version
    mismatch (or unreadable file) loads as empty — stale schemas never
    poison tile selection.  ``put`` keeps an entry's provenance so the
    benchmark sweep can report where each winner came from.
    """

    def __init__(self, path: str | Path | None = None):
        self.path = Path(path) if path is not None else DEFAULT_CACHE_PATH
        self.entries: dict[str, dict] = {}
        self._load()

    def _load(self) -> None:
        try:
            raw = json.loads(self.path.read_text())
        except (OSError, ValueError):
            return
        if not isinstance(raw, dict) or raw.get("version") != CACHE_VERSION:
            return
        entries = raw.get("entries", {})
        if isinstance(entries, dict):
            self.entries = {
                k: v
                for k, v in entries.items()
                if isinstance(v, dict)
                and all(isinstance(v.get(f), int) for f in ("block_m", "block_n", "block_k"))
            }

    def get(self, key: str) -> TileConfig | None:
        e = self.entries.get(key)
        if e is None:
            return None
        return TileConfig(e["block_m"], e["block_n"], e["block_k"])

    def put(
        self,
        key: str,
        config: TileConfig,
        *,
        time_s: float | None = None,
        source: str = "measured",
    ) -> None:
        entry: dict = dict(config.as_dict(), source=source)
        if time_s is not None:
            entry["time_s"] = time_s
        self.entries[key] = entry

    def save(self) -> Path:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.path.write_text(
            json.dumps(
                {"version": CACHE_VERSION, "entries": self.entries}, indent=2
            )
        )
        return self.path

    def __len__(self) -> int:
        return len(self.entries)


_cache_state = threading.local()


def active_tile_cache() -> TileCache | None:
    return getattr(_cache_state, "cache", None)


class use_tile_cache:
    """Context manager installing a TileCache for :func:`choose_blocks`.

    Accepts a :class:`TileCache` or a path (loaded on entry).  Thread-local,
    like the GEMM/conv policies in ``kernels.ops`` — wrap the trace of a
    step (``PerfKnobs(tile_cache=…)`` does this through ``perf_context``).
    """

    def __init__(self, cache: TileCache | str | Path):
        self.cache = cache if isinstance(cache, TileCache) else TileCache(cache)
        self._prev: TileCache | None = None

    def __enter__(self) -> TileCache:
        self._prev = active_tile_cache()
        _cache_state.cache = self.cache
        return self.cache

    def __exit__(self, *exc) -> None:
        _cache_state.cache = self._prev


# ---------------------------------------------------------------------------
# heuristic chooser (cache-aware)
# ---------------------------------------------------------------------------


def choose_blocks(
    M: int,
    N: int,
    P: int,
    R: int = 0,
    *,
    dtype_bytes: int = 2,
    vmem_budget: int = VMEM_BUDGET_BYTES,
    dtype: str = "",
    pool: str = "none",
    use_cache: bool = True,
    blocks: int = 1,
    residual: bool = False,
) -> TileConfig:
    """Pick (block_m, block_n, block_k) for a paired GEMM of the given shape.

    ``P`` paired lanes + ``R`` residual lanes (pass ``P=0`` for a plain
    dense GEMM of contraction length ``R``); ``pool`` budgets the fused 2×2
    pooling epilogue's window-major streams.  For the column-blocked layout
    pass ``blocks=n_blocks`` with the *per-block* (N, P, R) — the lane tile
    is pinned to N there, so only block_m/block_k are really free.
    ``residual`` budgets the fused skip-connection stream.  A warm
    :class:`TileCache` entry (installed via :class:`use_tile_cache`) is
    returned in preference to the heuristic.
    """
    if use_cache:
        cache = active_tile_cache()
        if cache is not None:
            hit = cache.get(cache_key(
                M, N, P, R, dtype=dtype, dtype_bytes=dtype_bytes, pool=pool,
                blocks=blocks, residual=residual,
            ))
            if hit is not None:
                return hit

    K_eff = max(P, R, 1)
    pw = _POOL_WINDOW if pool != "none" else 1
    # clamp to the problem dims: padding a 100×16 conv GEMM out to 128×128
    # tiles would spend VMEM on dead lanes that a larger block_k can use
    bm = min(_round_up_pow2(M, 128), M)
    bn = min(_round_up_pow2(N, 128), N)
    has_pairs, has_resid = P > 0, R > 0

    for bk in _BLOCK_K_CANDIDATES:
        if bk > K_eff and bk != _BLOCK_K_CANDIDATES[-1]:
            continue
        bk_eff = min(bk, K_eff)
        if (
            kernel_vmem_bytes(
                bm, bn, bk_eff,
                dtype_bytes=dtype_bytes,
                has_pairs=has_pairs, has_resid=has_resid,
                pool_window=pw, residual=residual,
            )
            <= vmem_budget
        ):
            return TileConfig(bm, bn, min(bk, K_eff))

    # fall back to shrinking the output tile until the smallest k-tile fits
    bk = min(_BLOCK_K_CANDIDATES[-1], K_eff)
    while bm * bn > 8 * 8 and (
        kernel_vmem_bytes(
            bm, bn, bk,
            dtype_bytes=dtype_bytes,
            has_pairs=has_pairs, has_resid=has_resid,
            pool_window=pw, residual=residual,
        )
        > vmem_budget
    ):
        if bm >= bn:
            bm //= 2
        else:
            bn //= 2
    return TileConfig(max(bm, 8), max(bn, 8), bk)


def resolve_blocks(
    M: int,
    N: int,
    P: int,
    R: int,
    *,
    block_m: int = 0,
    block_n: int = 0,
    block_k: int = 0,
    dtype_bytes: int = 2,
    dtype: str = "",
    pool: str = "none",
    blocks: int = 1,
    residual: bool = False,
) -> TileConfig:
    """Fill any zero block size from the cache/heuristic (explicit wins).

    ``blocks`` is the column-block count of the blocked paired GEMM —
    including the experts-as-blocks layout, where it is ``E`` (or
    ``E·ceil(F/bn)``) and scales the per-launch metadata VMEM the
    heuristic budgets for."""
    if block_m and block_n and block_k:
        return TileConfig(block_m, block_n, block_k)
    auto = choose_blocks(
        M, N, P, R, dtype_bytes=dtype_bytes, dtype=dtype, pool=pool,
        blocks=blocks, residual=residual,
    )
    return TileConfig(
        block_m or auto.block_m,
        block_n or auto.block_n,
        block_k or auto.block_k,
    )


# ---------------------------------------------------------------------------
# measured autotuning (drives the cache)
# ---------------------------------------------------------------------------


def measure(fn, *, reps: int = 3, warmup: int = 1) -> float:
    """Best-of-``reps`` wall-clock of ``fn()``, blocking on jax arrays.

    On TPU this times real kernel executions (compile cost is paid in the
    warmup runs); in this container it times interpret mode — still the
    right *mechanism*, exercised end to end, with hardware-meaningful
    numbers arriving the moment the same sweep runs on a TPU.
    """
    def _block(out):
        with contextlib.suppress(ImportError, TypeError):
            import jax

            jax.block_until_ready(out)
        return out

    for _ in range(warmup):
        _block(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        _block(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def candidate_configs(
    M: int,
    N: int,
    P: int,
    R: int,
    *,
    dtype_bytes: int = 2,
    pool: str = "none",
    vmem_budget: int = VMEM_BUDGET_BYTES,
    block_ks: tuple[int, ...] = _BLOCK_K_CANDIDATES,
) -> list[TileConfig]:
    """VMEM-feasible tile candidates for one problem (heuristic pick included).

    The search space is deliberately small — clamped bm/bn plus one halved
    variant of each, crossed with the lane-friendly ``block_k`` ladder —
    because each candidate costs a measured kernel execution.
    """
    K_eff = max(P, R, 1)
    pw = _POOL_WINDOW if pool != "none" else 1
    has_pairs, has_resid = P > 0, R > 0
    bm0 = min(_round_up_pow2(M, 128), M)
    bn0 = min(_round_up_pow2(N, 128), N)
    bms = sorted({bm0, max(bm0 // 2, 8)}, reverse=True)
    bns = sorted({bn0, max(bn0 // 2, 8)}, reverse=True)
    bks = sorted({min(bk, K_eff) for bk in block_ks}, reverse=True)

    out = []
    for bm in bms:
        for bn in bns:
            for bk in bks:
                fits = kernel_vmem_bytes(
                    bm, bn, bk,
                    dtype_bytes=dtype_bytes,
                    has_pairs=has_pairs, has_resid=has_resid,
                    pool_window=pw,
                ) <= vmem_budget
                if fits:
                    out.append(TileConfig(bm, bn, bk))
    heur = choose_blocks(
        M, N, P, R, dtype_bytes=dtype_bytes, pool=pool,
        vmem_budget=vmem_budget, use_cache=False,
    )
    if heur not in out:
        out.append(heur)
    return out


def autotune_blocks(
    runner,
    M: int,
    N: int,
    P: int,
    R: int,
    *,
    dtype_bytes: int = 2,
    dtype: str = "",
    pool: str = "none",
    cache: TileCache | None = None,
    candidates: list[TileConfig] | None = None,
    reps: int = 3,
    warmup: int = 1,
) -> tuple[TileConfig, list[dict]]:
    """Measure every candidate tile config and persist the winner.

    ``runner(config)`` must execute the kernel for this problem at
    ``config`` and return its (jax) result; :func:`measure` times it.
    Returns ``(winner, records)`` where each record carries the config, its
    measured time, and its VMEM estimate (the roofline bench prints these).
    When ``cache`` is given the winner is written through and saved, so the
    next :func:`choose_blocks` on this problem takes the measured pick.
    """
    cands = candidates or candidate_configs(
        M, N, P, R, dtype_bytes=dtype_bytes, pool=pool
    )
    pw = _POOL_WINDOW if pool != "none" else 1
    records = []
    best: TileConfig | None = None
    best_t = float("inf")
    for cfg in cands:
        t = measure(lambda cfg=cfg: runner(cfg), reps=reps, warmup=warmup)
        records.append(
            {
                **cfg.as_dict(),
                "time_s": t,
                "vmem_bytes": kernel_vmem_bytes(
                    cfg.block_m, cfg.block_n,
                    min(cfg.block_k, max(P, R, 1)),
                    dtype_bytes=dtype_bytes,
                    has_pairs=P > 0, has_resid=R > 0,
                    pool_window=pw,
                ),
            }
        )
        if t < best_t:
            best, best_t = cfg, t
    assert best is not None, "no feasible tile candidates"
    if cache is not None:
        cache.put(
            cache_key(M, N, P, R, dtype=dtype, dtype_bytes=dtype_bytes, pool=pool),
            best,
            time_s=best_t,
        )
        cache.save()
    return best, records
