"""Heuristic tile-size chooser for the paired / dense Pallas GEMMs.

The kernel's VMEM working set per program is

    xi (bm·bk) + xj (bm·bk)            [paired segment]
  + xr (bm·bk)                         [residual segment]
  + kmat / w_res (bk·bn)               [weight tile per live segment]
  + acc (bm·bn fp32) + out (bm·bn)

all times the element size, with double-buffering on the streamed inputs
(the Pallas pipeline prefetches the next k-tile while the current one
computes).  ``choose_blocks`` picks the largest ``block_k`` that keeps that
under a conservative VMEM budget at (128, 128) output tiles — the MXU-native
tile — shrinking ``block_m``/``block_n`` only for small problems.

This is a *heuristic*, not an autotuner: it exists so that callers (serving
knobs, benchmarks, tests) get a safe default for any (M, N, K) without
hand-picking; the benchmark sweep in ``benchmarks/roofline.py`` is the tool
for measuring where the heuristic leaves performance on the table.
"""
from __future__ import annotations

import dataclasses

# Usable VMEM budget per core: ~16 MB physical, keep headroom for the
# compiler's own buffers and semaphores.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024
# Lane/sublane-friendly candidates, largest first.
_BLOCK_K_CANDIDATES = (2048, 1024, 512, 256, 128)


@dataclasses.dataclass(frozen=True)
class TileConfig:
    block_m: int
    block_n: int
    block_k: int

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def kernel_vmem_bytes(
    bm: int,
    bn: int,
    bk: int,
    *,
    dtype_bytes: int = 2,
    has_pairs: bool = True,
    has_resid: bool = True,
    double_buffer: bool = True,
) -> int:
    """Estimated VMEM working set of one program of the paired kernel."""
    streams = 0
    if has_pairs:
        streams += 2 * bm * bk + bk * bn  # xi, xj, kmat tiles
    if has_resid:
        streams += bm * bk + bk * bn  # xr, w_res tiles
    buf = 2 if double_buffer else 1
    fixed = bm * bn * 4 + bm * bn * dtype_bytes  # fp32 acc + out tile
    return buf * streams * dtype_bytes + fixed


def _round_up_pow2(x: int, cap: int) -> int:
    p = 1
    while p < x and p < cap:
        p *= 2
    return min(p, cap)


def choose_blocks(
    M: int,
    N: int,
    P: int,
    R: int = 0,
    *,
    dtype_bytes: int = 2,
    vmem_budget: int = VMEM_BUDGET_BYTES,
) -> TileConfig:
    """Pick (block_m, block_n, block_k) for a paired GEMM of the given shape.

    ``P`` paired lanes + ``R`` residual lanes (pass ``P=0`` for a plain
    dense GEMM of contraction length ``R``).
    """
    K_eff = max(P, R, 1)
    bm = _round_up_pow2(M, 128)
    bn = _round_up_pow2(N, 128)
    has_pairs, has_resid = P > 0, R > 0

    for bk in _BLOCK_K_CANDIDATES:
        if bk > K_eff and bk != _BLOCK_K_CANDIDATES[-1]:
            continue
        bk_eff = min(bk, K_eff)
        if (
            kernel_vmem_bytes(
                bm, bn, bk_eff,
                dtype_bytes=dtype_bytes,
                has_pairs=has_pairs, has_resid=has_resid,
            )
            <= vmem_budget
        ):
            return TileConfig(bm, bn, min(bk, K_eff))

    # fall back to shrinking the output tile until the smallest k-tile fits
    bk = min(_BLOCK_K_CANDIDATES[-1], K_eff)
    while bm * bn > 8 * 8 and (
        kernel_vmem_bytes(
            bm, bn, bk,
            dtype_bytes=dtype_bytes,
            has_pairs=has_pairs, has_resid=has_resid,
        )
        > vmem_budget
    ):
        if bm >= bn:
            bm //= 2
        else:
            bn //= 2
    return TileConfig(max(bm, 8), max(bn, 8), bk)


def resolve_blocks(
    M: int,
    N: int,
    P: int,
    R: int,
    *,
    block_m: int = 0,
    block_n: int = 0,
    block_k: int = 0,
    dtype_bytes: int = 2,
) -> TileConfig:
    """Fill any zero block size from the heuristic (explicit values win)."""
    if block_m and block_n and block_k:
        return TileConfig(block_m, block_n, block_k)
    auto = choose_blocks(M, N, P, R, dtype_bytes=dtype_bytes)
    return TileConfig(
        block_m or auto.block_m,
        block_n or auto.block_n,
        block_k or auto.block_k,
    )
