"""Pallas TPU kernel: fused flash-attention forward (GQA, causal).

The roofline analysis (EXPERIMENTS.md §Roofline) shows every non-SSM cell
memory-bound, dominated by the flash score/probability blocks round-tripping
HBM — XLA cannot fuse across the online-softmax loop, a Pallas kernel is the
mechanism that keeps them in VMEM.  This kernel is the TPU-target
implementation; it is validated in interpret mode in this container and its
VMEM-resident traffic model backs the `attn_fused` accounting in the
dry-run (§Perf iteration 3).

Tiling: grid (B, H, nq, nk) with the KV dimension innermost ("arbitrary" —
sequential), carrying (m, l, acc) in VMEM scratch across the KV iterations of
one q-block; q/k/v/o blocks stream per grid step.  Causal skipping happens
in-kernel via ``pl.when`` (a fully-masked block never touches the MXU).
GQA is handled in the k/v index maps (query head h reads kv head h·KH//H).

Ragged sequence lengths are handled internally: inputs are zero-padded up to
the chunk grid, padded *keys* are masked to -inf in-kernel (mirroring
``layers.flash_attention``'s ``pos_k < Sk`` lane mask), and the output is
sliced back to the caller's (B, Sq, H, D).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU backend scratch spaces
    from jax.experimental.pallas import tpu as pltpu

    _SCRATCH = pltpu.VMEM
except Exception:  # pragma: no cover - exercised via monkeypatch in tests
    # Backend-neutral fallback: a MemoryRef in the ANY space is a callable
    # with the same (shape, dtype) signature as pltpu.VMEM and is accepted
    # by ``scratch_shapes`` in interpret mode, so the kernels keep working
    # when the TPU plugin namespace is absent.
    _SCRATCH = functools.partial(pl.MemoryRef, memory_space=pl.MemorySpace.ANY)


def _pad_axis(arr: jax.Array, axis: int, size: int) -> jax.Array:
    if arr.shape[axis] == size:
        return arr
    pads = [(0, 0)] * arr.ndim
    pads[axis] = (0, size - arr.shape[axis])
    return jnp.pad(arr, pads)


def _flash_fwd_kernel(
    q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
    *, scale: float, causal: bool, q_chunk: int, k_chunk: int, nk: int,
    valid_k: int
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal bound: the last kv block this q block attends to is skipped
    # statically via pl.when below
    @pl.when((not causal) or (ki * k_chunk <= (qi + 1) * q_chunk - 1))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (q_chunk, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (k_chunk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (q_chunk, k_chunk)
        ragged = valid_k % k_chunk != 0
        if causal or ragged:
            pos_k = ki * k_chunk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            ok = jnp.full(s.shape, True)
            if causal:
                pos_q = qi * q_chunk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
                ok &= pos_k <= pos_q
            if ragged:  # zero-padded key lanes never score
                ok &= pos_k < valid_k
            s = jnp.where(ok, s, -jnp.inf)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[:, None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_ref[...] = l_ref[...] * corr + p.sum(-1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Sk, KH, D)
    v: jax.Array,
    *,
    causal: bool = True,
    q_chunk: int = 512,
    k_chunk: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Fused flash-attention forward. Returns (B, Sq, H, D) in q.dtype."""
    B, Sq, H, D = q.shape
    _, Sk, KH, _ = k.shape
    if H % KH != 0:
        raise ValueError(
            f"GQA requires query heads to divide evenly over kv heads: "
            f"H={H}, KH={KH}"
        )
    G = H // KH
    scale = 1.0 / math.sqrt(D)
    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    # Ragged lengths: pad up to the chunk grid with zero lanes.  Padded keys
    # are masked to -inf in-kernel (valid_k); padded query rows compute
    # finite garbage that the final slice drops.
    nq, nk = -(-Sq // q_chunk), -(-Sk // k_chunk)
    Sq_p, Sk_p = nq * q_chunk, nk * k_chunk
    q = _pad_axis(q, 1, Sq_p)
    k = _pad_axis(k, 1, Sk_p)
    v = _pad_axis(v, 1, Sk_p)

    # layout: (B, H, S, D) so blocks are (1, 1, chunk, D)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_fwd_kernel,
        scale=scale, causal=causal, q_chunk=q_chunk, k_chunk=k_chunk, nk=nk,
        valid_k=Sk,
    )
    scratch = [
        _SCRATCH((q_chunk,), jnp.float32),
        _SCRATCH((q_chunk,), jnp.float32),
        _SCRATCH((q_chunk, D), jnp.float32),
    ]
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, q_chunk, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, k_chunk, D), lambda b, h, qi, ki, _G=G: (b, h // _G, ki, 0)),
            pl.BlockSpec((1, 1, k_chunk, D), lambda b, h, qi, ki, _G=G: (b, h // _G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_chunk, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq_p, D), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)[:, :Sq]
