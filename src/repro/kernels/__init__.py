"""Pallas TPU kernels for the perf-critical compute of the paper.

paired_matmul — the paper's "modified convolution unit" (Fig. 5) adapted to
the TPU: the subtract-then-MAC dataflow as a fused VMEM-tiled GEMM with a
reduced contraction dimension.  ops.py carries the jit'd public wrappers
(kernel on TPU, interpret mode on CPU); ref.py the pure-jnp oracles.
"""

from repro.kernels.ops import paired_matmul, dense_matmul  # noqa: F401
