"""Pallas TPU kernels for the perf-critical compute of the paper.

paired_matmul — the paper's "modified convolution unit" (Fig. 5) adapted to
the TPU: the subtract-then-MAC dataflow as a K-tiled, epilogue-fused GEMM
with a reduced contraction dimension (grid (m, n, k), fp32 VMEM
accumulator — see paired_matmul.py "Kernel tiling").  ops.py carries the
jit'd public wrappers (kernel on TPU, interpret mode on CPU) plus the
``pallas_gemm`` policy that routes model-layer GEMMs through the kernels;
tuning.py the heuristic tile chooser; ref.py the pure-jnp oracles.
"""

from repro.kernels.ops import (  # noqa: F401
    conv_context,
    dense_matmul,
    gemm_context,
    paired_matmul,
    pallas_conv,
    pallas_gemm,
    perf_context,
)
from repro.kernels.im2col import col2im, im2col  # noqa: F401
from repro.kernels.paired_conv import conv_im2col, paired_conv  # noqa: F401
from repro.kernels.tuning import TileConfig, choose_blocks  # noqa: F401
