"""Convolution through the paired Pallas GEMM — the paper's headline path.

LeNet-5's 405 600 multiplies live in its conv layers (Table I), so this is
where the subtractor replacement has to execute, not just be modeled.  The
lowering chain is::

    conv (NHWC, HWIO, any stride / VALID / SAME / explicit padding)
      → im2col patches (kernels/im2col.py): (N, OH, OW, K), K = kh·kw·cin
      → permute patch lanes to the [I | J | residual] layout of a
        StructuredPairing built offline on W.reshape(K, cout)
      → paired_matmul (kernels/paired_matmul.py): the K-tiled grid-(m, n, k)
        kernel subtracts paired patch lanes on the VPU and contracts over
        K − P lanes on the MXU, with the conv bias + activation fused into
        the epilogue.

With ``pool="max2"``/``"avg2"`` the lowering becomes the conv→pool
**megakernel**: the patch rows are re-arranged *window-major* — the four
GEMM rows of one 2×2 pooling window become the leading axis of a
``(4, N·⌊OH/2⌋·⌊OW/2⌋, K)`` operand — so the kernel reduces the window in
VMEM and writes only the pooled map to HBM.  conv→pool stops round-tripping
the full activation map (the row re-arrangement is a transpose of patches
XLA fuses into the extraction; odd trailing rows/cols are trimmed, matching
``reduce_window`` VALID semantics).

The pairing artifact (core/transform.py: PairedLayer) carries only the
*index structure* (which lanes pair).  The pair magnitudes are recomputed
from the live weights inside the traced function —
``Kmat = (W[I] − W[J]) / 2`` — so the same artifact serves inference and
``jax.grad`` (weights stay differentiable; only the pairing structure is
frozen, exactly like the paper's one-time preprocessing).

Artifacts may carry either pairing mode: a ``StructuredPairing`` (one lane
permutation shared by all output channels) routes to ``ops.paired_matmul``;
a ``BlockedPairing`` (one pairing per group of ``block_n`` output channels —
down to the paper's per-column pairing at ``block_n = 1``) routes to the
column-blocked kernel: the patch lanes are gathered once through the packed
``(n_blocks, K')`` index matrix and the per-block weight segments are
recomputed live under the same frozen structure
(``_blocked_live_segments``).  Epilogue, pooling megakernel, and the
custom-VJP split are identical on both routes.

Differentiation: ``paired_conv`` is a ``jax.custom_vjp`` — forward through
the Pallas kernel, backward as the VJP of the *folded dense equivalent*
(im2col einsum against W_approx, plus the same window reduction), which XLA
schedules as the standard two conv-backward GEMMs.  Same split as
``kernels.ops.fused_dense``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pairing import BlockedPairing, StructuredPairing
from repro.kernels import ops
from repro.kernels.im2col import Padding, Stride, im2col
from repro.kernels.paired_matmul import ACTIVATIONS, POOL_WINDOW, POOLS


def pool2_reference(y: jax.Array, pool: str) -> jax.Array:
    """2×2/stride-2 window reduction on an NHWC map, VALID semantics.

    The pure-jnp mirror of the kernel's fused pooling epilogue (odd trailing
    rows/cols trimmed, max or mean over each window) — and of
    ``lax.reduce_window`` with window (1,2,2,1), stride (1,2,2,1), VALID.
    """
    if pool == "none" or pool is None:
        return y
    assert pool in POOLS, f"unknown pool {pool!r}"
    n, oh, ow, c = y.shape
    poh, pow_ = oh // 2, ow // 2
    assert poh > 0 and pow_ > 0, f"map {(oh, ow)} too small for a 2x2 pool"
    yw = y[:, : 2 * poh, : 2 * pow_, :].reshape(n, poh, 2, pow_, 2, c)
    if pool == "max2":
        return yw.max(axis=(2, 4))
    return yw.mean(axis=(2, 4))


def _window_major(patches: jax.Array) -> tuple[jax.Array, tuple[int, int, int]]:
    """(N, OH, OW, K) patches → window-major (4, N·POH·POW, K) GEMM rows.

    Axis 0 enumerates the 2×2 window elements (dh-major) of pooled output
    row ``m = ((n·POH) + poh)·POW + pow``; odd trailing rows/cols are
    trimmed (VALID pooling).  Pure transpose — XLA fuses it into the patch
    extraction, nothing extra is materialised.
    """
    n, oh, ow, K = patches.shape
    poh, pow_ = oh // 2, ow // 2
    pw = patches[:, : 2 * poh, : 2 * pow_, :].reshape(n, poh, 2, pow_, 2, K)
    pw = pw.transpose(2, 4, 0, 1, 3, 5)  # (2, 2, n, poh, pow, K)
    return pw.reshape(POOL_WINDOW, n * poh * pow_, K), (n, poh, pow_)


def conv_im2col(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array | None = None,
    *,
    activation: str = "none",
    stride: Stride = 1,
    padding: Padding = "VALID",
    pool: str = "none",
) -> jax.Array:
    """Reference conv-as-GEMM: im2col patches against the flattened kernel.

    Pure jnp (differentiable as-is); the XLA-scheduled baseline for the
    Pallas path and the ``conv_impl="im2col"`` policy choice.  ``pool``
    applies the 2×2 window reduction after the activation (same epilogue
    order as the megakernel).
    """
    kh, kw, cin, cout = w.shape
    patches = im2col(x, kh, kw, stride=stride, padding=padding)
    y = jnp.einsum("nhwk,kf->nhwf", patches, w.reshape(kh * kw * cin, cout))
    if bias is not None:
        y = y + bias
    y = ACTIVATIONS[activation](y)
    return pool2_reference(y, pool)


def _pairing_of(artifact) -> StructuredPairing | BlockedPairing:
    """Accept a (Structured|Blocked)Pairing or anything carrying one
    (PairedLayer)."""
    return artifact.pairing if hasattr(artifact, "pairing") else artifact


def _live_segments(wm: jax.Array, sp: StructuredPairing):
    """Kmat / W_res recomputed from live weights under the frozen structure."""
    kmat = (wm[sp.I] - wm[sp.J]) * 0.5
    w_res = wm[sp.resid]
    return kmat, w_res


def _block_major_weights(wm: jax.Array, bp: BlockedPairing) -> jax.Array:
    """(K, N) live weights → block-major (n_blocks, K, bn), zero-padded cols."""
    K, N = bp.shape
    bn = bp.block_n
    pad = bp.n_blocks * bn - N
    wm_p = jnp.pad(wm, ((0, 0), (0, pad))) if pad else wm
    return wm_p.reshape(K, bp.n_blocks, bn).transpose(1, 0, 2)


def _blocked_live_segments(wm: jax.Array, bp: BlockedPairing, idx: dict):
    """Packed per-block Kmat / W_res recomputed from live weights.

    The blocked analogue of :func:`_live_segments`: ``idx`` is the (static,
    numpy) metadata from ``BlockedPairing.index_arrays()``; the gathers are
    ``take_along_axis`` over the block-major weight view, and the pad masks
    zero the padded lanes so they contract against nothing.  Fully traced —
    differentiable and valid after weight updates, like the structured path.
    """
    wm_t = _block_major_weights(wm, bp)  # (B, K, bn)
    take = lambda ind: jnp.take_along_axis(wm_t, ind[:, :, None], axis=1)
    I_m, J_m = jnp.asarray(idx["I"]), jnp.asarray(idx["J"])
    R_m = jnp.asarray(idx["resid"])
    pmask = jnp.asarray(idx["pair_mask"], wm.dtype)[:, :, None]
    rmask = jnp.asarray(idx["resid_mask"], wm.dtype)[:, :, None]
    kmat = (take(I_m) - take(J_m)) * 0.5 * pmask  # (B, Pmax, bn)
    w_res = take(R_m) * rmask  # (B, Rmax, bn)
    return kmat, w_res


def folded_conv_weight(w: jax.Array, pairing) -> jax.Array:
    """Dense W_approx (kh, kw, cin, cout) the paired kernel is equivalent to.

    The live-weight analogue of ``StructuredPairing.fold()`` /
    ``BlockedPairing.fold()``: paired rows snap to ±Kmat, residual rows pass
    through (per block, for a BlockedPairing).  Feeding this to a plain conv
    reproduces the subtractor dataflow bit-for-bit (the test oracle, and the
    backward-pass function).
    """
    sp = _pairing_of(pairing)
    kh, kw, cin, cout = w.shape
    wm = w.reshape(kh * kw * cin, cout)
    if isinstance(sp, BlockedPairing):
        idx = sp.index_arrays()
        kmat, w_res = _blocked_live_segments(wm, sp, idx)
        B, K = sp.n_blocks, sp.shape[0]
        bar = jnp.arange(B)[:, None]
        # scatter-add: padded entries all point at row 0 but add exact zeros
        # (the masks in the packed segments), so they never clobber real rows
        wf_t = (
            jnp.zeros((B, K, sp.block_n), wm.dtype)
            .at[bar, jnp.asarray(idx["I"])].add(kmat)
            .at[bar, jnp.asarray(idx["J"])].add(-kmat)
            .at[bar, jnp.asarray(idx["resid"])].add(w_res)
        )
        wf = wf_t.transpose(1, 0, 2).reshape(K, B * sp.block_n)[:, :cout]
        return wf.reshape(w.shape)
    kmat, w_res = _live_segments(wm, sp)
    wf = (
        jnp.zeros_like(wm)
        .at[sp.I].set(kmat)
        .at[sp.J].set(-kmat)
        .at[sp.resid].set(w_res)
    )
    return wf.reshape(w.shape)


def paired_conv_ref(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array | None,
    pairing,
    *,
    activation: str = "none",
    stride: Stride = 1,
    padding: Padding = "VALID",
    pool: str = "none",
) -> jax.Array:
    """Pure-jnp oracle: folded dense conv (+pool) == the paired kernel's math."""
    return conv_im2col(
        x, folded_conv_weight(w, pairing), bias,
        activation=activation, stride=stride, padding=padding, pool=pool,
    )


def paired_conv(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array | None = None,
    *,
    pairing,
    activation: str = "none",
    stride: Stride = 1,
    padding: Padding = "VALID",
    pool: str = "none",
    block_m: int = 0,
    block_n: int = 0,
    block_k: int = 0,
    interpret: bool | None = None,
) -> jax.Array:
    """Conv through the paired Pallas kernel. x: (N, H, W, cin) → (N, OH, OW, cout).

    ``pairing`` is the offline artifact (StructuredPairing, BlockedPairing,
    or a PairedLayer carrying either) for ``w.reshape(K, cout)``;
    ``block_* = 0`` defers to the tile cache / tuning heuristic.
    ``stride``/``padding`` follow :func:`repro.kernels.im2col.im2col`.
    ``pool="max2"``/``"avg2"`` fuses the 2×2 window reduction into the
    kernel epilogue (one HBM writeback for conv→pool; output is the pooled
    (N, ⌊OH/2⌋, ⌊OW/2⌋, cout) map).  A BlockedPairing routes to the
    column-blocked kernel — per-block lane metadata, same epilogues.
    Differentiable: Pallas forward, folded-XLA backward.
    """
    sp = _pairing_of(pairing)
    kh, kw, cin, cout = w.shape
    K = kh * kw * cin
    assert sp.shape == (K, cout), (
        f"pairing built for {sp.shape}, conv kernel flattens to {(K, cout)}"
    )
    assert pool == "none" or pool in POOLS, f"unknown pool {pool!r}"
    blocked = isinstance(sp, BlockedPairing)
    idx = sp.index_arrays() if blocked else None
    # static gather indices: [I | J | residual] lanes — one row per block in
    # the blocked layout, a single permutation otherwise
    perm = np.asarray(idx["perm"] if blocked else sp.perm())

    def fwd_kernel(x, w, bias):
        patches = im2col(x, kh, kw, stride=stride, padding=padding)
        wm = w.reshape(K, cout)
        kmat, w_res = _blocked_live_segments(wm, sp, idx) if blocked else _live_segments(wm, sp)
        kmat, w_res = kmat.astype(x.dtype), w_res.astype(x.dtype)
        if pool != "none":
            xw, (n, poh, pow_) = _window_major(patches)
            if blocked:
                xg = jnp.moveaxis(xw[..., perm], 2, 0)  # (B, 4, M, K')
                y = ops.paired_matmul_blocked(
                    xg, kmat, w_res, bias, n_cols=cout,
                    activation=activation, pool=pool,
                    block_m=block_m, block_k=block_k, interpret=interpret,
                )
            else:
                y = ops.paired_matmul(
                    xw[..., perm], kmat, w_res, bias,
                    activation=activation, pool=pool,
                    block_m=block_m, block_n=block_n, block_k=block_k,
                    interpret=interpret,
                )
            return y.reshape(n, poh, pow_, cout)
        if blocked:
            xp = patches.reshape(-1, K)
            xg = jnp.moveaxis(xp[:, perm], 1, 0)  # (B, M, K')
            y = ops.paired_matmul_blocked(
                xg, kmat, w_res, bias, n_cols=cout,
                activation=activation,
                block_m=block_m, block_k=block_k, interpret=interpret,
            )
            return y.reshape(*patches.shape[:-1], cout)
        return ops.paired_matmul(
            patches[..., perm], kmat, w_res, bias,
            activation=activation,
            block_m=block_m, block_n=block_n, block_k=block_k,
            interpret=interpret,
        )

    def ref(x, w, bias):
        return paired_conv_ref(
            x, w, bias, sp,
            activation=activation, stride=stride, padding=padding, pool=pool,
        )

    @jax.custom_vjp
    def f(x, w, bias):
        return fwd_kernel(x, w, bias)

    def f_fwd(x, w, bias):
        return fwd_kernel(x, w, bias), (x, w, bias)

    def f_bwd(res, dy):
        xr, wr, br = res
        _, vjp = jax.vjp(ref, xr, wr, br)
        return vjp(dy)

    f.defvjp(f_fwd, f_bwd)
    return f(x, w, bias)
