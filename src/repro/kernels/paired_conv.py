"""Convolution through the paired Pallas GEMM — the paper's headline path.

LeNet-5's 405 600 multiplies live in its conv layers (Table I), so this is
where the subtractor replacement has to execute, not just be modeled.  The
lowering chain is::

    conv (NHWC, HWIO, VALID, stride 1)
      → im2col patches (kernels/im2col.py): (N, OH, OW, K), K = kh·kw·cin
      → permute patch lanes to the [I | J | residual] layout of a
        StructuredPairing built offline on W.reshape(K, cout)
      → paired_matmul (kernels/paired_matmul.py): the K-tiled grid-(m, n, k)
        kernel subtracts paired patch lanes on the VPU and contracts over
        K − P lanes on the MXU, with the conv bias + activation fused into
        the epilogue.

The pairing artifact (core/transform.py: PairedLayer) carries only the
*index structure* (which lanes pair).  The pair magnitudes are recomputed
from the live weights inside the traced function —
``Kmat = (W[I] − W[J]) / 2`` — so the same artifact serves inference and
``jax.grad`` (weights stay differentiable; only the pairing structure is
frozen, exactly like the paper's one-time preprocessing).

Differentiation: ``paired_conv`` is a ``jax.custom_vjp`` — forward through
the Pallas kernel, backward as the VJP of the *folded dense equivalent*
(im2col einsum against W_approx), which XLA schedules as the standard two
conv-backward GEMMs.  Same split as ``kernels.ops.fused_dense``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pairing import StructuredPairing
from repro.kernels import ops
from repro.kernels.im2col import im2col
from repro.kernels.paired_matmul import ACTIVATIONS

def conv_im2col(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array | None = None,
    *,
    activation: str = "none",
) -> jax.Array:
    """Reference conv-as-GEMM: im2col patches against the flattened kernel.

    Pure jnp (differentiable as-is); the XLA-scheduled baseline for the
    Pallas path and the ``conv_impl="im2col"`` policy choice.
    """
    kh, kw, cin, cout = w.shape
    patches = im2col(x, kh, kw)
    y = jnp.einsum("nhwk,kf->nhwf", patches, w.reshape(kh * kw * cin, cout))
    if bias is not None:
        y = y + bias
    return ACTIVATIONS[activation](y)


def _pairing_of(artifact) -> StructuredPairing:
    """Accept a StructuredPairing or anything carrying one (PairedLayer)."""
    return artifact.pairing if hasattr(artifact, "pairing") else artifact


def _live_segments(wm: jax.Array, sp: StructuredPairing):
    """Kmat / W_res recomputed from live weights under the frozen structure."""
    kmat = (wm[sp.I] - wm[sp.J]) * 0.5
    w_res = wm[sp.resid]
    return kmat, w_res


def folded_conv_weight(w: jax.Array, pairing) -> jax.Array:
    """Dense W_approx (kh, kw, cin, cout) the paired kernel is equivalent to.

    The live-weight analogue of ``StructuredPairing.fold()``: paired rows
    snap to ±Kmat, residual rows pass through.  Feeding this to a plain conv
    reproduces the subtractor dataflow bit-for-bit (the test oracle, and the
    backward-pass function).
    """
    sp = _pairing_of(pairing)
    kh, kw, cin, cout = w.shape
    wm = w.reshape(kh * kw * cin, cout)
    kmat, w_res = _live_segments(wm, sp)
    wf = (
        jnp.zeros_like(wm)
        .at[sp.I].set(kmat)
        .at[sp.J].set(-kmat)
        .at[sp.resid].set(w_res)
    )
    return wf.reshape(w.shape)


def paired_conv_ref(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array | None,
    pairing,
    *,
    activation: str = "none",
) -> jax.Array:
    """Pure-jnp oracle: folded dense conv == the paired kernel's math."""
    return conv_im2col(
        x, folded_conv_weight(w, pairing), bias, activation=activation
    )


def paired_conv(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array | None = None,
    *,
    pairing,
    activation: str = "none",
    block_m: int = 0,
    block_n: int = 0,
    block_k: int = 0,
    interpret: bool | None = None,
) -> jax.Array:
    """Conv through the paired Pallas kernel. x: (N, H, W, cin) → (N, OH, OW, cout).

    ``pairing`` is the offline artifact (StructuredPairing or PairedLayer)
    for ``w.reshape(K, cout)``; ``block_* = 0`` defers to the tuning
    heuristic.  Differentiable: Pallas forward, folded-XLA backward.
    """
    sp = _pairing_of(pairing)
    kh, kw, cin, cout = w.shape
    K = kh * kw * cin
    assert sp.shape == (K, cout), (
        f"pairing built for {sp.shape}, conv kernel flattens to {(K, cout)}"
    )
    perm = np.asarray(sp.perm())

    def fwd_kernel(x, w, bias):
        patches = im2col(x, kh, kw)
        xp = patches[..., perm]  # static gather → [I | J | residual] lanes
        wm = w.reshape(K, cout)
        kmat, w_res = _live_segments(wm, sp)
        return ops.paired_matmul(
            xp, kmat.astype(x.dtype), w_res.astype(x.dtype), bias,
            activation=activation,
            block_m=block_m, block_n=block_n, block_k=block_k,
            interpret=interpret,
        )

    def ref(x, w, bias):
        return paired_conv_ref(x, w, bias, sp, activation=activation)

    @jax.custom_vjp
    def f(x, w, bias):
        return fwd_kernel(x, w, bias)

    def f_fwd(x, w, bias):
        return fwd_kernel(x, w, bias), (x, w, bias)

    def f_bwd(res, dy):
        xr, wr, br = res
        _, vjp = jax.vjp(ref, xr, wr, br)
        return vjp(dy)

    f.defvjp(f_fwd, f_bwd)
    return f(x, w, bias)
