"""Pallas TPU kernel: the paper's subtractor MAC array, as a fused GEMM.

The ASIC datapath of the paper evaluates a combined weight pair (+k, -k) as
``k · (I₁ − I₂)`` — one subtractor replaces a multiplier+adder (eq. 1).  On a
TPU the MXU charges the same for every multiply-accumulate lane, so the
*structural* translation of the saving is a **shorter contraction**: with
``P`` shared pairs and ``R`` residual channels (``K = 2P + R``),

    y = (x[:, :P] − x[:, P:2P]) @ Kmat  +  x[:, 2P:] @ W_res

contracts over ``P + R = K − P`` lanes instead of ``K``.  The subtraction is
VPU work fused into the same kernel — it never round-trips HBM.  The input
is expected *pre-permuted* to the ``[I | J | residual]`` layout
(``StructuredPairing.perm()``); the permutation is free at deploy time
because it folds into the previous layer's output projection.

Kernel tiling
=============
The kernel runs on a three-dimensional grid ``(M/bm, N/bn, nk)`` with the
contraction dimension innermost, so all k-steps of one output tile execute
back-to-back on the same core:

* each program loads a ``(bm, bk)`` activation tile and a ``(bk, bn)``
  weight tile into VMEM — never a full-K row block.  That is what lets the
  same kernel serve LeNet (K = 400) and the production configs the ROADMAP
  names (mistral-large ``d_model`` 12288, d_ff 28672) without blowing the
  ~16 MB VMEM budget;
* partial products accumulate into a ``(bm, bn)`` **fp32 VMEM scratch**
  accumulator, zero-initialised at ``k == 0`` and flushed to the output ref
  at the last k-step (``jax.experimental.pallas`` revisits the same output
  block for every k, so the flush races nothing);
* the contraction axis is *segmented*: the first ``nkp`` k-steps walk the
  paired lanes (subtract-then-MAC over ``Kmat``), the remaining ``nkr``
  steps walk the residual lanes (plain MAC over ``W_res``).  Segment
  boundaries are static, so ``pl.when`` predication costs one scalar compare
  per step; block index maps clamp into their own segment.  ``P == 0`` or
  ``R == 0`` simply drop a segment — the three historical ``pallas_call``
  branches are now one parameterized builder (``_build_paired_call``);
* the **epilogue is fused**: bias add and an optional activation
  (relu / gelu / silu / tanh) happen on the fp32 accumulator right before
  the flush, so downstream layers stop paying an extra HBM round-trip for
  ``y + b`` / ``act(y)``.

Per-segment k-tiles are padded with zero lanes up to a ``bk`` multiple;
zero activation lanes × zero weight rows contribute nothing, so no masking
is needed in the accumulation.

Residual-add epilogue
=====================
``residual=`` streams an ``(M, N)`` operand (output-space, e.g. the skip
branch of a Transformer sublayer) into the flush: it is added on the fp32
accumulator *after* bias + activation (and after the pooled window
reduction, when pooling is fused), immediately before the single HBM
writeback.  A decoder's ``h + attn_out(x)`` / ``h + mlp(x)`` therefore
stops being a standalone XLA add over a full hidden-state tensor — the
skip connection rides the same kernel writeback.  The residual may arrive
in a different dtype than the activations (bf16 skip against an fp32
accumulator is the common serving case); it is promoted to fp32 for the
add and the result is cast once to the output dtype.

Fused pooling epilogue (the conv→pool→activation megakernel)
============================================================
With ``pool="max2"`` / ``"avg2"`` the kernel additionally reduces a 2×2
spatial window *inside VMEM* before its single HBM writeback — the serving
path for conv→pool stops round-tripping the full activation map through
HBM.  The caller pre-arranges the GEMM rows **window-major**: the activation
operand is ``(4, M, K)`` where axis 0 enumerates the 2×2 window elements of
pooled output row ``m`` (see ``paired_conv``'s layout transform).  Each
program then accumulates a ``(4, bm, bn)`` fp32 scratch (four 2-D MXU dots
per k-step — the window axis is a leading, untiled dimension, which Mosaic
handles without sublane reshapes), applies bias → activation on the full
window, reduces over the window axis, and flushes only the ``(bm, bn)``
*pooled* tile.  The HBM writeback shrinks 4×, and the separate pooling op
disappears from the schedule.

Column-blocked layout (per-n-block pairings)
============================================
The paper's per-column pairing gives every output channel its own lane
permutation; the structured layout above shares one across all N.  The
*column-blocked* mode interpolates: ``core.pairing.pair_rows_blocked``
computes an independent shared-row pairing per group of ``block_n`` output
channels, and :func:`paired_matmul_blocked_pallas` executes it by giving
**each grid n-step its own segment metadata**.  Operands arrive block-major:

* activations are pre-gathered through the packed ``(n_blocks, K')`` index
  matrix (``BlockedPairing.index_arrays()["perm"]``, one XLA gather) into
  ``(n_blocks, M, K')`` — block ``b``'s rows permuted to *its* ``[I | J |
  resid]`` order, every block padded to the common ``(Pmax, Rmax)`` split
  (``K' = 2·Pmax + Rmax``);
* weights are packed ``(n_blocks, Pmax, bn)`` / ``(n_blocks, Rmax, bn)``
  with zero rows on the padding, so padded lanes contract against zeros and
  need no masking — exactly the zero-lane trick the k-tile padding already
  uses;
* the grid becomes ``(M/bm, n_blocks, nk)`` and every operand spec carries a
  leading block axis indexed by the n-step, so the k-segmentation, fp32
  accumulator, fused epilogue and pooling epilogue all run unchanged *per
  block* — the kernel body only swaps its tile accessors.

The gather must happen outside the kernel: a k-tiled stream can only DMA
contiguous lane blocks, and a block's paired lanes are scattered across the
full K — pre-gathering (which XLA fuses with the im2col patch extraction)
is what keeps the contraction K-tiled.  The cost is the activation
replication factor ``n_blocks`` (the paper's per-column dataflow at
``block_n = 1`` fundamentally reads each input once per output channel's
subtract schedule); ``block_n`` is the knob trading that bandwidth against
pairing rate.

``interpret=True`` executes the same kernel body with jnp semantics on CPU —
that is how the kernel is validated in this container (TPU is the target).
"""
from __future__ import annotations

from collections.abc import Callable

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Epilogue activations the kernel can fuse. "none" is the identity.
ACTIVATIONS: dict[str, Callable] = {
    "none": lambda x: x,
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "swish": jax.nn.silu,
    "tanh": jnp.tanh,
}

# Fused 2×2 window reductions over the leading (window) axis of the fp32
# accumulator. "none" means no pooling (2-D kernel layout).
POOLS: dict[str, Callable] = {
    "max2": lambda a: a.max(axis=0),
    "avg2": lambda a: a.mean(axis=0),
}
POOL_WINDOW = 4  # 2×2 — the only window geometry LeNet (and the paper) uses


def _apply_epilogue(acc, bias_block, activation: str):
    """Bias add + activation on the fp32 accumulator (pre-flush)."""
    if bias_block is not None:
        acc = acc + bias_block.astype(jnp.float32)
    return ACTIVATIONS[activation](acc)


def _ceil_to(x: int, m: int) -> int:
    return -(-x // m) * m


def _pad_to(arr: jax.Array, axis: int, size: int) -> jax.Array:
    if arr.shape[axis] == size:
        return arr
    pads = [(0, 0)] * arr.ndim
    pads[axis] = (0, size - arr.shape[axis])
    return jnp.pad(arr, pads)


def _build_paired_call(
    *,
    bm: int,
    bn: int,
    nkp: int,
    bkp: int,
    nkr: int,
    bkr: int,
    has_bias: bool,
    has_residual: bool,
    activation: str,
    pool: str,
    Mp: int,
    Np: int,
    out_dtype,
    interpret: bool,
    n_blocks: int = 0,
):
    """One parameterized ``pallas_call`` covering all segment combinations.

    The contraction grid has ``nkp`` paired k-steps followed by ``nkr``
    residual k-steps; either count may be zero (but not both).  Inputs are
    ordered ``[xi, xj, kmat][:has_pairs] + [xr, w_res][:has_resid] +
    [bias][:has_bias] + [residual][:has_residual]``.

    ``has_residual`` streams an output-shaped ``(Mp, Np)`` operand added on
    the fp32 accumulator in the flush, after bias/activation (and after the
    pooled reduction) — the fused skip connection.  It is indexed like the
    output tile, so it works identically in the blocked layout (the
    residual lives in output space; blocks only partition the contraction
    metadata).

    ``pool != "none"`` selects the megakernel layout: activation operands
    are window-major ``(4, Mp, K)``, the accumulator grows a leading window
    axis, and the flush reduces the 2×2 window before the (single, pooled)
    HBM writeback.  ``Mp`` then counts *pooled* output rows.

    ``n_blocks > 0`` selects the column-blocked layout (module docstring,
    "Column-blocked layout"): every activation/weight operand carries a
    leading block axis indexed by the grid n-step (block shape 1), so each
    n-step contracts against its own ``[I | J | resid]`` segment metadata;
    the grid n extent is ``n_blocks`` and ``Np == n_blocks · bn``.
    """
    has_pairs = nkp > 0
    has_resid = nkr > 0
    has_pool = pool != "none"
    blocked = n_blocks > 0
    W = POOL_WINDOW if has_pool else 1
    nk = nkp + nkr
    assert nk > 0
    if blocked:
        assert Np == n_blocks * bn, (Np, n_blocks, bn)

    # The TPU MXU multiplies bf16 operands at full product precision and
    # accumulates fp32; XLA's *CPU* dot instead rounds each product to bf16.
    # Interpret mode is the validation oracle, so upcast dot operands there
    # to match the hardware semantics being modelled.
    cast = (lambda a: a.astype(jnp.float32)) if interpret else (lambda a: a)

    def sub(a, b):
        # The paper's subtractor operates at *input* precision: for bf16
        # inputs the difference is rounded to bf16 before it feeds the MXU.
        # reduce_precision pins that rounding — XLA's excess-precision pass
        # would otherwise elide the bf16 round-trip inside the fused kernel
        # and silently diverge from the hardware dataflow (and from ref.py).
        d = a - b
        if interpret and d.dtype != jnp.float32:
            info = jnp.finfo(d.dtype)
            d = jax.lax.reduce_precision(
                d.astype(jnp.float32), info.nexp, info.nmant
            )
        return d

    def kernel(*refs):
        refs = list(refs)
        acc_ref = refs.pop()
        o_ref = refs.pop()
        r_ref = refs.pop() if has_residual else None
        b_ref = refs.pop() if has_bias else None
        it = iter(refs)
        k = pl.program_id(2)

        # Window-element accessors: with pooling the activation refs carry a
        # leading window axis and the accumulator matches; each window
        # element runs its own 2-D MXU dot (the window axis stays a leading,
        # untiled dim — no sublane reshapes).  In the blocked layout every
        # operand additionally carries a leading (size-1) block axis — the
        # n-step already selected the block, so the accessors just squeeze.
        def x_at(ref, w):
            if blocked:
                return ref[0, w] if has_pool else ref[0]
            return ref[w] if has_pool else ref[...]

        def w_tile(ref):
            return ref[0] if blocked else ref[...]

        def acc_add(w, val):
            if has_pool:
                acc_ref[w] = acc_ref[w] + val
            else:
                acc_ref[...] += val

        @pl.when(k == 0)
        def _zero():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        if has_pairs:
            xi_ref, xj_ref, km_ref = next(it), next(it), next(it)

            def paired_step():
                # VPU subtract (the paper's subtractor) at input precision,
                # then one MXU dot per window element.
                km = cast(w_tile(km_ref))
                for w in range(W):
                    diff = sub(x_at(xi_ref, w), x_at(xj_ref, w))
                    acc_add(w, jnp.dot(
                        cast(diff), km, preferred_element_type=jnp.float32,
                    ))

            if has_resid:
                pl.when(k < nkp)(paired_step)
            else:
                paired_step()
        if has_resid:
            xr_ref, wr_ref = next(it), next(it)

            def resid_step():
                wr = cast(w_tile(wr_ref))
                for w in range(W):
                    acc_add(w, jnp.dot(
                        cast(x_at(xr_ref, w)), wr,
                        preferred_element_type=jnp.float32,
                    ))

            if has_pairs:
                pl.when(k >= nkp)(resid_step)
            else:
                resid_step()

        @pl.when(k == nk - 1)
        def _flush():
            bias_block = b_ref[...] if has_bias else None
            acc = _apply_epilogue(acc_ref[...], bias_block, activation)
            if has_pool:
                acc = POOLS[pool](acc)  # (4, bm, bn) → (bm, bn) in VMEM
            if has_residual:
                # fused skip connection: fp32 add after bias/activation/pool,
                # still inside VMEM — the residual never costs its own HBM
                # round-trip through a standalone add op
                acc = acc + r_ref[...].astype(jnp.float32)
            o_ref[...] = acc.astype(o_ref.dtype)

    # --- block specs: each segment's index map clamps into its own range ---
    # (with pooling, activation blocks carry the full window axis up front;
    # in the blocked layout every operand leads with a block axis the grid
    # n-step indexes)
    def x_spec(bk, kidx):
        if blocked:
            if has_pool:
                return pl.BlockSpec(
                    (1, W, bm, bk), lambda m, n, k: (n, 0, m, kidx(k))
                )
            return pl.BlockSpec((1, bm, bk), lambda m, n, k: (n, m, kidx(k)))
        if has_pool:
            return pl.BlockSpec((W, bm, bk), lambda m, n, k: (0, m, kidx(k)))
        return pl.BlockSpec((bm, bk), lambda m, n, k: (m, kidx(k)))

    def w_spec(bk, kidx):
        if blocked:
            return pl.BlockSpec((1, bk, bn), lambda m, n, k: (n, kidx(k), 0))
        return pl.BlockSpec((bk, bn), lambda m, n, k: (kidx(k), n))

    in_specs = []
    if has_pairs:
        pk = lambda k: jnp.minimum(k, nkp - 1)
        in_specs += [x_spec(bkp, pk), x_spec(bkp, pk), w_spec(bkp, pk)]
    if has_resid:
        rk = lambda k: jnp.clip(k - nkp, 0, nkr - 1)
        in_specs += [x_spec(bkr, rk), w_spec(bkr, rk)]
    if has_bias:
        in_specs.append(pl.BlockSpec((1, bn), lambda m, n, k: (0, n)))
    if has_residual:
        # output-space operand: indexed exactly like the output tile
        in_specs.append(pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)))

    kwargs = {}
    if not interpret:
        # k must iterate sequentially per output tile (the accumulator
        # carries across k-steps); m/n tiles are independent.
        params_cls = getattr(pltpu, "TPUCompilerParams", None)
        if params_cls is not None:
            kwargs["compiler_params"] = params_cls(
                dimension_semantics=("parallel", "parallel", "arbitrary")
            )

    # name the kernel by its segments: profiles (and the dtype analysis rule,
    # which pins reduce_precision on low-precision *subtractor* kernels) key
    # on "paired" meaning the kernel actually executes x[I]-x[J] lanes
    name = "paired_matmul" if has_pairs else "dense_matmul"
    if blocked:
        name += "_blocked"
    if has_pool:
        name += "_pool"

    acc_shape = (W, bm, bn) if has_pool else (bm, bn)
    return pl.pallas_call(
        kernel,
        name=name,
        grid=(Mp // bm, n_blocks if blocked else Np // bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM(acc_shape, jnp.float32)],
        interpret=interpret,
        **kwargs,
    )


def paired_matmul_pallas(
    x: jax.Array,  # (M, K) pre-permuted to [I | J | residual]
    kmat: jax.Array,  # (P, N) per-column pair magnitudes
    w_res: jax.Array,  # (R, N) residual weights, R = K - 2P
    bias: jax.Array | None = None,  # (N,) fused epilogue bias
    *,
    residual: jax.Array | None = None,  # (M, N) fused skip-connection add
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    activation: str = "none",
    pool: str = "none",
    interpret: bool = True,
) -> jax.Array:
    """K-tiled fused subtract-then-MAC GEMM with epilogue. Returns (M, N).

    The contraction over ``P`` paired lanes and ``R`` residual lanes is
    tiled in ``block_k`` chunks with an fp32 VMEM accumulator (see the
    module docstring, "Kernel tiling").

    ``pool="max2"``/``"avg2"`` selects the megakernel: ``x`` must then be
    window-major ``(4, M, K)`` — axis 0 enumerating the 2×2 window elements
    of pooled output row ``m`` — and the result is the *pooled* ``(M, N)``
    map, reduced in VMEM before the single HBM writeback (see the module
    docstring, "Fused pooling epilogue").

    ``residual`` fuses an output-shaped skip-connection add into the flush
    (after bias/activation/pool, fp32, before the single writeback — see
    the module docstring, "Residual-add epilogue"); with pooling it must
    already be the pooled ``(M, N)`` map.
    """
    assert pool == "none" or pool in POOLS, f"unknown pool {pool!r}"
    has_pool = pool != "none"
    if has_pool:
        assert x.ndim == 3 and x.shape[0] == POOL_WINDOW, (
            f"pool={pool!r} expects window-major x (4, M, K), got {x.shape}"
        )
    else:
        assert x.ndim == 2, f"expected (M, K) activations, got {x.shape}"
    M, K = x.shape[-2], x.shape[-1]
    P, N = kmat.shape
    R = w_res.shape[0]
    assert K == 2 * P + R, f"layout mismatch: K={K} vs 2P+R={2*P+R}"
    assert activation in ACTIVATIONS, f"unknown activation {activation!r}"
    if residual is not None:
        assert residual.shape == (M, N), (
            f"residual must be output-shaped {(M, N)}, got {residual.shape}"
        )

    xi = x[..., :P]
    xj = x[..., P : 2 * P]
    xr = x[..., 2 * P :]

    if P + R == 0:
        # degenerate zero-length contraction: epilogue only
        y = jnp.zeros(((POOL_WINDOW, M, N) if has_pool else (M, N)), jnp.float32)
        b = None if bias is None else bias.astype(jnp.float32)[None]
        y = _apply_epilogue(y, b, activation)
        if has_pool:
            y = POOLS[pool](y)
        if residual is not None:
            y = y + residual.astype(jnp.float32)
        return y.astype(x.dtype)

    m_axis, k_axis = x.ndim - 2, x.ndim - 1
    bm = min(block_m, M)
    bn = min(block_n, N)
    Mp = _ceil_to(M, bm)
    Np = _ceil_to(N, bn)

    # per-segment k tiles (each segment keeps its own block size ≤ block_k)
    bkp = min(block_k, P) if P else 0
    bkr = min(block_k, R) if R else 0
    nkp = -(-P // bkp) if P else 0
    nkr = -(-R // bkr) if R else 0

    operands = []
    if P:
        Pp = nkp * bkp
        operands += [
            _pad_to(_pad_to(xi, m_axis, Mp), k_axis, Pp),
            _pad_to(_pad_to(xj, m_axis, Mp), k_axis, Pp),
            _pad_to(_pad_to(kmat, 0, Pp), 1, Np),
        ]
    if R:
        Rp = nkr * bkr
        operands += [
            _pad_to(_pad_to(xr, m_axis, Mp), k_axis, Rp),
            _pad_to(_pad_to(w_res, 0, Rp), 1, Np),
        ]
    if bias is not None:
        operands.append(_pad_to(bias[None], 1, Np))
    if residual is not None:
        operands.append(_pad_to(_pad_to(residual, 0, Mp), 1, Np))

    call = _build_paired_call(
        bm=bm, bn=bn, nkp=nkp, bkp=bkp, nkr=nkr, bkr=bkr,
        has_bias=bias is not None, has_residual=residual is not None,
        activation=activation, pool=pool,
        Mp=Mp, Np=Np, out_dtype=x.dtype, interpret=interpret,
    )
    out = call(*operands)
    return out[:M, :N]


def paired_matmul_blocked_pallas(
    x: jax.Array,  # (B, M, K') block-gathered, or (B, 4, M, K') window-major
    kmat: jax.Array,  # (B, Pmax, bn) packed per-block pair magnitudes
    w_res: jax.Array,  # (B, Rmax, bn) packed per-block residual weights
    bias: jax.Array | None = None,  # (N,) fused epilogue bias
    *,
    n_cols: int,
    residual: jax.Array | None = None,  # (M, n_cols) fused skip-connection add
    block_m: int = 128,
    block_k: int = 512,
    activation: str = "none",
    pool: str = "none",
    interpret: bool = True,
) -> jax.Array:
    """Column-blocked K-tiled paired GEMM. Returns (M, n_cols).

    Each of the ``B`` blocks owns ``bn`` contiguous output columns and its
    own ``[I | J | resid]`` lane segments, padded to the common
    ``(Pmax, Rmax)`` split (``K' = 2·Pmax + Rmax``; padded lanes carry zero
    weights).  ``x`` is the activation matrix already gathered through the
    packed index matrix (``BlockedPairing.index_arrays()["perm"]``), so row
    block ``b`` of ``x`` is permuted to block ``b``'s lane order.  Only the
    last block may cover fewer than ``bn`` real columns (``n_cols`` trims
    the padding); the lane tile is pinned to ``bn`` — the pairing block size
    *is* the kernel's n-tile.  Epilogue (bias + activation), the fused
    2×2 pooling (``x`` then ``(B, 4, M, K')`` window-major) and the
    residual-add epilogue (``residual`` lives in *output* space, so it is
    indexed like the output tile — blocks only partition the contraction
    metadata) behave exactly as in :func:`paired_matmul_pallas`, per block.

    The block axis doubles as the MoE **expert grid**: per-expert pairings
    (``core.transform.pair_params`` on ``(L, E, K, F)`` weights) map each
    expert — or each ``(expert, column-block)`` cell — onto one ``B`` entry
    with its own permuted activation rows, so
    :func:`repro.kernels.ops.fused_paired_expert_dense` runs the whole
    expert batch as a single blocked launch.
    """
    assert pool == "none" or pool in POOLS, f"unknown pool {pool!r}"
    has_pool = pool != "none"
    if has_pool:
        assert x.ndim == 4 and x.shape[1] == POOL_WINDOW, (
            f"pool={pool!r} expects block-major window-major x (B, 4, M, K'), "
            f"got {x.shape}"
        )
    else:
        assert x.ndim == 3, f"expected (B, M, K') activations, got {x.shape}"
    B, P, bn = kmat.shape
    R = w_res.shape[1]
    assert w_res.shape[0] == B and w_res.shape[2] == bn, (kmat.shape, w_res.shape)
    M, Kp = x.shape[-2], x.shape[-1]
    assert x.shape[0] == B, (x.shape, B)
    assert Kp == 2 * P + R, f"packed layout mismatch: K'={Kp} vs 2P+R={2*P+R}"
    assert 0 < n_cols <= B * bn, (n_cols, B, bn)
    assert activation in ACTIVATIONS, f"unknown activation {activation!r}"
    if residual is not None:
        assert residual.shape == (M, n_cols), (
            f"residual must be output-shaped {(M, n_cols)}, got {residual.shape}"
        )

    xi = x[..., :P]
    xj = x[..., P : 2 * P]
    xr = x[..., 2 * P :]

    if P + R == 0:
        y = jnp.zeros(((POOL_WINDOW, M, n_cols) if has_pool else (M, n_cols)),
                      jnp.float32)
        b = None if bias is None else bias.astype(jnp.float32)[None]
        y = _apply_epilogue(y, b, activation)
        if has_pool:
            y = POOLS[pool](y)
        if residual is not None:
            y = y + residual.astype(jnp.float32)
        return y.astype(x.dtype)

    m_axis, k_axis = x.ndim - 2, x.ndim - 1
    bm = min(block_m, M)
    Mp = _ceil_to(M, bm)
    Np = B * bn

    bkp = min(block_k, P) if P else 0
    bkr = min(block_k, R) if R else 0
    nkp = -(-P // bkp) if P else 0
    nkr = -(-R // bkr) if R else 0

    operands = []
    if P:
        Pp = nkp * bkp
        operands += [
            _pad_to(_pad_to(xi, m_axis, Mp), k_axis, Pp),
            _pad_to(_pad_to(xj, m_axis, Mp), k_axis, Pp),
            _pad_to(kmat, 1, Pp),
        ]
    if R:
        Rp = nkr * bkr
        operands += [
            _pad_to(_pad_to(xr, m_axis, Mp), k_axis, Rp),
            _pad_to(w_res, 1, Rp),
        ]
    if bias is not None:
        operands.append(_pad_to(bias[None], 1, Np))
    if residual is not None:
        operands.append(_pad_to(_pad_to(residual, 0, Mp), 1, Np))

    call = _build_paired_call(
        bm=bm, bn=bn, nkp=nkp, bkp=bkp, nkr=nkr, bkr=bkr,
        has_bias=bias is not None, has_residual=residual is not None,
        activation=activation, pool=pool,
        Mp=Mp, Np=Np, out_dtype=x.dtype, interpret=interpret, n_blocks=B,
    )
    out = call(*operands)
    return out[:M, :n_cols]


def dense_matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    bias: jax.Array | None = None,
    *,
    residual: jax.Array | None = None,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    activation: str = "none",
    interpret: bool = True,
) -> jax.Array:
    """Baseline K-tiled GEMM with identical tiling + epilogue fusion.

    The degenerate single-segment case of the paired builder (P == 0):
    like-for-like comparison baseline and the serving fast path for
    unpaired layers.
    """
    P0 = jnp.zeros((0, w.shape[1]), w.dtype)
    return paired_matmul_pallas(
        x, P0, w, bias, residual=residual,
        block_m=block_m, block_n=block_n, block_k=block_k,
        activation=activation, interpret=interpret,
    )
