"""Pallas TPU kernel: the paper's subtractor MAC array, as a fused GEMM.

The ASIC datapath of the paper evaluates a combined weight pair (+k, -k) as
``k · (I₁ − I₂)`` — one subtractor replaces a multiplier+adder (eq. 1).  On a
TPU the MXU charges the same for every multiply-accumulate lane, so the
*structural* translation of the saving is a **shorter contraction**: with
``P`` shared pairs and ``R`` residual channels (``K = 2P + R``),

    y = (x[:, :P] − x[:, P:2P]) @ Kmat  +  x[:, 2P:] @ W_res

contracts over ``P + R = K − P`` lanes instead of ``K``.  The subtraction is
VPU work fused into the same kernel — it never round-trips HBM.  The input
is expected *pre-permuted* to the ``[I | J | residual]`` layout
(``StructuredPairing.perm()``); the permutation is free at deploy time
because it folds into the previous layer's output projection.

Tiling: grid over (M/bm, N/bn); each program loads its x row-block — the
paired halves (bm, P) twice and the residual (bm, R) once — plus the
matching (P, bn) / (R, bn) weight columns into VMEM, subtracts on the VPU,
and runs two MXU dots with fp32 accumulation.  For every assigned
architecture the full-K row block fits VMEM comfortably
(largest: mistral d_model 12288 → ≤ 6.3 MB bf16 at bm=128).

``interpret=True`` executes the same kernel body with jnp semantics on CPU —
that is how the kernel is validated in this container (TPU is the target).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _paired_kernel(xi_ref, xj_ref, xr_ref, km_ref, wr_ref, o_ref):
    """One (bm, bn) output tile: subtract-then-MAC + residual MAC."""
    diff = (xi_ref[...] - xj_ref[...])  # VPU: (bm, P) — the paper's subtractor
    acc = jnp.dot(diff, km_ref[...], preferred_element_type=jnp.float32)
    acc += jnp.dot(xr_ref[...], wr_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def _paired_only_kernel(xi_ref, xj_ref, km_ref, o_ref):
    diff = xi_ref[...] - xj_ref[...]
    o_ref[...] = jnp.dot(
        diff, km_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def paired_matmul_pallas(
    x: jax.Array,  # (M, K) pre-permuted to [I | J | residual]
    kmat: jax.Array,  # (P, N) per-column pair magnitudes
    w_res: jax.Array,  # (R, N) residual weights, R = K - 2P
    *,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Fused subtract-then-MAC GEMM. Returns (M, N) in x.dtype."""
    M, K = x.shape
    P, N = kmat.shape
    R = w_res.shape[0]
    assert K == 2 * P + R, f"layout mismatch: K={K} vs 2P+R={2*P+R}"

    bm = min(block_m, M)
    bn = min(block_n, N)
    # pad M/N up to tile multiples (pallas grids need exact tiling)
    Mp = -(-M // bm) * bm
    Np = -(-N // bn) * bn
    if Mp != M:
        x = jnp.pad(x, ((0, Mp - M), (0, 0)))
    if Np != N:
        kmat = jnp.pad(kmat, ((0, 0), (0, Np - N)))
        w_res = jnp.pad(w_res, ((0, 0), (0, Np - N)))

    xi = x[:, :P]
    xj = x[:, P : 2 * P]
    xr = x[:, 2 * P :]

    grid = (Mp // bm, Np // bn)
    if R == 0:
        out = pl.pallas_call(
            _paired_only_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, P), lambda m, n: (m, 0)),
                pl.BlockSpec((bm, P), lambda m, n: (m, 0)),
                pl.BlockSpec((P, bn), lambda m, n: (0, n)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda m, n: (m, n)),
            out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
            interpret=interpret,
        )(xi, xj, kmat)
    elif P == 0:
        # no pairs found — plain GEMM over the residual
        out = pl.pallas_call(
            _dense_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, R), lambda m, n: (m, 0)),
                pl.BlockSpec((R, bn), lambda m, n: (0, n)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda m, n: (m, n)),
            out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
            interpret=interpret,
        )(xr, w_res)
    else:
        out = pl.pallas_call(
            _paired_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, P), lambda m, n: (m, 0)),
                pl.BlockSpec((bm, P), lambda m, n: (m, 0)),
                pl.BlockSpec((bm, R), lambda m, n: (m, 0)),
                pl.BlockSpec((P, bn), lambda m, n: (0, n)),
                pl.BlockSpec((R, bn), lambda m, n: (0, n)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda m, n: (m, n)),
            out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
            interpret=interpret,
        )(xi, xj, xr, kmat, w_res)
    return out[:M, :N]


def _dense_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def dense_matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Baseline GEMM with identical tiling (for like-for-like comparison)."""
    M, K = x.shape
    _, N = w.shape
    bm, bn = min(block_m, M), min(block_n, N)
    Mp, Np = -(-M // bm) * bm, -(-N // bn) * bn
    if Mp != M:
        x = jnp.pad(x, ((0, Mp - M), (0, 0)))
    if Np != N:
        w = jnp.pad(w, ((0, 0), (0, Np - N)))
    out = pl.pallas_call(
        _dense_kernel,
        grid=(Mp // bm, Np // bn),
        in_specs=[
            pl.BlockSpec((bm, K), lambda m, n: (m, 0)),
            pl.BlockSpec((K, bn), lambda m, n: (0, n)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n: (m, n)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        interpret=interpret,
    )(x, w)
    return out[:M, :N]
