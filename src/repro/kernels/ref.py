"""Pure-jnp oracles for the Pallas kernels (the ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def paired_matmul_ref(x: jax.Array, kmat: jax.Array, w_res: jax.Array) -> jax.Array:
    """y = (x[:, :P] - x[:, P:2P]) @ Kmat + x[:, 2P:] @ W_res, fp32 accum.

    The subtraction happens at *input* precision — that is the paper's
    subtractor semantics (the hardware unit operates on the input format),
    and what the Pallas kernel's VPU does — then the dot accumulates fp32.
    """
    P = kmat.shape[0]
    diff = x[:, :P] - x[:, P : 2 * P]  # input-dtype subtract
    y = diff.astype(jnp.float32) @ kmat.astype(jnp.float32)
    y = y + x[:, 2 * P :].astype(jnp.float32) @ w_res.astype(jnp.float32)
    return y.astype(x.dtype)


def dense_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return (x.astype(jnp.float32) @ w.astype(jnp.float32)).astype(x.dtype)
