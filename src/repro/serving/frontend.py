"""Hardened async serving front end over :class:`~repro.serving.engine.ServeEngine`.

Continuous batching under simulated load: seeded Poisson arrivals feed a
bounded request queue; admission is length-bucketed into the engine's free
slots; long prompts prefill in chunks (an initial chunk through the real
prefill, the tail piggybacked one token per shared decode step, so a long
prompt never stalls the other slots' decode); per-request deadlines and a
queue timeout shed work that can't be served in time, with structured
reasons.  A :class:`~repro.serving.guards.NumericWatchdog` inspects every
decode step's logits and degrades bad slots to the **unpaired** fallback
engine (exact arithmetic) with bounded backoff — the graceful-degradation
half of the paper's approximate-compute bet.

Time is *virtual*: each batched decode step and each prefilled token charges
a configured cost, and fault-injected latency spikes multiply it.  That
keeps p50/p99 latency and tokens/sec deterministic for a given seed —
interpret-mode wall-clock would be noise — while the report also records
real wall time.

The loop is synchronous Python driving jitted step functions — "async" here
is the scheduling discipline (arrivals, admission, interleaved prefill,
eviction) rather than an event loop, which is exactly the part a serving
system must get right and the part this bench can gate in CI.
"""
from __future__ import annotations

import dataclasses
import time as _time
from collections import deque

import numpy as np

from repro.serving.engine import CapacityError, ServeEngine
from repro.serving.faults import SLOT_FAULTS, FaultInjector
from repro.serving.guards import GuardConfig, IncidentLog, NumericWatchdog

TERMINAL_STATES = ("completed", "degraded", "shed")


@dataclasses.dataclass
class Request:
    """One serving request plus its full lifecycle record."""

    rid: int
    prompt: np.ndarray  # (plen,) int32
    max_new_tokens: int
    arrival: float  # virtual seconds
    # lifecycle (filled by the front end):
    state: str = "queued"  # queued | running | completed | degraded | shed
    tokens: list[int] = dataclasses.field(default_factory=list)
    admit_time: float | None = None
    first_token_time: float | None = None
    finish_time: float | None = None
    shed_reason: str | None = None
    retries: int = 0
    degraded: bool = False  # ever routed to the fallback path
    engine: str | None = None  # "primary" | "fallback" while running
    slot: int | None = None
    prefill_done: int = 0  # prompt tokens absorbed so far (chunked prefill)
    not_before: float = 0.0  # backoff: earliest virtual re-admission time

    @property
    def plen(self) -> int:
        return len(self.prompt)

    def latency(self) -> float | None:
        return None if self.finish_time is None else self.finish_time - self.arrival

    def ttft(self) -> float | None:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    max_queue: int = 64  # arrivals beyond this are shed ("queue_full")
    prefill_chunk: int = 8  # prompt tokens per monolithic prefill call;
    # the rest of a long prompt rides the shared decode steps 1 tok/step
    bucket_width: int = 8  # length-bucket granularity for admission order
    deadline_s: float = float("inf")  # completion deadline after arrival
    queue_timeout_s: float = float("inf")  # max queue wait before shedding
    step_cost_s: float = 0.01  # virtual cost of one batched decode step
    prefill_cost_s: float = 0.002  # virtual cost per prefilled prompt token
    max_kernel_retries: int = 3  # simulated-launch-failure retries per step
    max_steps: int = 100_000  # hard loop bound: a scheduling bug fails fast
    guard: GuardConfig = dataclasses.field(default_factory=GuardConfig)


def poisson_workload(
    *,
    rate_rps: float,
    horizon_s: float,
    seed: int,
    vocab: int,
    prompt_len: tuple[int, int] = (4, 24),
    new_tokens: tuple[int, int] = (4, 12),
) -> list[Request]:
    """Seeded Poisson arrival process with mixed prompt/output lengths.

    Inter-arrival gaps are Exponential(rate); prompt and output lengths are
    uniform over the given inclusive-exclusive ranges.  Deterministic for a
    given seed — the bench's offered-load axis.
    """
    rng = np.random.default_rng(seed)
    reqs: list[Request] = []
    t = 0.0
    while True:
        t += float(rng.exponential(1.0 / rate_rps))
        if t > horizon_s:
            break
        plen = int(rng.integers(*prompt_len))
        reqs.append(Request(
            rid=len(reqs),
            prompt=rng.integers(0, vocab, size=(plen,)).astype(np.int32),
            max_new_tokens=int(rng.integers(*new_tokens)),
            arrival=t,
        ))
    return reqs


def _percentiles(values: list[float]) -> dict[str, float | None]:
    if not values:
        return {"p50": None, "p99": None}
    arr = np.asarray(values, np.float64)
    return {"p50": round(float(np.percentile(arr, 50)), 6),
            "p99": round(float(np.percentile(arr, 99)), 6)}


@dataclasses.dataclass
class ServeReport:
    """Everything one load run produced, plus the summary the bench emits."""

    requests: list[Request]
    incidents: IncidentLog
    virtual_time: float
    wall_s: float
    steps: int
    offered_load_rps: float | None = None

    def by_state(self) -> dict[str, list[Request]]:
        out: dict[str, list[Request]] = {s: [] for s in (*TERMINAL_STATES, "other")}
        for r in self.requests:
            out[r.state if r.state in TERMINAL_STATES else "other"].append(r)
        return out

    def lost(self) -> list[Request]:
        """Requests not in a terminal state — must always be empty."""
        return [r for r in self.requests if r.state not in TERMINAL_STATES]

    def summary(self) -> dict:
        by = self.by_state()
        done = by["completed"] + by["degraded"]
        shed_reasons: dict[str, int] = {}
        for r in by["shed"]:
            shed_reasons[r.shed_reason or "?"] = (
                shed_reasons.get(r.shed_reason or "?", 0) + 1)
        n_tokens = sum(len(r.tokens) for r in done)
        return {
            "n_requests": len(self.requests),
            "completed": len(by["completed"]),
            "degraded": len(by["degraded"]),
            "shed": len(by["shed"]),
            "shed_reasons": shed_reasons,
            "lost": len(self.lost()),
            "offered_load_rps": self.offered_load_rps,
            "latency_s": _percentiles([r.latency() for r in done]),
            "ttft_s": _percentiles(
                [r.ttft() for r in done if r.ttft() is not None]),
            "generated_tokens": n_tokens,
            "tokens_per_s_virtual": (
                round(n_tokens / self.virtual_time, 3) if self.virtual_time else None),
            "virtual_time_s": round(self.virtual_time, 6),
            "wall_s": round(self.wall_s, 3),
            "steps": self.steps,
            "incidents": self.incidents.counts(),
        }


class ServeFrontend:
    """Drives a primary (possibly subtractor-paired) engine and an optional
    exact fallback engine through one simulated-load run."""

    def __init__(
        self,
        primary: ServeEngine,
        fallback: ServeEngine | None = None,
        cfg: FrontendConfig | None = None,
        faults: FaultInjector | None = None,
    ):
        self.primary = primary
        self.fallback = fallback
        self.cfg = cfg or FrontendConfig()
        self.faults = faults
        self.log = IncidentLog()
        self.watchdog = NumericWatchdog(self.cfg.guard, self.log)
        # (engine_name, slot) -> Request
        self.running: dict[tuple[str, int], Request] = {}
        self._quarantine_until: dict[tuple[str, int], int] = {}

    # -- helpers --------------------------------------------------------------
    def _engines(self):
        yield "primary", self.primary
        if self.fallback is not None:
            yield "fallback", self.fallback

    def _engine(self, name: str) -> ServeEngine:
        return self.primary if name == "primary" else self.fallback

    def _shed(self, r: Request, reason: str, *, now: float, step: int) -> None:
        if r.slot is not None and r.engine is not None:
            self._engine(r.engine).release_slot(r.slot)
            self.running.pop((r.engine, r.slot), None)
        r.state, r.shed_reason, r.finish_time = "shed", reason, now
        r.engine = r.slot = None
        self.log.add(time=now, step=step, engine=r.engine or "-",
                     slot=-1, rid=r.rid, kind=reason, action="shed")

    def _bucket_order(self, queue: list[Request], now: float) -> list[Request]:
        """Length-bucketed admission order: serve the bucket of the oldest
        eligible request first (so similar-length prompts batch together),
        oldest-first inside a bucket, then everything else oldest-first."""
        eligible = [r for r in queue if r.not_before <= now]
        if not eligible:
            return []
        w = max(1, self.cfg.bucket_width)
        lead = min(eligible, key=lambda r: r.arrival)
        lead_bucket = lead.plen // w
        return sorted(
            eligible,
            key=lambda r: (r.plen // w != lead_bucket, r.arrival, r.rid),
        )

    def _admit(self, r: Request, name: str, slot: int, now: float) -> float:
        """Prefill the first chunk into ``slot``; returns the virtual cost."""
        eng = self._engine(name)
        c0 = min(r.plen, max(1, self.cfg.prefill_chunk))
        first = eng.add_request(slot, r.prompt[:c0])
        r.state, r.engine, r.slot = "running", name, slot
        r.admit_time = now
        r.prefill_done = c0
        r.tokens = []
        if c0 < r.plen:
            # chunked prefill: the tail rides the shared decode steps —
            # override the engine's sampled token with the next prompt token
            eng.force_token(slot, int(r.prompt[c0]))
        else:
            r.tokens.append(int(first))
            r.first_token_time = now + self.cfg.prefill_cost_s * c0
        self.running[(name, slot)] = r
        cost = self.cfg.prefill_cost_s * c0
        # a one-token request is already done after prefill
        self._finish_if_done(r, now=now + cost)
        return cost

    def _account_token(self, r: Request, tok: int, *, now: float) -> None:
        """One decode-step emission for a running request: either consumes
        one more prompt token (chunked prefill) or appends a generated one."""
        eng = self._engine(r.engine)
        if r.prefill_done < r.plen:
            r.prefill_done += 1
            if r.prefill_done < r.plen:
                eng.force_token(r.slot, int(r.prompt[r.prefill_done]))
            else:
                # the step that absorbed the last prompt token emitted the
                # first generated token
                r.tokens.append(tok)
                r.first_token_time = now
        else:
            r.tokens.append(tok)

    def _finish_if_done(self, r: Request, *, now: float) -> None:
        if len(r.tokens) < r.max_new_tokens:
            return
        r.tokens = r.tokens[: r.max_new_tokens]
        self._engine(r.engine).release_slot(r.slot)
        self.running.pop((r.engine, r.slot), None)
        r.state = "degraded" if r.degraded else "completed"
        r.finish_time = now
        r.engine = r.slot = None

    def _degrade(self, r: Request, name: str, slot: int, reason: str,
                 queue: list[Request], *, now: float, step: int) -> None:
        """Watchdog verdict for a flagged slot: quarantine it, then retry the
        request from its prompt on the fallback path or shed it."""
        action = self.watchdog.quarantine(
            self._engine(name), name, slot, reason,
            step=step, now=now, rid=r.rid)
        self.running.pop((name, slot), None)
        self._quarantine_until[(name, slot)] = step + self.cfg.guard.quarantine_steps
        if action == "shed":
            r.state, r.shed_reason, r.finish_time = "shed", f"retries_exhausted:{reason}", now
            r.engine = r.slot = None
            return
        r.not_before = now + self.watchdog.backoff(r.retries)
        r.retries += 1
        r.degraded = True
        r.state, r.engine, r.slot = "queued", None, None
        r.tokens = []
        r.prefill_done = 0
        r.first_token_time = None
        queue.append(r)

    # -- the loop -------------------------------------------------------------
    def run(self, workload: list[Request],
            offered_load_rps: float | None = None) -> ServeReport:
        cfg = self.cfg
        t_wall = _time.perf_counter()
        now = 0.0
        step = 0
        pending = deque(sorted(workload, key=lambda r: (r.arrival, r.rid)))
        queue: list[Request] = []

        while pending or queue or self.running:
            if step >= cfg.max_steps:
                raise RuntimeError(
                    f"front end exceeded max_steps={cfg.max_steps} with "
                    f"{len(pending)} pending / {len(queue)} queued / "
                    f"{len(self.running)} running — scheduling bug or "
                    f"undersized budget")

            # quarantine cooldowns expire on the step clock
            for (name, slot), until in list(self._quarantine_until.items()):
                if step >= until:
                    self._engine(name).clear_quarantine(slot)
                    del self._quarantine_until[(name, slot)]

            # arrivals → bounded queue
            while pending and pending[0].arrival <= now:
                r = pending.popleft()
                if len(queue) >= cfg.max_queue:
                    self._shed(r, "queue_full", now=now, step=step)
                else:
                    queue.append(r)

            # shed queued work that can no longer meet its bounds
            for r in list(queue):
                wait = now - r.arrival
                if now > r.arrival + cfg.deadline_s:
                    queue.remove(r)
                    self._shed(r, "deadline", now=now, step=step)
                elif wait > cfg.queue_timeout_s:
                    queue.remove(r)
                    self._shed(r, "queue_timeout", now=now, step=step)

            # length-bucketed admission into free slots
            for r in self._bucket_order(queue, now):
                target = "fallback" if (r.degraded and self.fallback is not None) \
                    else "primary"
                eng = self._engine(target)
                if r.plen + r.max_new_tokens > eng.max_seq:
                    queue.remove(r)
                    self._shed(r, "too_long", now=now, step=step)
                    continue
                free = eng.free_slots()
                if not free:
                    continue
                queue.remove(r)
                now += self._admit(r, target, free[0], now)

            if not self.running:
                # nothing to step: jump virtual time to the next event
                horizons = [r.arrival for r in pending][:1]
                horizons += [r.not_before for r in queue if r.not_before > now]
                if horizons:
                    now = max(now, min(horizons))
                elif queue:
                    # queued work blocked only by quarantine cooldowns —
                    # let the step clock tick them down
                    step += 1
                    continue
                else:
                    break
                step += 1
                continue

            # one batched decode step per engine with active slots
            for name, eng in self._engines():
                if not eng.active.any():
                    continue
                cost = cfg.step_cost_s
                if name == "primary" and self.faults is not None:
                    cost *= self.faults.latency_multiplier(step)
                    n_fail = self.faults.kernel_failures(step)
                    if n_fail:
                        retries = min(n_fail, cfg.max_kernel_retries)
                        cost += cfg.step_cost_s * retries
                        self.log.add(
                            time=now, step=step, engine=name, slot=-1, rid=-1,
                            kind="kernel_failure", action="injected",
                            detail=f"{n_fail} consecutive launch failure(s), "
                                   f"{retries} retried")
                        if n_fail > cfg.max_kernel_retries:
                            # launch keeps failing: degrade every active slot
                            now += cost
                            for slot in np.flatnonzero(eng.active):
                                r = self.running.get((name, int(slot)))
                                if r is not None:
                                    self._degrade(r, name, int(slot),
                                                  "kernel_failure", queue,
                                                  now=now, step=step)
                            continue
                    # cache poisoning happens before the step so the model
                    # itself produces the bad logits the watchdog must catch
                    for ev in self.faults.poison_kv(eng, step):
                        occupant = self.running.get((name, ev.slot))
                        self.log.add(
                            time=now, step=step, engine=name, slot=ev.slot,
                            rid=occupant.rid if occupant else -1,
                            kind=ev.kind, action="injected")

                nxt = eng.step()
                now += cost

                if name == "primary" and self.faults is not None:
                    corrupted, applied = self.faults.corrupt_logits(
                        eng.last_logits, step, eng.active)
                    eng.last_logits = corrupted
                    for ev in applied:
                        occupant = self.running.get((name, ev.slot))
                        self.log.add(
                            time=now, step=step, engine=name, slot=ev.slot,
                            rid=occupant.rid if occupant else -1,
                            kind=ev.kind, action="injected")

                flagged = self.watchdog.scan(eng, name, step=step, now=now)
                for slot, reason in flagged.items():
                    r = self.running.get((name, slot))
                    if r is None:  # active slot without a tracked request
                        eng.quarantine_slot(slot)
                        self._quarantine_until[(name, slot)] = (
                            step + cfg.guard.quarantine_steps)
                        continue
                    self._degrade(r, name, slot, reason, queue,
                                  now=now, step=step)

                # token accounting for the slots that survived the watchdog
                for (ename, slot), r in list(self.running.items()):
                    if ename != name or slot in flagged:
                        continue
                    self._account_token(r, int(nxt[slot]), now=now)
                    self._finish_if_done(r, now=now)

            # completion deadlines for running requests
            for (name, slot), r in list(self.running.items()):
                if now > r.arrival + cfg.deadline_s:
                    self._shed(r, "deadline", now=now, step=step)

            step += 1

        return ServeReport(
            requests=sorted(workload, key=lambda r: r.rid),
            incidents=self.log,
            virtual_time=now,
            wall_s=_time.perf_counter() - t_wall,
            steps=step,
            offered_load_rps=offered_load_rps,
        )


def faulted_request_ids(report: ServeReport) -> set[int]:
    """Requests that took a slot-targeted injected fault (the ones the
    zero-lost gate requires to end degraded-completed or cleanly shed)."""
    return {
        inc.rid for inc in report.incidents.records
        if inc.action == "injected" and inc.kind in SLOT_FAULTS and inc.rid >= 0
    }
