"""Serving substrate: batched KV-cache decode engine."""

from repro.serving.engine import ServeEngine  # noqa: F401
