"""Serving substrate: batched KV-cache decode engine + the hardened async
front end (continuous batching, fault injection, numeric watchdog with
graceful degradation to the unpaired exact path)."""

from repro.serving.engine import INACTIVE_TOKEN, CapacityError, ServeEngine  # noqa: F401
from repro.serving.faults import (  # noqa: F401
    FAULT_KINDS,
    SLOT_FAULTS,
    FaultEvent,
    FaultInjector,
    KernelFault,
)
from repro.serving.frontend import (  # noqa: F401
    FrontendConfig,
    Request,
    ServeFrontend,
    ServeReport,
    faulted_request_ids,
    poisson_workload,
)
from repro.serving.guards import (  # noqa: F401
    GuardConfig,
    Incident,
    IncidentLog,
    NumericWatchdog,
    check_logits,
)
