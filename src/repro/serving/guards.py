"""Numeric watchdog + graceful degradation policy for the serving front end.

The subtractor path trades exactness knobs (pairing, rounding) for power —
so the serving layer must assume its numerics *can* go bad and guarantee the
blast radius of a bad slot is one quarantined slot, never a garbage token
stream.  The watchdog checks every decode step's logits for NaN/Inf and
overflow; a flagged slot is quarantined (evicted + cache-scrubbed, admission
refused for a cooldown) and its request is retried with bounded backoff on
the **unpaired** fallback engine (``gemm="pallas"``/``"xla"`` knobs — exact
arithmetic), or shed with a structured reason once retries are exhausted.
Every action lands in a structured :class:`IncidentLog` the bench and CI
read back.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    overflow: float = 1e6  # |logit| above this is treated as corrupt
    max_retries: int = 2  # degraded re-admissions per request before shedding
    backoff_s: float = 0.05  # virtual re-admission delay; doubles per retry
    quarantine_steps: int = 2  # front-end ticks a flagged slot sits out


@dataclasses.dataclass
class Incident:
    """One structured incident-log record (JSON-serializable via as_dict)."""

    time: float  # virtual seconds
    step: int  # front-end step index
    engine: str  # "primary" | "fallback"
    slot: int
    rid: int  # request id (-1 when no request occupied the slot)
    kind: str  # "nan" | "inf" | "overflow" | fault kind | "kernel_failure"
    action: str  # "injected" | "quarantined" | "retried_degraded" | "shed"
    detail: str = ""

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class IncidentLog:
    def __init__(self):
        self.records: list[Incident] = []

    def add(self, **kw: Any) -> Incident:
        inc = Incident(**kw)
        self.records.append(inc)
        return inc

    def counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for r in self.records:
            key = f"{r.action}:{r.kind}"
            out[key] = out.get(key, 0) + 1
        return out

    def for_request(self, rid: int) -> list[Incident]:
        return [r for r in self.records if r.rid == rid]

    def as_dicts(self) -> list[dict]:
        return [r.as_dict() for r in self.records]

    def __len__(self) -> int:
        return len(self.records)


def check_logits(
    logits: np.ndarray | None,
    active: np.ndarray,
    overflow: float = GuardConfig.overflow,
) -> dict[int, str]:
    """Per-slot corruption verdicts over a (batch, vocab) decode-step logits
    array: ``{slot: "nan" | "inf" | "overflow"}`` for every *active* slot
    whose logits are unusable.  Inactive slots are never flagged."""
    if logits is None:
        return {}
    bad: dict[int, str] = {}
    for slot in np.flatnonzero(np.asarray(active, bool)):
        row = logits[slot]
        if np.isnan(row).any():
            bad[int(slot)] = "nan"
        elif np.isinf(row).any():
            bad[int(slot)] = "inf"
        elif np.abs(row).max() > overflow:
            bad[int(slot)] = "overflow"
    return bad


class NumericWatchdog:
    """Quarantine + retry/shed policy over one engine's decode steps.

    The watchdog owns the *decision* (quarantine the slot; retry the request
    degraded with backoff, or shed it) and the incident log; the front end
    owns the queue, so re-admission mechanics stay there.
    """

    def __init__(self, cfg: GuardConfig | None = None,
                 log: IncidentLog | None = None):
        self.cfg = cfg or GuardConfig()
        self.log = log if log is not None else IncidentLog()

    def scan(self, engine, engine_name: str, *, step: int,
             now: float) -> dict[int, str]:
        """Check the engine's last decode-step logits; returns flagged slots."""
        return check_logits(engine.last_logits, engine.active,
                            self.cfg.overflow)

    def quarantine(self, engine, engine_name: str, slot: int, reason: str, *,
                   step: int, now: float, rid: int) -> str:
        """Quarantine ``slot`` and decide the request's fate.

        Returns the action taken: ``"retried_degraded"`` (the front end must
        re-enqueue the request for the fallback engine, not before
        :meth:`backoff` seconds from now) or ``"shed"`` (retries exhausted).
        ``retries`` is read off the request by the caller *after* this —
        the watchdog only counts via the incident log.
        """
        engine.quarantine_slot(slot)
        self.log.add(time=now, step=step, engine=engine_name, slot=slot,
                     rid=rid, kind=reason, action="quarantined",
                     detail=f"slot evicted + cache scrubbed; cooldown "
                            f"{self.cfg.quarantine_steps} step(s)")
        n_prior = sum(
            1 for r in self.log.records
            if r.rid == rid and r.action == "retried_degraded"
        )
        if n_prior >= self.cfg.max_retries:
            self.log.add(time=now, step=step, engine=engine_name, slot=slot,
                         rid=rid, kind=reason, action="shed",
                         detail=f"retries exhausted ({n_prior}/"
                                f"{self.cfg.max_retries})")
            return "shed"
        self.log.add(time=now, step=step, engine=engine_name, slot=slot,
                     rid=rid, kind=reason, action="retried_degraded",
                     detail=f"retry {n_prior + 1}/{self.cfg.max_retries} on "
                            f"the unpaired fallback path, backoff "
                            f"{self.backoff(n_prior):.3f}s")
        return "retried_degraded"

    def backoff(self, n_prior_retries: int) -> float:
        """Bounded exponential backoff before a degraded re-admission."""
        return self.cfg.backoff_s * (2.0 ** n_prior_retries)
