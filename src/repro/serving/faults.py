"""Deterministic fault injection for the hardened serving front end.

Approximate-compute accelerators are exactly where numeric faults surface in
production: a mis-paired lane, a bad rounding table, or a flaky kernel launch
turns into NaN/Inf activations long before it turns into a crash.  This
module simulates those failure modes *on a schedule* so the front end's
watchdog + degradation policy (serving.guards) can be tested and benchmarked
reproducibly:

- ``nan_logits`` / ``inf_logits`` — corrupt one slot's decode-step logits
  (a transient bad kernel output on the paired path);
- ``kv_poison`` — write NaN into one slot's cached K/V (and SSM/conv state)
  rows, so the *model itself* produces non-finite logits on the next step —
  the end-to-end path a real accumulated-error fault would take;
- ``latency_spike`` — multiply the virtual cost of one batched step
  (a straggling kernel launch);
- ``kernel_failure`` — the step "crashes" ``magnitude`` consecutive times
  before succeeding (the front end retries, bounded).

Every event is an explicit :class:`FaultEvent` pinned to a front-end step
index; :meth:`FaultInjector.from_rates` derives a schedule from a seed for
chaos-style sweeps, but the schedule itself is always materialized up front —
two runs with the same events see byte-identical fault timing.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from collections.abc import Mapping, Sequence

import numpy as np

FAULT_KINDS = (
    "nan_logits", "inf_logits", "kv_poison", "latency_spike", "kernel_failure",
)

#: fault kinds that target one slot's numerics (and must therefore end in a
#: degraded completion or a structured shed — the zero-requests-lost gate)
SLOT_FAULTS = ("nan_logits", "inf_logits", "kv_poison")


class KernelFault(RuntimeError):
    """A (simulated) kernel launch failure on the paired path."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: fires when the front end reaches ``step``."""

    step: int
    kind: str
    slot: int = 0  # target slot for SLOT_FAULTS; ignored otherwise
    magnitude: float = 4.0  # latency multiplier / consecutive kernel failures

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"choose from {FAULT_KINDS}")


class FaultInjector:
    """Applies a fault schedule to a :class:`~repro.serving.engine.ServeEngine`.

    The injector only *mutates state the front end hands it* (logits arrays,
    the engine cache) and records everything it actually did in ``fired`` —
    the bench's every-fault-accounted gate reads that list back.
    """

    def __init__(self, events: Sequence[FaultEvent] = ()):
        self._by_step: dict[int, list[FaultEvent]] = defaultdict(list)
        for ev in events:
            self._by_step[ev.step].append(ev)
        self.events = tuple(events)
        self.fired: list[FaultEvent] = []

    @classmethod
    def from_rates(
        cls,
        seed: int,
        n_steps: int,
        batch_size: int,
        rates: Mapping[str, float],
        magnitude: float = 4.0,
    ) -> FaultInjector:
        """Bernoulli(rate) draw per (step, kind), slot drawn uniformly —
        deterministic given the seed (the schedule is materialized here,
        never re-drawn at fire time)."""
        unknown = sorted(set(rates) - set(FAULT_KINDS))
        if unknown:
            raise ValueError(f"unknown fault kind(s) {unknown}")
        rng = np.random.default_rng(seed)
        events = []
        for step in range(n_steps):
            for kind in FAULT_KINDS:
                rate = rates.get(kind, 0.0)
                if rate > 0 and rng.random() < rate:
                    events.append(FaultEvent(
                        step=step, kind=kind,
                        slot=int(rng.integers(0, batch_size)),
                        magnitude=magnitude,
                    ))
        return cls(events)

    def events_at(self, step: int, kind: str | None = None) -> list[FaultEvent]:
        evs = self._by_step.get(step, [])
        return [e for e in evs if kind is None or e.kind == kind]

    # -- application helpers (each records what actually fired) --------------
    def corrupt_logits(self, logits: np.ndarray, step: int,
                       active: np.ndarray) -> tuple[np.ndarray, list[FaultEvent]]:
        """Apply the step's nan/inf logits events to a (batch, vocab) host
        array; events targeting inactive slots are dropped (nothing to hit)."""
        out = logits
        applied = []
        for ev in self.events_at(step):
            if ev.kind not in ("nan_logits", "inf_logits"):
                continue
            if ev.slot >= len(active) or not active[ev.slot]:
                continue
            if out is logits:
                out = logits.copy()
            out[ev.slot] = np.nan if ev.kind == "nan_logits" else np.inf
            applied.append(ev)
        self.fired.extend(applied)
        return out, applied

    def poison_kv(self, engine, step: int) -> list[FaultEvent]:
        """Write NaN into the targeted slots' cached state (rows the decode
        step will genuinely attend — positions below the slot's pos)."""
        applied = []
        for ev in self.events_at(step, "kv_poison"):
            if ev.slot >= engine.batch_size or not engine.active[ev.slot]:
                continue
            poison_slot_cache(engine, ev.slot)
            applied.append(ev)
        self.fired.extend(applied)
        return applied

    def latency_multiplier(self, step: int) -> float:
        mult = 1.0
        for ev in self.events_at(step, "latency_spike"):
            mult *= max(1.0, ev.magnitude)
            self.fired.append(ev)
        return mult

    def kernel_failures(self, step: int) -> int:
        """Consecutive simulated launch failures at this step (0 → healthy)."""
        n = 0
        for ev in self.events_at(step, "kernel_failure"):
            n += int(ev.magnitude)
            self.fired.append(ev)
        return n


def poison_slot_cache(engine, slot: int) -> None:
    """NaN one slot's cache rows in place: attended K/V positions (below the
    slot's pos, so the poison provably reaches the next step's logits), full
    SSM/conv state, and cross-attention frames."""
    upto = max(1, int(np.asarray(engine.pos)[slot]))
    segs = []
    for seg in engine.cache["segments"]:
        out = {}
        for k, v in seg.items():
            if k == "h" or k.startswith("conv") or k in ("xk", "xv"):
                out[k] = v.at[:, slot].set(np.nan)
            else:  # attention K/V or MLA latents: seq axis at dim 2
                out[k] = v.at[:, slot, :upto].set(np.nan)
        segs.append(out)
    engine.cache = {"segments": segs}
