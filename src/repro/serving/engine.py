"""Batched serving engine: prefill once, decode step-by-step.

The engine owns a fixed-capacity batch of sequence slots (continuous-batching
style): each slot tracks its own position, so requests of different lengths
decode together; a finished slot is refilled by the next request without
recompiling (positions are data, not shapes).

This is the single-host reference engine; the pjit'd distributed variant
reuses exactly these step functions through ``launch/steps.build_serve_step``
(same ``decode_step``, sharded cache).
"""
from __future__ import annotations

import dataclasses
from collections.abc import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels.ops import perf_context
from repro.models import lm as M
from repro.models.param import unzip


class CapacityError(ValueError):
    """A request or decode step would exceed the engine's hard bounds.

    Raised instead of letting JAX scatter semantics silently clamp an
    out-of-range cache write into the last row (which corrupts the newest
    cached position for the slot without any error).
    """


#: token emitted for slots that are not active — callers must never treat it
#: as model output (vocab ids are non-negative, so -1 can't collide)
INACTIVE_TOKEN = -1


@dataclasses.dataclass
class ServeEngine:
    cfg: ModelConfig
    params: dict
    max_seq: int
    batch_size: int
    knobs: M.PerfKnobs = M.DEFAULT_KNOBS
    #: optional (mesh, rules) — when a mesh is given the engine becomes the
    #: distributed variant: params are paired *per TP shard* and placed with
    #: their pairing metadata beside the weight shards, the cache is
    #: sequence-sharded, and the decode/prefill steps are pjit'd
    #: (launch.steps.wire_serve_cell).  Same slot machinery either way.
    mesh: object = None
    rules: object = None

    def __post_init__(self):
        # attn == "pallas_fused" routes single-token decode attention through
        # the Pallas decode-attention kernel whose attended output feeds the
        # paired out-projection epilogue in VMEM (kernels.decode_attention) —
        # one fewer HBM writeback per decoder layer.  Validate here rather
        # than letting attn_context silently fall back to the dense path on a
        # typo'd knob.
        if self.knobs.attn not in ("xla", "pallas_fused"):
            raise ValueError(
                f"unknown knobs.attn {self.knobs.attn!r} "
                "(expected 'xla' or 'pallas_fused')")
        if self.mesh is not None and self.knobs.attn != "xla":
            raise NotImplementedError(
                "attn='pallas_fused' is single-host only: the sharded serve "
                "cell decodes against a sequence-sharded cache, and the fused "
                "decode-attention kernel has no cross-shard softmax yet — "
                "the mesh path keeps the dense decode attention")
        cache_tree = M.init_cache(self.cfg, self.batch_size, self.max_seq)
        self.cache, _ = unzip(cache_tree)
        self.pos = jnp.zeros((self.batch_size,), jnp.int32)
        self.tokens = jnp.zeros((self.batch_size, 1), jnp.int32)
        self.active = np.zeros((self.batch_size,), bool)
        # slots a numeric watchdog pulled out of service (see serving.guards):
        # quarantined slots refuse admission until clear_quarantine() runs
        self.quarantined = np.zeros((self.batch_size,), bool)
        # decode-step logits of the last step() (host copy, (batch, vocab)) —
        # what the numeric watchdog inspects for NaN/Inf/overflow
        self.last_logits: np.ndarray | None = None

        if self.mesh is not None:
            from repro.launch.steps import wire_serve_cell

            cell = wire_serve_cell(
                self.cfg, self.params, self.mesh,
                batch_size=self.batch_size, max_seq=self.max_seq,
                knobs=self.knobs, rules=self.rules,
            )
            self.params = cell.params
            self.rules = cell.rules
            self.pair_report = cell.pair_report
            self.cache = jax.tree.map(jax.device_put, self.cache, cell.c_shard)
            self._cell = cell
            self._decode = lambda p, c, t, pos: cell.decode(
                p, c, {"tokens": t, "pos": pos}
            )
            self._prefill = cell.prefill
            return

        # gemm == "pallas_paired" needs per-weight pairing metadata
        # (core.transform.pair_lm_params) next to the decoder weights.  If
        # the caller hasn't preprocessed the params already, run the paper's
        # one-time preprocessing here — knobs.pair_rounding sets the rounding
        # size, knobs.pair_block_n the pairing-spectrum point (0 →
        # structured shared-row pairing, n ≥ 1 → column-blocked, 1 == the
        # paper's per-column pairing).  The weights themselves stay live
        # (magnitudes recompute inside the traced step).
        self.pair_report = None
        if self.knobs.gemm == "pallas_paired":
            from repro.core.transform import has_lm_pairing, pair_lm_params
            from repro.kernels.ops import paired_mode_of

            if not has_lm_pairing(self.params):
                mode, block_n = paired_mode_of(self.knobs)
                self.params, self.pair_report = pair_lm_params(
                    self.params, self.knobs.pair_rounding,
                    mode=mode, block_n=block_n,
                )

        # knobs.gemm == "pallas" routes every layers.dense GEMM in the traced
        # step through the fused K-tiled kernel ("pallas_paired" routes the
        # pairing-annotated decoder GEMMs through the subtractor kernel, with
        # the sublayer residual adds fused into its epilogue), knobs.conv
        # selects the conv
        # lowering for conv-bearing models (knobs.fuse_pool additionally
        # fuses 2×2 pooling into the conv epilogue, knobs.pair_block_n the
        # pairing-mode spectrum point the conv artifacts use), and
        # knobs.tile_cache points tile selection at persisted measured
        # winners (the policies are consulted at trace time, so they must
        # wrap the function body, not the jit call).
        def decode_fn(p, c, t, pos):
            with perf_context(self.knobs):
                return M.decode_step(self.cfg, p, c, t, pos)

        def prefill_fn(p, b):
            with perf_context(self.knobs):
                return M.prefill(self.cfg, p, b, knobs=self.knobs)

        self._decode = jax.jit(decode_fn)
        self._prefill = jax.jit(prefill_fn)

    # -- request management -------------------------------------------------
    def add_request(self, slot: int, prompt: np.ndarray, extras: dict | None = None):
        """Prefill a prompt into one slot. prompt: (plen,) int32.

        Admission is validated, not asserted: ``assert`` vanishes under
        ``python -O`` and JAX scatter would then clamp an oversized prompt's
        cache writes into the last row silently.  Raises :class:`CapacityError`
        on any bound violation; a quarantined slot refuses admission until
        :meth:`clear_quarantine`.
        """
        plen = len(prompt)
        if not 0 <= slot < self.batch_size:
            raise CapacityError(
                f"slot {slot} out of range for batch_size={self.batch_size}")
        if self.active[slot]:
            raise CapacityError(
                f"slot {slot} is still active — release_slot() it first")
        if self.quarantined[slot]:
            raise CapacityError(
                f"slot {slot} is quarantined — clear_quarantine() it first")
        if plen < 1:
            raise CapacityError("empty prompt")
        if plen >= self.max_seq:
            raise CapacityError(
                f"prompt length {plen} leaves no decode room in "
                f"max_seq={self.max_seq} (need plen < max_seq)")
        batch = {"tokens": jnp.asarray(prompt[None, :], jnp.int32)}
        batch.update(extras or {})
        last_logits, cache = self._prefill(self.params, batch)

        # splice this request's prefill cache into the engine cache at `slot`
        def splice(dst_seg, src_seg):
            out = {}
            for k, dst in dst_seg.items():
                src = src_seg[k].astype(dst.dtype)
                if k == "h":  # ssm state: no seq axis
                    out[k] = dst.at[:, slot].set(src[:, 0])
                elif k.startswith("conv"):
                    out[k] = dst.at[:, slot].set(src[:, 0])
                elif k in ("xk", "xv"):  # cross-attn K/V: full frames axis
                    out[k] = dst.at[:, slot].set(src[:, 0])
                else:  # attention K/V or MLA latents: seq axis at dim 2
                    L = src.shape[2]
                    out[k] = dst.at[:, slot, :L].set(src[:, 0])
            return out

        self.cache = {
            "segments": [
                splice(d, s)
                for d, s in zip(self.cache["segments"], cache["segments"], strict=True)
            ]
        }
        self.pos = self.pos.at[slot].set(plen)
        next_tok = int(jnp.argmax(last_logits[0, -1, : self.cfg.vocab]))
        self.tokens = self.tokens.at[slot, 0].set(next_tok)
        self.active[slot] = True
        return next_tok

    def step(self, sample: Callable | None = None) -> np.ndarray:
        """One decode step for every active slot. Returns (batch,) next tokens.

        Inactive slots emit :data:`INACTIVE_TOKEN` (-1) — finished or evicted
        sequences stop producing model output.  Raises :class:`CapacityError`
        when any *active* slot has no cache row left (``pos >= max_seq``)
        instead of letting the scatter clamp into the last row.
        """
        over = self.active & (np.asarray(self.pos) >= self.max_seq)
        if over.any():
            raise CapacityError(
                f"slot(s) {np.flatnonzero(over).tolist()} at pos "
                f"{np.asarray(self.pos)[over].tolist()} have no cache rows "
                f"left (max_seq={self.max_seq}) — evict or raise max_seq")
        logits, self.cache = self._decode(self.params, self.cache, self.tokens, self.pos)
        logits = logits[:, 0, : self.cfg.vocab]
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32) if sample is None else sample(logits)
        self.pos = self.pos + jnp.asarray(self.active, jnp.int32)
        self.tokens = nxt[:, None]
        self.last_logits = np.asarray(logits)
        return np.where(self.active, np.asarray(nxt), INACTIVE_TOKEN)

    def force_token(self, slot: int, token: int) -> None:
        """Override the next input token for one slot (chunked prefill:
        the front end feeds the unprefilled tail of a long prompt through
        the shared decode steps, one token per step, so other slots keep
        decoding instead of stalling behind a monolithic prefill)."""
        self.tokens = self.tokens.at[slot, 0].set(int(token))

    def release_slot(self, slot: int, *, scrub: bool = True) -> None:
        """Evict a slot: mark it free and (by default) scrub its cache rows.

        Scrubbing zeroes every cache entry's ``slot`` row (K/V, MLA latents,
        SSM state, conv state) so a later request admitted into the slot can
        never attend stale keys from the previous occupant.
        """
        if not 0 <= slot < self.batch_size:
            raise CapacityError(
                f"slot {slot} out of range for batch_size={self.batch_size}")
        self.active[slot] = False
        self.pos = self.pos.at[slot].set(0)
        self.tokens = self.tokens.at[slot, 0].set(0)
        if scrub:
            self.cache = {
                "segments": [
                    {k: v.at[:, slot].set(0) for k, v in seg.items()}
                    for seg in self.cache["segments"]
                ]
            }

    def quarantine_slot(self, slot: int) -> None:
        """Pull a slot out of service: evict + scrub + refuse admission until
        :meth:`clear_quarantine`.  The numeric watchdog (serving.guards) calls
        this when the slot's logits go non-finite; the request itself is the
        front end's to retry on the degraded path."""
        self.release_slot(slot, scrub=True)
        self.quarantined[slot] = True

    def clear_quarantine(self, slot: int) -> None:
        self.quarantined[slot] = False

    def free_slots(self) -> list[int]:
        """Slots admission may use right now (inactive and not quarantined)."""
        return [
            i for i in range(self.batch_size)
            if not self.active[i] and not self.quarantined[i]
        ]

    def generate(self, slot_prompts: dict[int, np.ndarray], n_steps: int,
                 extras: dict | None = None) -> dict[int, list[int]]:
        """Convenience: prefill the given slots, decode n_steps greedily."""
        outs: dict[int, list[int]] = {}
        for slot, prompt in slot_prompts.items():
            first = self.add_request(slot, prompt, extras)
            outs[slot] = [first]
        for _ in range(n_steps - 1):
            nxt = self.step()
            for slot in slot_prompts:
                outs[slot].append(int(nxt[slot]))
        return outs
