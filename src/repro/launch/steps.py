"""Step builders: the pjit'd train / prefill / serve step for any arch.

Everything here works from *abstract* parameter trees (ShapeDtypeStructs via
``abstract_init``) so the multi-pod dry-run can lower + compile the 123B
configs without allocating a byte, and from concrete trees for real runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels.ops import perf_context
from repro.launch.inputs import batch_logical_axes, batch_specs
from repro.models import lm as M
from repro.models.param import unzip
from repro.parallel.rules import rules_for
from repro.parallel.sharding import Rules, activate, shardings_for, spec_for_axes
from repro.train.optimizer import Optimizer, adamw


# ---------------------------------------------------------------------------
# abstract init (no allocation)
# ---------------------------------------------------------------------------


def abstract_params(cfg: ModelConfig, dtype=None):
    """(ShapeDtypeStruct tree, logical-axes tree) for the model params."""
    cap: dict = {}

    def f(key):
        tree = M.init_lm(cfg, key)
        vals, axes = unzip(tree)
        cap["axes"] = axes
        return vals

    shapes = jax.eval_shape(f, jax.random.key(0))
    if dtype is not None:
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, dtype)
            if jnp.issubdtype(s.dtype, jnp.floating)
            else s,
            shapes,
        )
    return shapes, cap["axes"]


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int):
    cap: dict = {}

    def f():
        tree = M.init_cache(cfg, batch, max_seq)
        vals, axes = unzip(tree)
        cap["axes"] = axes
        return vals

    shapes = jax.eval_shape(f)
    return shapes, cap["axes"]


def abstract_opt_state(opt: Optimizer, param_shapes):
    return jax.eval_shape(opt.init, param_shapes)


def opt_state_axes(param_axes, opt_state_shapes):
    """Optimizer state shards exactly like its parameter (moments are
    elementwise)."""

    def like(sub):
        if isinstance(sub, dict) and set(sub) >= {"m", "v"}:
            return {k: param_axes for k in sub}
        return {k: param_axes for k in sub}

    return like(opt_state_shapes)


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, opt: Optimizer, knobs: M.PerfKnobs, mesh, rules: Rules):
    """Returns train_step(params, opt_state, step, batch) -> (params', opt', metrics).

    ``knobs.gemm == "pallas"`` traces the step with the fused Pallas GEMM
    policy active (see kernels.ops.perf_context), baking the K-tiled
    kernels into the compiled step; ``knobs.tile_cache`` makes the trace
    consult persisted measured tile configs, and ``knobs.fuse_pool`` turns
    on the conv→pool megakernel epilogue for conv-bearing models."""

    def train_step(params, opt_state, step, batch):
        with activate(mesh, rules), perf_context(knobs):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: M.lm_loss(cfg, p, batch, knobs=knobs), has_aux=True
            )(params)
            new_params, new_opt = opt.update(grads, opt_state, params, step)
        return new_params, new_opt, {**metrics, "loss": loss}

    return train_step


def build_prefill_step(cfg: ModelConfig, knobs: M.PerfKnobs, mesh, rules: Rules):
    def prefill_step(params, batch):
        with activate(mesh, rules), perf_context(knobs):
            logits, cache = M.prefill(cfg, params, batch, knobs=knobs)
        return logits, cache

    return prefill_step


def build_serve_step(cfg: ModelConfig, mesh, rules: Rules,
                     knobs: M.PerfKnobs = M.DEFAULT_KNOBS):
    def serve_step(params, cache, batch):
        with activate(mesh, rules), perf_context(knobs):
            logits, new_cache = M.decode_step(
                cfg, params, cache, batch["tokens"], batch["pos"]
            )
        return logits, new_cache

    return serve_step


# ---------------------------------------------------------------------------
# fully-wired jit for one (arch × shape × mesh) cell
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LoweredCell:
    jitted: Any
    arg_shapes: tuple
    in_shardings: tuple
    mode: str

    def lower(self):
        return self.jitted.lower(*self.arg_shapes)


def wire_cell(
    cfg: ModelConfig,
    mesh,
    *,
    seq_len: int,
    global_batch: int,
    mode: str,
    knobs: M.PerfKnobs = M.DEFAULT_KNOBS,
    rules: Rules | None = None,
) -> LoweredCell:
    """Build the jit'd step + abstract args + shardings for one dry-run cell."""
    rules = rules or rules_for(cfg, mode, mesh)

    def batch_shardings(kind, specs):
        ax = batch_logical_axes(cfg, kind)
        return {
            k: jax.sharding.NamedSharding(
                mesh,
                spec_for_axes(v, mesh=mesh, rules=rules, dim_sizes=specs[k].shape),
            )
            for k, v in ax.items()
        }

    if mode == "train":
        param_shapes, param_axes = abstract_params(cfg)
        opt = adamw(1e-4, weight_decay=0.1)
        opt_shapes = abstract_opt_state(opt, param_shapes)
        p_shard = shardings_for(param_axes, mesh, rules, param_shapes)
        o_shard = jax.tree.map(
            lambda s: s,  # placeholder; replaced below by zipped map
            opt_shapes,
        )
        # optimizer moments shard like their params
        o_shard = {k: p_shard for k in opt_shapes}
        step_fn = build_train_step(cfg, opt, knobs, mesh, rules)
        bspecs = batch_specs(cfg, global_batch, seq_len, "train")
        bshard = batch_shardings("train", bspecs)
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_shard, o_shard, None, bshard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
        )
        args = (param_shapes, opt_shapes, jax.ShapeDtypeStruct((), jnp.int32), bspecs)
        return LoweredCell(jitted, args, (p_shard, o_shard, None, bshard), mode)

    if mode == "prefill":
        param_shapes, param_axes = abstract_params(cfg, dtype=jnp.dtype(cfg.dtype))
        p_shard = shardings_for(param_axes, mesh, rules, param_shapes)
        step_fn = build_prefill_step(cfg, knobs, mesh, rules)
        bspecs = batch_specs(cfg, global_batch, seq_len, "prefill")
        bshard = batch_shardings("prefill", bspecs)
        jitted = jax.jit(step_fn, in_shardings=(p_shard, bshard))
        args = (param_shapes, bspecs)
        return LoweredCell(jitted, args, (p_shard, bshard), mode)

    if mode == "decode":
        param_shapes, param_axes = abstract_params(cfg, dtype=jnp.dtype(cfg.dtype))
        p_shard = shardings_for(param_axes, mesh, rules, param_shapes)
        cache_shapes, cache_axes = abstract_cache(cfg, global_batch, seq_len)
        c_shard = shardings_for(cache_axes, mesh, rules, cache_shapes)
        step_fn = build_serve_step(cfg, mesh, rules, knobs)
        bspecs = batch_specs(cfg, global_batch, seq_len, "decode")
        bshard = batch_shardings("decode", bspecs)
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_shard, c_shard, bshard),
            out_shardings=(None, c_shard),
            donate_argnums=(1,),
        )
        args = (param_shapes, cache_shapes, bspecs)
        return LoweredCell(jitted, args, (p_shard, c_shard, bshard), mode)

    raise ValueError(f"unknown mode {mode!r}")
