"""Step builders: the pjit'd train / prefill / serve step for any arch.

Everything here works from *abstract* parameter trees (ShapeDtypeStructs via
``abstract_init``) so the multi-pod dry-run can lower + compile the 123B
configs without allocating a byte, and from concrete trees for real runs.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.transform import has_lm_pairing, pair_params, tp_shard_plan
from repro.kernels.ops import paired_mode_of, perf_context
from repro.launch.inputs import batch_logical_axes, batch_specs
from repro.models import lm as M
from repro.models.param import pairing_axes, unzip
from repro.parallel.rules import rules_for
from repro.parallel.sharding import (
    Rules,
    activate,
    paired_shardings_for,
    shardings_for,
    spec_for_axes,
)
from repro.train.optimizer import Optimizer, adamw


# ---------------------------------------------------------------------------
# abstract init (no allocation)
# ---------------------------------------------------------------------------


def abstract_params(cfg: ModelConfig, dtype=None):
    """(ShapeDtypeStruct tree, logical-axes tree) for the model params."""
    cap: dict = {}

    def f(key):
        tree = M.init_lm(cfg, key)
        vals, axes = unzip(tree)
        cap["axes"] = axes
        return vals

    shapes = jax.eval_shape(f, jax.random.key(0))
    if dtype is not None:
        shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, dtype)
            if jnp.issubdtype(s.dtype, jnp.floating)
            else s,
            shapes,
        )
    return shapes, cap["axes"]


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int):
    cap: dict = {}

    def f():
        tree = M.init_cache(cfg, batch, max_seq)
        vals, axes = unzip(tree)
        cap["axes"] = axes
        return vals

    shapes = jax.eval_shape(f)
    return shapes, cap["axes"]


def abstract_opt_state(opt: Optimizer, param_shapes):
    return jax.eval_shape(opt.init, param_shapes)


def opt_state_axes(param_axes, opt_state_shapes):
    """Optimizer state shards exactly like its parameter (moments are
    elementwise): every top-level state slot — adamw's m/v, sgd's mom —
    mirrors the param axes tree."""
    return {k: param_axes for k in opt_state_shapes}


# ---------------------------------------------------------------------------
# step functions
# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, opt: Optimizer, knobs: M.PerfKnobs, mesh, rules: Rules):
    """Returns train_step(params, opt_state, step, batch) -> (params', opt', metrics).

    ``knobs.gemm == "pallas"`` traces the step with the fused Pallas GEMM
    policy active (see kernels.ops.perf_context), baking the K-tiled
    kernels into the compiled step; ``knobs.tile_cache`` makes the trace
    consult persisted measured tile configs, and ``knobs.fuse_pool`` turns
    on the conv→pool megakernel epilogue for conv-bearing models."""

    def train_step(params, opt_state, step, batch):
        with activate(mesh, rules), perf_context(knobs):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: M.lm_loss(cfg, p, batch, knobs=knobs), has_aux=True
            )(params)
            new_params, new_opt = opt.update(grads, opt_state, params, step)
        return new_params, new_opt, {**metrics, "loss": loss}

    return train_step


def build_prefill_step(cfg: ModelConfig, knobs: M.PerfKnobs, mesh, rules: Rules):
    def prefill_step(params, batch):
        with activate(mesh, rules), perf_context(knobs):
            logits, cache = M.prefill(cfg, params, batch, knobs=knobs)
        return logits, cache

    return prefill_step


def build_serve_step(cfg: ModelConfig, mesh, rules: Rules,
                     knobs: M.PerfKnobs = M.DEFAULT_KNOBS):
    def serve_step(params, cache, batch):
        with activate(mesh, rules), perf_context(knobs):
            logits, new_cache = M.decode_step(
                cfg, params, cache, batch["tokens"], batch["pos"]
            )
        return logits, new_cache

    return serve_step


# ---------------------------------------------------------------------------
# fully-wired jit for one (arch × shape × mesh) cell
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServeCell:
    """A concrete, sharded decode cell: paired + device_put params, jitted
    decode/prefill steps, and the shardings they were placed with."""

    params: Any
    decode: Any  # jit'd serve_step(params, cache, {"tokens", "pos"})
    prefill: Any  # jit'd prefill_step(params, batch)
    p_shard: Any
    c_shard: Any
    rules: Rules
    pair_report: Any


def wire_serve_cell(
    cfg: ModelConfig,
    params: Any,
    mesh,
    *,
    batch_size: int,
    max_seq: int,
    knobs: M.PerfKnobs = M.DEFAULT_KNOBS,
    rules: Rules | None = None,
) -> ServeCell:
    """Wire a *concrete* decode cell against a mesh.

    This is where the shard-aware pairing pieces meet: the weight leaves are
    resolved against (mesh, rules) to a TP shard plan
    (``core.transform.tp_shard_plan``), pairing is built per shard
    (``pair_params(shards=…)`` — no pair crosses a shard boundary), the
    ``"<name>_pairing"`` siblings get axes (``models.param.pairing_axes``)
    and placements derived from their weight's resolved spec
    (``parallel.sharding.paired_shardings_for``), and the decode step is
    jitted with metadata pinned beside its weight shards — so the decode
    while-loop never reshards pairing metadata.
    """
    rules = rules or rules_for(cfg, "decode", mesh)
    _, param_axes = abstract_params(cfg)
    report = None
    if knobs.gemm == "pallas_paired" and not has_lm_pairing(params):
        mode, block_n = paired_mode_of(knobs)
        plan = tp_shard_plan(
            param_axes, params, mesh, rules, leaves=cfg.paired_leaves
        )
        params, report = pair_params(
            params, knobs.pair_rounding, mode=mode, block_n=block_n,
            leaves=cfg.paired_leaves, shards=plan,
        )
    paxes = pairing_axes(params, param_axes)
    p_shard = paired_shardings_for(paxes, mesh, rules, params)
    params = jax.tree.map(jax.device_put, params, p_shard)
    cache_shapes, cache_axes = abstract_cache(cfg, batch_size, max_seq)
    c_shard = shardings_for(cache_axes, mesh, rules, cache_shapes)
    decode = jax.jit(
        build_serve_step(cfg, mesh, rules, knobs),
        in_shardings=(p_shard, c_shard, None),
        out_shardings=(None, c_shard),
        donate_argnums=(1,),
    )
    prefill = jax.jit(
        build_prefill_step(cfg, knobs, mesh, rules),
        in_shardings=(p_shard, None),
    )
    return ServeCell(params, decode, prefill, p_shard, c_shard, rules, report)


@dataclasses.dataclass
class LoweredCell:
    jitted: Any
    arg_shapes: tuple
    in_shardings: tuple
    mode: str

    def lower(self):
        return self.jitted.lower(*self.arg_shapes)


def wire_cell(
    cfg: ModelConfig,
    mesh,
    *,
    seq_len: int,
    global_batch: int,
    mode: str,
    knobs: M.PerfKnobs = M.DEFAULT_KNOBS,
    rules: Rules | None = None,
) -> LoweredCell:
    """Build the jit'd step + abstract args + shardings for one dry-run cell."""
    rules = rules or rules_for(cfg, mode, mesh)

    def batch_shardings(kind, specs):
        ax = batch_logical_axes(cfg, kind)
        return {
            k: jax.sharding.NamedSharding(
                mesh,
                spec_for_axes(v, mesh=mesh, rules=rules, dim_sizes=specs[k].shape),
            )
            for k, v in ax.items()
        }

    if mode == "train":
        param_shapes, param_axes = abstract_params(cfg)
        opt = adamw(1e-4, weight_decay=0.1)
        opt_shapes = abstract_opt_state(opt, param_shapes)
        p_shard = shardings_for(param_axes, mesh, rules, param_shapes)
        # optimizer moments shard like their params: resolve the state's own
        # axes tree rather than hand-copying param shardings
        o_axes = opt_state_axes(param_axes, opt_shapes)
        o_shard = shardings_for(o_axes, mesh, rules, opt_shapes)
        step_fn = build_train_step(cfg, opt, knobs, mesh, rules)
        bspecs = batch_specs(cfg, global_batch, seq_len, "train")
        bshard = batch_shardings("train", bspecs)
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_shard, o_shard, None, bshard),
            out_shardings=(p_shard, o_shard, None),
            donate_argnums=(0, 1),
        )
        args = (param_shapes, opt_shapes, jax.ShapeDtypeStruct((), jnp.int32), bspecs)
        return LoweredCell(jitted, args, (p_shard, o_shard, None, bshard), mode)

    if mode == "prefill":
        param_shapes, param_axes = abstract_params(cfg, dtype=jnp.dtype(cfg.dtype))
        p_shard = shardings_for(param_axes, mesh, rules, param_shapes)
        step_fn = build_prefill_step(cfg, knobs, mesh, rules)
        bspecs = batch_specs(cfg, global_batch, seq_len, "prefill")
        bshard = batch_shardings("prefill", bspecs)
        jitted = jax.jit(step_fn, in_shardings=(p_shard, bshard))
        args = (param_shapes, bspecs)
        return LoweredCell(jitted, args, (p_shard, bshard), mode)

    if mode == "decode":
        param_shapes, param_axes = abstract_params(cfg, dtype=jnp.dtype(cfg.dtype))
        p_shard = shardings_for(param_axes, mesh, rules, param_shapes)
        cache_shapes, cache_axes = abstract_cache(cfg, global_batch, seq_len)
        c_shard = shardings_for(cache_axes, mesh, rules, cache_shapes)
        step_fn = build_serve_step(cfg, mesh, rules, knobs)
        bspecs = batch_specs(cfg, global_batch, seq_len, "decode")
        bshard = batch_shardings("decode", bspecs)
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_shard, c_shard, bshard),
            out_shardings=(None, c_shard),
            donate_argnums=(1,),
        )
        args = (param_shapes, cache_shapes, bspecs)
        return LoweredCell(jitted, args, (p_shard, c_shard, bshard), mode)

    raise ValueError(f"unknown mode {mode!r}")
