"""Distributed serving driver: prefill + batched greedy decode on a mesh.

    # local CPU validation with a reduced config (+ the paper's pairing)
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --paired-rounding 0.01 --steps 16

On a real fleet the same `serve_step` lowers against the production mesh
(see launch/dryrun.py decode cells: cache sequence-sharded over `model`,
batch over `data`); here the ServeEngine drives it on local devices.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.transform import pair_model_params
from repro.models import lm as M
from repro.models.lenet import CONV_IMPLS
from repro.models.param import unzip
from repro.serving.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--paired-rounding", type=float, default=0.0)
    ap.add_argument("--pair-block-n", type=int, default=0,
                    help="pairing-mode spectrum: 0 → the paper's per-column "
                         "pairing for weight folding (and structured pairing "
                         "for kernel artifacts); n >= 1 → column-blocked "
                         "pairing with one shared-row pairing per n output "
                         "channels (kernel-executable; 1 == per-column)")
    ap.add_argument("--gemm", choices=("xla", "pallas", "pallas_paired"),
                    default="xla",
                    help="route layer GEMMs through the fused K-tiled "
                         "Pallas kernel (interpret mode off-TPU); "
                         "pallas_paired runs the decoder qkv/out-proj/MLP "
                         "GEMMs on the subtractor kernel with the sublayer "
                         "residual adds fused into the epilogue "
                         "(see --pair-rounding / --pair-block-n)")
    ap.add_argument("--pair-rounding", type=float, default=0.0,
                    help="rounding size for the pallas_paired LM pairing "
                         "artifacts (live-weight kernel path, distinct from "
                         "--paired-rounding's offline weight folding); 0.0 "
                         "is the exact-parity point")
    ap.add_argument("--conv", choices=CONV_IMPLS, default="xla",
                    help="conv lowering for conv-bearing models: plain "
                         "lax.conv, im2col patch GEMM, or the paired "
                         "subtractor kernel (no-op for the pure-LM archs)")
    ap.add_argument("--fuse-pool", action="store_true",
                    help="conv→pool megakernel: absorb 2x2 max-pools into "
                         "the paired-conv epilogue (--conv pallas_paired "
                         "only; one HBM writeback per conv layer)")
    ap.add_argument("--block-k", type=int, default=0,
                    help="Pallas GEMM k-tile; 0 → tile cache / heuristic")
    ap.add_argument("--tile-cache", default="",
                    help="path to a persisted kernel TileCache "
                         "(benchmarks/roofline.py writes one); measured "
                         "tile configs there beat the VMEM heuristic")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params, _ = unzip(M.init_lm(cfg, jax.random.key(0)))
    if args.paired_rounding > 0:
        mode = "column_blocked" if args.pair_block_n >= 1 else "per_column"
        params, report = pair_model_params(
            params, args.paired_rounding, min_dim=4,
            mode=mode, block_n=args.pair_block_n,
        )
        s = report.savings()
        print(f"[serve] subtractor pairing ({mode}"
              f"{f', block_n={args.pair_block_n}' if args.pair_block_n else ''}): "
              f"{report.total_pairs} pairs "
              f"({100*report.pair_fraction:.1f}% of weights) → modeled "
              f"power −{100*s['power_saving']:.1f}%, area −{100*s['area_saving']:.1f}%")

    knobs = M.PerfKnobs(q_chunk=32, k_chunk=32, remat="none",
                        gemm=args.gemm, conv=args.conv, block_k=args.block_k,
                        fuse_pool=args.fuse_pool, tile_cache=args.tile_cache,
                        pair_block_n=args.pair_block_n,
                        pair_rounding=args.pair_rounding)
    eng = ServeEngine(cfg, params, max_seq=args.max_seq, batch_size=args.batch, knobs=knobs)
    if eng.pair_report is not None:
        rp = eng.pair_report
        print(f"[serve] paired-kernel LM path ({rp.mode}"
              f"{f', block_n={args.pair_block_n}' if args.pair_block_n else ''}"
              f", rounding {args.pair_rounding}): "
              f"{rp.total_pairs} per-column-equivalent pairs across "
              f"{len(rp.leaves)} decoder weights "
              f"({100 * rp.pair_fraction:.1f}% of paired-eligible weights); "
              f"residual adds fused into the kernel epilogue")
    rng = np.random.default_rng(0)
    prompts = {
        i: rng.integers(0, cfg.vocab, size=(8 + 4 * i,)).astype(np.int32)
        for i in range(args.batch)
    }
    t0 = time.time()
    outs = eng.generate(prompts, args.steps)
    dt = time.time() - t0
    for slot, toks in outs.items():
        print(f"[serve] slot {slot}: prompt {len(prompts[slot])} toks → {toks}")
    print(f"[serve] {args.batch * args.steps} tokens in {dt:.2f}s "
          f"({args.batch * args.steps / dt:.1f} tok/s incl. prefill)")


if __name__ == "__main__":
    main()
