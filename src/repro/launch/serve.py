"""Distributed serving driver: prefill + batched greedy decode on a mesh.

    # local CPU validation with a reduced config (+ the paper's pairing)
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --paired-rounding 0.01 --steps 16

    # hardened front end: Poisson load + chaos over the paired engine, with
    # graceful degradation to the unpaired fallback path
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --gemm pallas_paired --frontend --arrival-rate 20 --horizon 0.5 \
        --inject nan_logits:0.05,kv_poison:0.02,kernel_failure:0.02

On a real fleet the same `serve_step` lowers against the production mesh
(see launch/dryrun.py decode cells: cache sequence-sharded over `model`,
batch over `data`); here the ServeEngine drives it on local devices.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.transform import pair_model_params
from repro.models import lm as M
from repro.models.lenet import CONV_IMPLS
from repro.models.param import unzip
from repro.serving.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--paired-rounding", type=float, default=0.0)
    ap.add_argument("--pair-block-n", type=int, default=0,
                    help="pairing-mode spectrum: 0 → the paper's per-column "
                         "pairing for weight folding (and structured pairing "
                         "for kernel artifacts); n >= 1 → column-blocked "
                         "pairing with one shared-row pairing per n output "
                         "channels (kernel-executable; 1 == per-column)")
    ap.add_argument("--gemm", choices=("xla", "pallas", "pallas_paired"),
                    default="xla",
                    help="route layer GEMMs through the fused K-tiled "
                         "Pallas kernel (interpret mode off-TPU); "
                         "pallas_paired runs the decoder qkv/out-proj/MLP "
                         "GEMMs on the subtractor kernel with the sublayer "
                         "residual adds fused into the epilogue "
                         "(see --pair-rounding / --pair-block-n)")
    ap.add_argument("--pair-rounding", type=float, default=0.0,
                    help="rounding size for the pallas_paired LM pairing "
                         "artifacts (live-weight kernel path, distinct from "
                         "--paired-rounding's offline weight folding); 0.0 "
                         "is the exact-parity point")
    ap.add_argument("--attn", choices=("xla", "pallas_fused"), default="xla",
                    help="decode attention lowering: xla runs the dense "
                         "reference; pallas_fused runs the single-token "
                         "Pallas decode-attention kernel whose attended "
                         "output feeds the paired out-projection epilogue "
                         "directly (one fewer HBM writeback per layer)")
    ap.add_argument("--conv", choices=CONV_IMPLS, default="xla",
                    help="conv lowering for conv-bearing models: plain "
                         "lax.conv, im2col patch GEMM, or the paired "
                         "subtractor kernel (no-op for the pure-LM archs)")
    ap.add_argument("--fuse-pool", action="store_true",
                    help="conv→pool megakernel: absorb 2x2 max-pools into "
                         "the paired-conv epilogue (--conv pallas_paired "
                         "only; one HBM writeback per conv layer)")
    ap.add_argument("--block-k", type=int, default=0,
                    help="Pallas GEMM k-tile; 0 → tile cache / heuristic")
    ap.add_argument("--tile-cache", default="",
                    help="path to a persisted kernel TileCache "
                         "(benchmarks/roofline.py writes one); measured "
                         "tile configs there beat the VMEM heuristic")
    # -- hardened front end (serving.frontend) -------------------------------
    ap.add_argument("--frontend", action="store_true",
                    help="drive the engine through the async front end: "
                         "seeded Poisson arrivals, length-bucketed admission, "
                         "chunked prefill, numeric watchdog with degradation "
                         "to the unpaired fallback engine")
    ap.add_argument("--arrival-rate", type=float, default=10.0,
                    help="offered load in requests per virtual second")
    ap.add_argument("--horizon", type=float, default=1.0,
                    help="arrival window in virtual seconds")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload + fault-schedule seed")
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="prompt tokens per monolithic prefill; the tail of "
                         "longer prompts rides the shared decode steps")
    ap.add_argument("--deadline", type=float, default=float("inf"),
                    help="per-request completion deadline (virtual s)")
    ap.add_argument("--fallback-gemm", choices=("xla", "pallas"), default="xla",
                    help="unpaired exact path quarantined requests degrade to")
    ap.add_argument("--inject", default="",
                    help="fault rates, e.g. 'nan_logits:0.05,kv_poison:0.02' "
                         "(per front-end step; see serving.faults.FAULT_KINDS)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params, _ = unzip(M.init_lm(cfg, jax.random.key(0)))
    if args.paired_rounding > 0:
        mode = "column_blocked" if args.pair_block_n >= 1 else "per_column"
        params, report = pair_model_params(
            params, args.paired_rounding, min_dim=4,
            mode=mode, block_n=args.pair_block_n,
        )
        s = report.savings()
        print(f"[serve] subtractor pairing ({mode}"
              f"{f', block_n={args.pair_block_n}' if args.pair_block_n else ''}): "
              f"{report.total_pairs} pairs "
              f"({100*report.pair_fraction:.1f}% of weights) → modeled "
              f"power −{100*s['power_saving']:.1f}%, area −{100*s['area_saving']:.1f}%")

    knobs = M.PerfKnobs(q_chunk=32, k_chunk=32, remat="none",
                        gemm=args.gemm, attn=args.attn, conv=args.conv,
                        block_k=args.block_k,
                        fuse_pool=args.fuse_pool, tile_cache=args.tile_cache,
                        pair_block_n=args.pair_block_n,
                        pair_rounding=args.pair_rounding)
    eng = ServeEngine(cfg, params, max_seq=args.max_seq, batch_size=args.batch, knobs=knobs)
    if eng.pair_report is not None:
        rp = eng.pair_report
        print(f"[serve] paired-kernel LM path ({rp.mode}"
              f"{f', block_n={args.pair_block_n}' if args.pair_block_n else ''}"
              f", rounding {args.pair_rounding}): "
              f"{rp.total_pairs} per-column-equivalent pairs across "
              f"{len(rp.leaves)} decoder weights "
              f"({100 * rp.pair_fraction:.1f}% of paired-eligible weights); "
              f"residual adds fused into the kernel epilogue")
    if args.frontend:
        _run_frontend(args, cfg, params, eng)
        return

    rng = np.random.default_rng(0)
    prompts = {
        i: rng.integers(0, cfg.vocab, size=(8 + 4 * i,)).astype(np.int32)
        for i in range(args.batch)
    }
    t0 = time.time()
    outs = eng.generate(prompts, args.steps)
    dt = time.time() - t0
    for slot, toks in outs.items():
        print(f"[serve] slot {slot}: prompt {len(prompts[slot])} toks → {toks}")
    print(f"[serve] {args.batch * args.steps} tokens in {dt:.2f}s "
          f"({args.batch * args.steps / dt:.1f} tok/s incl. prefill)")


def _parse_fault_rates(spec: str) -> dict[str, float]:
    rates: dict[str, float] = {}
    for part in filter(None, (p.strip() for p in spec.split(","))):
        kind, _, rate = part.partition(":")
        rates[kind] = float(rate or 0.0)
    return rates


def _run_frontend(args, cfg, params, eng: ServeEngine) -> None:
    """Simulated-load run: Poisson arrivals + optional chaos, degrading to a
    fresh unpaired fallback engine built from the same (unpaired) weights."""
    import json

    from repro.serving import (
        FaultInjector,
        FrontendConfig,
        ServeFrontend,
        poisson_workload,
    )

    # `params` is the pre-pairing tree (ServeEngine pairs its own copy), so
    # the fallback engine runs plain exact GEMMs with no metadata siblings
    fb_knobs = dataclasses.replace(
        eng.knobs, gemm=args.fallback_gemm, pair_rounding=0.0)
    fallback = ServeEngine(cfg, params, max_seq=args.max_seq,
                           batch_size=args.batch, knobs=fb_knobs)
    workload = poisson_workload(
        rate_rps=args.arrival_rate, horizon_s=args.horizon, seed=args.seed,
        vocab=cfg.vocab, prompt_len=(3, max(4, args.max_seq // 4)),
        new_tokens=(2, max(3, args.steps)),
    )
    faults = None
    rates = _parse_fault_rates(args.inject)
    if rates:
        faults = FaultInjector.from_rates(
            args.seed, n_steps=4096, batch_size=args.batch, rates=rates)
    fe = ServeFrontend(
        eng, fallback,
        FrontendConfig(prefill_chunk=args.prefill_chunk,
                       deadline_s=args.deadline),
        faults=faults,
    )
    report = fe.run(workload, offered_load_rps=args.arrival_rate)
    print(f"[serve] front end: {len(workload)} requests @ "
          f"{args.arrival_rate} req/s over {args.horizon}s "
          f"({len(report.incidents)} incident records)")
    print(json.dumps(report.summary(), indent=2))
    lost = report.lost()
    if lost:
        raise SystemExit(f"[serve] LOST {len(lost)} request(s): "
                         f"{[r.rid for r in lost]}")


if __name__ == "__main__":
    main()
