"""Model inputs: concrete batches (smoke tests / examples) and
ShapeDtypeStruct stand-ins (dry-run — weak-type-correct, shardable, no
device allocation).

Modality frontends are STUBS per the task spec: whisper gets precomputed
frame embeddings, internvl2 gets precomputed patch embeddings.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def batch_logical_axes(cfg: ModelConfig, kind: str) -> dict:
    """Logical sharding axes for each batch entry (leading dim = batch)."""
    ax: dict[str, Any] = {"tokens": ("batch", "seq")}
    if kind == "train":
        ax["labels"] = ("batch", "seq")
    if cfg.vision_prefix:
        ax["patches"] = ("batch", None, None)
    if cfg.encoder is not None:
        ax["frames"] = ("batch", None, None)
    if kind == "decode":
        ax = {"tokens": ("batch", None), "pos": ("batch",)}
    return ax


def batch_specs(cfg: ModelConfig, batch: int, seq: int, kind: str) -> dict:
    """ShapeDtypeStructs for one step's inputs (no allocation)."""
    cdt = jnp.dtype(cfg.dtype)
    if kind == "decode":
        return {
            "tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
            "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
        }
    specs = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    if cfg.vision_prefix:
        specs["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.vision_prefix, cfg.vision_embed_dim), cdt
        )
    if cfg.encoder is not None:
        specs["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.encoder.frames, cfg.d_model), cdt
        )
    return specs


def make_batch(cfg: ModelConfig, batch: int, seq: int, kind: str = "train", seed: int = 0) -> dict:
    """Concrete random batch matching batch_specs (for smoke tests)."""
    rng = np.random.default_rng(seed)
    cdt = jnp.dtype(cfg.dtype)
    if kind == "decode":
        return {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (batch, 1)), jnp.int32),
            "pos": jnp.zeros((batch,), jnp.int32),
        }
    out = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32)}
    if kind == "train":
        out["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq)), jnp.int32)
    if cfg.vision_prefix:
        out["patches"] = jnp.asarray(
            rng.normal(size=(batch, cfg.vision_prefix, cfg.vision_embed_dim)), cdt
        ) * 0.02
    if cfg.encoder is not None:
        out["frames"] = jnp.asarray(
            rng.normal(size=(batch, cfg.encoder.frames, cfg.d_model)), cdt
        ) * 0.02
    return out
