"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and everything else must see the single real device.

Mesh geometry (TPU v5e):
  single-pod: (16, 16)      = 256 chips,  axes (data, model)
  multi-pod:  (2, 16, 16)   = 512 chips,  axes (pod, data, model)

The ``pod`` axis is pure data parallelism across pods (the only traffic that
crosses DCN is the once-per-step gradient all-reduce); ``data`` is in-pod
DP/FSDP; ``model`` is tensor/expert parallelism inside the pod where ICI
bandwidth lives.
"""
from __future__ import annotations

import jax

from repro.parallel.sharding import make_mesh_compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def data_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """The mesh axes that carry the batch dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
