import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent on the
production mesh without real hardware.

For every (architecture × input shape × mesh) cell this lowers + compiles
the real step function — train_step for train shapes, prefill_step for
prefill, serve_step for decode — against ShapeDtypeStruct inputs (no
allocation), then records:

* ``memory_analysis()``  — per-device bytes (proves the config fits HBM),
* ``cost_analysis()``    — per-device HLO FLOPs / bytes (roofline terms 1+2),
* parsed collective traffic from the compiled HLO (roofline term 3).

The two XLA_FLAGS lines above MUST run before any other import (jax locks
the device count on first init); smoke tests and benches import jax normally
and see one device.

Usage:
    python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
    python -m repro.launch.dryrun --arch all --shape all --multi-pod both
"""
import argparse
import json
import time
import traceback
from pathlib import Path


from repro.configs import ALL_ARCHS, get_config
from repro.configs.base import SHAPES, supports_shape
from repro.launch.inputs import batch_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import wire_cell
from repro.models.lm import PerfKnobs
from repro.parallel.hlo import analyze, xla_cost_analysis
from repro.parallel.sharding import record_spec_fallbacks, set_mesh_compat

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of one cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    return batch_specs(cfg, shape.global_batch, shape.seq_len, shape.kind)


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    knobs: PerfKnobs | None = None,
    *,
    save: bool = True,
    tag: str = "",
) -> dict:
    if knobs is None:
        knobs = PerfKnobs()
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (f"__{tag}" if tag else "")

    ok, why = supports_shape(cfg, shape)
    if not ok:
        out = {"cell": cell_id, "status": "skipped", "reason": why}
        print(json.dumps(out))
        if save:
            _save(cell_id, out)
        return out

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    try:
        # every spec_for_axes replication fallback taken while wiring +
        # lowering this cell (divisibility, mesh-axis contention) lands in
        # the record — silent degradation is a config bug until audited
        with record_spec_fallbacks() as fallbacks:
            cell = wire_cell(
                cfg, mesh,
                seq_len=shape.seq_len,
                global_batch=shape.global_batch,
                mode=shape.kind,
                knobs=knobs,
            )
            with set_mesh_compat(mesh):
                lowered = cell.lower()
                t_lower = time.time() - t0
                compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = xla_cost_analysis(compiled)
        hlo = compiled.as_text()
        # trip-count-aware HLO accounting (xla cost_analysis counts scan
        # bodies once — see parallel/hlo.py)
        scopes = ("flash_vmem",) if knobs.attn_fused else ()
        hc = analyze(hlo, fused_scopes=scopes)
        attn_bytes = 0.0
        if knobs.attn_fused:
            attn_bytes = fused_attention_hbm_bytes(cfg, shape, mesh, knobs)
            hc.hbm_bytes += attn_bytes
        coll = hc.collective

        # pairing buffers are loop-invariant decode state — lint the compiled
        # HLO for reshards/copies of them inside the while loop (error-severity
        # findings make the cell record visibly dirty without failing the run)
        from repro.analysis import RuleContext, run_rules

        lint = run_rules(
            RuleContext(target=cell_id, hlo_text=hlo),
            rule_ids=("hlo/pairing-resharding-in-loop",),
        )

        n_chips = mesh.devices.size
        out = {
            "cell": cell_id,
            "status": "ok",
            "arch": arch,
            "shape": shape_name,
            "mode": shape.kind,
            "mesh": list(mesh.devices.shape),
            "n_chips": int(n_chips),
            "knobs": vars(knobs) if hasattr(knobs, "__dict__") else dataclass_dict(knobs),
            "seconds": {"lower": round(t_lower, 1), "compile": round(t_compile, 1)},
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
                "peak_device_bytes": mem.argument_size_in_bytes
                + mem.output_size_in_bytes
                + mem.temp_size_in_bytes
                - mem.alias_size_in_bytes,
            },
            "cost": {
                "flops": hc.flops,
                "bytes_accessed": hc.hbm_bytes,
                "attn_fused_model_bytes": attn_bytes,
                "xla_flops_unscaled": cost.get("flops", 0.0),
                "xla_bytes_unscaled": cost.get("bytes accessed", 0.0),
            },
            "collectives": coll,
            "sharding_fallbacks": [
                {"axis": axis, "reason": reason, "count": count}
                for (axis, reason), count in fallbacks.items()
            ],
            "analysis": {
                "errors": len(lint.errors()),
                "findings": [f.as_dict() for f in lint.findings],
            },
            "model": {
                "params": cfg.param_count(),
                "params_active": cfg.param_count(active_only=True),
            },
        }
        # useful-compute cross-check: 6·N·D (train) or 2·N·D (decode)
        tokens_per_chip = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1) / n_chips
        mf = (6.0 if shape.kind == "train" else 2.0) * cfg.param_count(active_only=True) * tokens_per_chip
        out["model"]["model_flops_per_chip"] = mf
        out["model"]["useful_flops_ratio"] = mf / hc.flops if hc.flops else 0.0
        print(
            f"[dryrun] {cell_id}: OK lower {t_lower:.0f}s compile {t_compile:.0f}s | "
            f"peak/device {out['memory']['peak_device_bytes']/2**30:.2f} GiB | "
            f"flops/device {out['cost']['flops']:.3e} (useful {out['model']['useful_flops_ratio']:.2f}) | "
            f"coll {coll['total_bytes']/2**20:.1f} MiB"
        )
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug we record
        out = {
            "cell": cell_id,
            "status": "failed",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
        print(f"[dryrun] {cell_id}: FAILED {type(e).__name__}: {e}")

    if save:
        _save(cell_id, out)
    return out


def dataclass_dict(k):
    import dataclasses

    return dataclasses.asdict(k)


def fused_attention_hbm_bytes(cfg, shape, mesh, knobs: PerfKnobs) -> float:
    """Per-chip HBM traffic of the Pallas flash kernel, modeled from shapes.

    The kernel streams Q once, writes O once, and re-reads K/V once per
    q-block (causal skipping → on average (nq+1)/2 of them).  For train
    cells the remat schedule runs the forward twice and the backward reads
    ~2x the forward, so traffic ≈ 4x forward.  MLA uses the materialised
    per-head K (nope+rope) / padded V.  SSM layers have no attention.
    """
    if cfg.attention_kind == "none" or shape.kind == "decode":
        return 0.0
    names = mesh.axis_names
    model = mesh.shape["model"] if "model" in names else 1
    data = 1
    for a in ("pod", "data"):
        if a in names:
            data *= mesh.shape[a]
    B_loc = max(1, shape.global_batch // data)
    S = shape.seq_len + cfg.meta_tokens
    qc = min(knobs.q_chunk, S)
    nq = -(-S // qc)
    H, KH = cfg.n_heads, cfg.n_kv_heads
    if cfg.mla is not None:
        hd_q = cfg.mla.qk_nope_dim + cfg.mla.qk_rope_dim
        hd_kv = hd_q  # k materialised per head; v padded to the same width
        KH = H
    else:
        hd_q = hd_kv = cfg.head_dim
    H_loc = H // model if H % model == 0 else H
    KH_loc = KH // model if KH % model == 0 else KH
    bpe = 2  # bf16
    q_o = 2 * B_loc * S * H_loc * hd_q * bpe
    kv = 2 * B_loc * S * KH_loc * hd_kv * bpe
    kv_reads = kv * (nq + 1) / 2  # causal-skip average
    n_attn = sum(1 for i in range(cfg.n_layers) if cfg.layer_kind(i) != "ssm")
    if cfg.encoder is not None:
        n_attn += cfg.encoder.n_layers  # bidirectional: full nk — approximate
    passes = 4.0 if shape.kind == "train" else 1.0
    return n_attn * (q_o + kv_reads) * passes


def _save(cell_id: str, payload: dict) -> None:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    (RESULTS_DIR / f"{cell_id}.json").write_text(json.dumps(payload, indent=2))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument(
        "--multi-pod", default="both", choices=["true", "false", "both"],
        help="single-pod (16x16), multi-pod (2x16x16), or both",
    )
    ap.add_argument("--q-chunk", type=int, default=1024)
    ap.add_argument("--k-chunk", type=int, default=1024)
    ap.add_argument("--remat", default="full", choices=["full", "dots", "none"])
    ap.add_argument("--attn-fused", action="store_true",
                    help="account flash-attention interiors as VMEM-fused "
                    "(Pallas kernel target; adds modeled boundary traffic)")
    ap.add_argument("--skip-done", action="store_true", help="skip cells with saved results")
    ap.add_argument("--tag", default="", help="suffix for result files (perf experiments)")
    args = ap.parse_args()

    archs = ALL_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    pods = {"true": [True], "false": [False], "both": [False, True]}[args.multi_pod]
    knobs = PerfKnobs(q_chunk=args.q_chunk, k_chunk=args.k_chunk, remat=args.remat,
                      attn_fused=args.attn_fused)

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                cell_id = f"{arch}__{shape}__{mesh_name}" + (f"__{args.tag}" if args.tag else "")
                if args.skip_done and (RESULTS_DIR / f"{cell_id}.json").exists():
                    prev = json.loads((RESULTS_DIR / f"{cell_id}.json").read_text())
                    if prev.get("status") in ("ok", "skipped"):
                        continue
                out = run_cell(arch, shape, mp, knobs, tag=args.tag)
                n_ok += out["status"] == "ok"
                n_fail += out["status"] == "failed"
                n_skip += out["status"] == "skipped"
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed, {n_skip} skipped")


if __name__ == "__main__":
    main()
