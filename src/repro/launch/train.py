"""Distributed training driver.

Runs the pjit train step on whatever mesh is available — production meshes
in a real fleet, or a small host-device mesh for local validation:

    # real (or forced-host-device) cluster
    python -m repro.launch.train --arch qwen2-1.5b --steps 100 ...

    # local CPU validation with a reduced config
    python -m repro.launch.train --arch qwen2-1.5b --smoke --steps 20

Fault tolerance: checkpoints (params, opt_state) every --ckpt-every steps
with atomic rename; on restart the loop resumes from the newest checkpoint
and regenerates the deterministic data stream from the step counter, so a
killed job continues bit-identically.  Elasticity: the mesh shape is an
argument — rerunning with a different shape re-shards the same logical rules
onto the new topology (the checkpoint stores plain host arrays, which are
re-placed by pjit on load).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.data.tokens import token_batches
from repro.launch.steps import build_train_step
from repro.models import lm as M
from repro.models.param import unzip
from repro.parallel.rules import rules_for
from repro.parallel.sharding import make_mesh_compat, set_mesh_compat, shardings_for
from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.train.optimizer import adamw, cosine_schedule


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default="", help="e.g. 2x2 → axes (data, model)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--paired-rounding", type=float, default=0.0,
                    help="apply the paper's weight pairing before training "
                    "(demonstrates pairing-aware finetune)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)

    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        names = ("data", "model")[: len(shape)]
        mesh = make_mesh_compat(shape, names)
    else:
        mesh = make_mesh_compat((jax.device_count(), 1), ("data", "model"))
    rules = rules_for(cfg, "train", mesh)

    tree = M.init_lm(cfg, jax.random.key(0))
    params, axes = unzip(tree)
    if args.paired_rounding > 0:
        from repro.core.transform import pair_model_params

        params, report = pair_model_params(params, args.paired_rounding)
        print(f"[train] paired {report.total_pairs} weight pairs "
              f"({100*report.pair_fraction:.1f}% of weights) "
              f"→ modeled savings {report.savings()}")

    opt = adamw(cosine_schedule(args.lr, args.steps, warmup_steps=min(100, args.steps // 10)))
    opt_state = opt.init(params)

    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        (params, opt_state), meta = restore_checkpoint(args.ckpt_dir, (params, opt_state))
        start = meta["step"]
        print(f"[train] resumed from step {start}")

    p_shard = shardings_for(axes, mesh, rules, params)
    step_fn = build_train_step(cfg, opt, M.PerfKnobs(q_chunk=min(1024, args.seq)), mesh, rules)
    jitted = jax.jit(
        step_fn,
        in_shardings=(p_shard, {k: p_shard for k in opt_state}, None, None),
        out_shardings=(p_shard, {k: p_shard for k in opt_state}, None),
        donate_argnums=(0, 1),
    )

    data = token_batches(args.batch, args.seq, cfg.vocab, seed=1, start_step=start)
    t0 = time.time()
    with set_mesh_compat(mesh):
        for i, (tok, lab) in enumerate(data, start=start):
            if i >= args.steps:
                break
            batch = {"tokens": jnp.asarray(tok), "labels": jnp.asarray(lab)}
            if cfg.vision_prefix:
                batch["patches"] = jnp.zeros(
                    (args.batch, cfg.vision_prefix, cfg.vision_embed_dim), cfg.dtype
                )
            if cfg.encoder is not None:
                batch["frames"] = jnp.zeros(
                    (args.batch, cfg.encoder.frames, cfg.d_model), cfg.dtype
                )
            params, opt_state, metrics = jitted(params, opt_state, jnp.int32(i), batch)
            if args.log_every and (i + 1) % args.log_every == 0:
                m = jax.tree.map(float, metrics)
                print(f"[train] step {i+1} loss {m['loss']:.4f} xent {m['xent']:.4f} "
                      f"({(i+1-start)/(time.time()-t0):.2f} it/s)")
            if args.ckpt_dir and args.ckpt_every and (i + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, i + 1, (params, opt_state),
                                metadata={"step": i + 1})
    print(f"[train] done: {args.steps - start} steps in {time.time()-t0:.1f}s, "
          f"final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
