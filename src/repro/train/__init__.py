"""Training substrate: pure-JAX optimizers, loop, fault-tolerant checkpoints."""

from repro.train.optimizer import adamw, sgd, Optimizer, cosine_schedule  # noqa: F401
from repro.train.checkpoint import save_checkpoint, restore_checkpoint, latest_step  # noqa: F401
