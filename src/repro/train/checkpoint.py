"""Fault-tolerant checkpointing for parameter/optimizer pytrees.

Designed for the restart-on-failure regime of large fleets:

* **atomic**: checkpoints are written to a temp dir and ``os.replace``d into
  place, so a host dying mid-write can never corrupt the latest checkpoint;
* **self-describing**: the treedef is stored alongside the arrays, restore
  does not need the model to be constructed first;
* **keep-N**: old steps are garbage-collected, newest ``keep`` remain;
* **resumable**: ``latest_step`` + ``restore_checkpoint`` let the launcher
  resume from whatever survived, including the optimizer state and the data
  iterator's RNG seed (stored in metadata).

At fleet scale each data-parallel replica holds identical state, so only
process 0 writes (``should_write``); model-parallel shards would write
per-shard files keyed by ``jax.process_index()`` — on this single-process
container that collapses to one file, but the layout keys are kept.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat], treedef


def save_checkpoint(
    ckpt_dir: str | os.PathLike,
    step: int,
    tree: Any,
    *,
    metadata: dict | None = None,
    keep: int = 3,
    process_index: int | None = None,
) -> Path:
    """Atomically write ``tree`` as checkpoint ``step``. Returns final path."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    pidx = jax.process_index() if process_index is None else process_index

    flat, treedef = _flatten_with_paths(tree)
    arrays = {f"arr_{i}": np.asarray(leaf) for i, (_, leaf) in enumerate(flat)}
    manifest = {
        "step": int(step),
        "paths": [p for p, _ in flat],
        "treedef": str(treedef),
        "metadata": metadata or {},
        "process_index": pidx,
    }

    final = ckpt_dir / f"step_{step:010d}"
    tmp = Path(tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_"))
    try:
        np.savez(tmp / f"shard_{pidx}.npz", **arrays)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():  # a retry after partial failure
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic on POSIX
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise

    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: Path, keep: int) -> None:
    steps = sorted(
        (p for p in ckpt_dir.iterdir() if p.name.startswith("step_")),
        key=lambda p: p.name,
    )
    for p in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir: str | os.PathLike) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = sorted(p.name for p in ckpt_dir.iterdir() if p.name.startswith("step_"))
    if not steps:
        return None
    return int(steps[-1].split("_")[1])


def restore_checkpoint(
    ckpt_dir: str | os.PathLike,
    tree_like: Any,
    *,
    step: int | None = None,
    process_index: int | None = None,
) -> tuple[Any, dict]:
    """Restore into the structure of ``tree_like``. Returns (tree, metadata)."""
    ckpt_dir = Path(ckpt_dir)
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    pidx = jax.process_index() if process_index is None else process_index
    final = ckpt_dir / f"step_{step:010d}"
    manifest = json.loads((final / "manifest.json").read_text())
    with np.load(final / f"shard_{pidx}.npz") as z:
        arrays = [z[f"arr_{i}"] for i in range(len(manifest["paths"]))]

    flat_like, treedef = jax.tree_util.tree_flatten(tree_like)
    if len(flat_like) != len(arrays):
        raise ValueError(
            f"checkpoint has {len(arrays)} leaves, target tree {len(flat_like)}"
        )
    restored = [
        np.asarray(a, dtype=np.asarray(l).dtype) for a, l in zip(arrays, flat_like, strict=True)
    ]
    return jax.tree_util.tree_unflatten(treedef, restored), manifest["metadata"]
