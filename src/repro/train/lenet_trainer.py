"""Train LeNet-5 for the paper reproduction, with on-disk caching.

Both benchmarks (table1 / fig8) and examples need *the same* trained weights;
``get_trained_lenet`` trains once (a couple of epochs is enough on the
synthetic set) and caches the result under ``.cache/``.
"""
from __future__ import annotations

from pathlib import Path

import jax
import numpy as np

from repro.data.mnist import batches, load_mnist, pad_to_32
from repro.models.lenet import init_lenet, lenet_accuracy, lenet_loss
from repro.train.loop import train
from repro.train.optimizer import adamw, cosine_schedule

CACHE = Path(".cache")


def get_trained_lenet(
    *,
    epochs: int = 3,
    train_n: int = 20000,
    test_n: int = 4000,
    seed: int = 0,
    cache: bool = True,
    verbose: bool = False,
):
    """Returns (params, test_images32, test_labels, info dict)."""
    CACHE.mkdir(exist_ok=True)
    cache_file = CACHE / f"lenet_e{epochs}_n{train_n}_s{seed}.npz"

    test_x, test_y, source = load_mnist("test", synthetic_n=test_n, seed=seed)
    test_x32 = pad_to_32(test_x)

    if cache and cache_file.exists():
        with np.load(cache_file) as z:
            params = {
                layer: {"w": z[f"{layer}_w"], "b": z[f"{layer}_b"]}
                for layer in ("conv1", "conv2", "conv3", "fc1", "fc2")
            }
        acc = lenet_accuracy(params, test_x32, test_y)
        return params, test_x32, test_y, {"source": source, "test_acc": acc, "cached": True}

    train_x, train_y, _ = load_mnist("train", synthetic_n=train_n, seed=seed)
    train_x32 = pad_to_32(train_x)

    params = init_lenet(jax.random.key(seed))
    steps_per_epoch = train_n // 128
    opt = adamw(cosine_schedule(1e-3, steps_per_epoch * epochs, warmup_steps=50))
    data = batches(train_x32, train_y, 128, seed=seed, epochs=epochs)
    params, info = train(
        params, lenet_loss, opt, data, log_every=0, verbose=verbose
    )

    if cache:
        flat = {}
        for layer, sub in params.items():
            flat[f"{layer}_w"] = np.asarray(sub["w"])
            flat[f"{layer}_b"] = np.asarray(sub["b"])
        np.savez(cache_file, **flat)

    acc = lenet_accuracy(params, test_x32, test_y)
    return params, test_x32, test_y, {
        "source": source,
        "test_acc": acc,
        "cached": False,
        "train_steps": info["steps"],
    }
