"""Pure-JAX optimizers (no optax in this environment).

``Optimizer`` is the usual (init, update) pair over parameter pytrees.
States are pytrees with the same structure as the params, so they shard with
the identical logical rules (critical for FSDP: optimizer state lives on the
same shards as its parameter).
"""
from __future__ import annotations

from collections.abc import Callable
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


def _tree_zeros_like(params, dtype=None):
    return jax.tree.map(
        lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params
    )


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def cosine_schedule(
    base_lr: float, total_steps: int, warmup_steps: int = 0, min_ratio: float = 0.1
) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(1.0, step / jnp.maximum(1, warmup_steps))
        frac = jnp.clip(
            (step - warmup_steps) / jnp.maximum(1, total_steps - warmup_steps), 0, 1
        )
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return base_lr * warm * cos

    return lr


def adamw(
    lr: float | Callable[[jax.Array], jax.Array] = 1e-3,
    *,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    grad_clip: float | None = 1.0,
    state_dtype=jnp.float32,
) -> Optimizer:
    """AdamW with decoupled weight decay + optional global-norm clipping.

    Moments are kept in fp32 regardless of param dtype (bf16-safe)."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {
            "m": _tree_zeros_like(params, state_dtype),
            "v": _tree_zeros_like(params, state_dtype),
        }

    def update(grads, state, params, step):
        if grad_clip is not None:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        step1 = jnp.asarray(step, jnp.int32) + 1
        lr_t = lr_fn(step1)
        c1 = 1.0 - b1 ** step1.astype(jnp.float32)
        c2 = 1.0 - b2 ** step1.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(state_dtype)
            m_ = b1 * m + (1 - b1) * g32
            v_ = b2 * v + (1 - b2) * jnp.square(g32)
            mhat = m_ / c1
            vhat = v_ / c2
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                delta = delta + weight_decay * p.astype(state_dtype)
            p_ = p.astype(state_dtype) - lr_t * delta
            return p_.astype(p.dtype), m_, v_

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        # unzip the 3-tuples
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": new_m, "v": new_v}

    return Optimizer(init=init, update=update)


def sgd(
    lr: float | Callable[[jax.Array], jax.Array] = 1e-2,
    *,
    momentum: float = 0.9,
    grad_clip: float | None = None,
) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"mom": _tree_zeros_like(params, jnp.float32)}

    def update(grads, state, params, step):
        if grad_clip is not None:
            grads, _ = clip_by_global_norm(grads, grad_clip)
        lr_t = lr_fn(jnp.asarray(step, jnp.int32) + 1)

        def upd(p, g, m):
            m_ = momentum * m + g.astype(jnp.float32)
            p_ = p.astype(jnp.float32) - lr_t * m_
            return p_.astype(p.dtype), m_

        out = jax.tree.map(upd, params, grads, state["mom"])
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"mom": new_m}

    return Optimizer(init=init, update=update)
