"""Generic single-host train loop used by LeNet repro + LM smoke training.

The distributed (pjit) loop lives in ``repro/launch/train.py``; this module is
the small-scale substrate: jit'd step, metrics, periodic checkpointing, and
resume-from-latest (fault tolerance is exercised by tests/test_checkpoint.py).
"""
from __future__ import annotations

import time
from collections.abc import Callable, Iterable
from typing import Any

import jax
import jax.numpy as jnp

from repro.train.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.train.optimizer import Optimizer


def make_train_step(loss_fn: Callable, optimizer: Optimizer):
    """loss_fn(params, *batch) -> (loss, aux). Returns jit'd step fn."""

    @jax.jit
    def step(params, opt_state, step_idx, *batch):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, *batch)
        new_params, new_state = optimizer.update(grads, opt_state, params, step_idx)
        return new_params, new_state, loss, aux

    return step


def train(
    params: Any,
    loss_fn: Callable,
    optimizer: Optimizer,
    data: Iterable,
    *,
    ckpt_dir: str | None = None,
    ckpt_every: int = 0,
    log_every: int = 50,
    max_steps: int | None = None,
    verbose: bool = True,
) -> tuple[Any, dict]:
    """Run the loop; resumes from ckpt_dir if it already has checkpoints."""
    opt_state = optimizer.init(params)
    start = 0
    if ckpt_dir and latest_step(ckpt_dir) is not None:
        (params, opt_state), meta = restore_checkpoint(ckpt_dir, (params, opt_state))
        start = meta.get("step", latest_step(ckpt_dir))
        if verbose:
            print(f"[train] resumed from step {start}")

    step_fn = make_train_step(loss_fn, optimizer)
    t0 = time.time()
    i = start
    last_loss, last_aux = float("nan"), None
    for i, batch in enumerate(data, start=start):
        if max_steps is not None and i >= max_steps:
            break
        batch = tuple(jnp.asarray(b) for b in batch)
        params, opt_state, loss, aux = step_fn(params, opt_state, i, *batch)
        last_loss, last_aux = float(loss), aux
        if verbose and log_every and (i + 1) % log_every == 0:
            print(
                f"[train] step {i+1} loss {last_loss:.4f} aux {jax.tree.map(float, aux)}"
                f" ({(i + 1 - start) / (time.time() - t0):.1f} it/s)"
            )
        if ckpt_dir and ckpt_every and (i + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_dir, i + 1, (params, opt_state), metadata={"step": i + 1})
    if ckpt_dir and ckpt_every:
        save_checkpoint(ckpt_dir, i + 1, (params, opt_state), metadata={"step": i + 1})
    return params, {"last_loss": last_loss, "last_aux": last_aux, "steps": i + 1 - start}
