"""Distribution: logical-axis sharding rules, mesh plumbing, collectives."""

from repro.parallel.sharding import (  # noqa: F401
    Rules,
    activate,
    constrain,
    current_mesh,
    shardings_for,
    spec_for_axes,
)
