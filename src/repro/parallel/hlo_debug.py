"""Debug CLI: top HBM-byte and collective contributors of a dry-run cell.

    PYTHONPATH=src python -m repro.parallel.hlo_debug --arch X --shape Y [--multi-pod]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
from collections import defaultdict


from repro.parallel import hlo as H
from repro.parallel.sharding import set_mesh_compat


def top_contributors(text: str, k: int = 15):
    comps, entry = H.parse_hlo(text)
    edges = defaultdict(list)
    fus, app = set(), set()
    for comp in comps.values():
        for op in comp.ops:
            m = dict(H._CALL_RE.findall(op.line))
            if op.op == "while":
                trips = H._trip_count(comps.get(m.get("condition")), op.line)
                if m.get("body"):
                    edges[m["body"]].append((comp.name, float(trips)))
            elif op.op == "fusion" and m.get("calls"):
                edges[m["calls"]].append((comp.name, 1.0)); fus.add(m["calls"])
            elif m.get("to_apply"):
                edges[m["to_apply"]].append((comp.name, 1.0)); app.add(m["to_apply"])
    cache = {}
    def mult(n, d=0):
        if n == entry: return 1.0
        if n in cache: return cache[n]
        if d > 200 or n not in edges: return 1.0
        cache[n] = sum(mult(c, d + 1) * w for c, w in edges[n]) or 1.0
        return cache[n]

    brows, crows = [], []
    for comp in comps.values():
        m = mult(comp.name)
        shapes = {op.var: op.type_str for op in comp.ops}
        skip_bytes = comp.name in fus or comp.name in app
        for op in comp.ops:
            base = op.op.replace("-start", "")
            if base in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                        "collective-permute") and not op.op.endswith("-done"):
                _, r = H._shape_elems_bytes(op.type_str)
                crows.append((r * m, r, m, base, op.line.strip()[:140]))
            if skip_bytes or op.op in H._SKIP_BYTES_OPS or op.op.endswith("-done"):
                continue
            _, rb = H._shape_elems_bytes(op.type_str)
            if op.op in H._WINDOW_BYTES_OPS:
                b = 2 * rb
            elif op.op in H._UPDATE_BYTES_OPS:
                ub = H._shape_elems_bytes(shapes.get(op.operands[1], ""))[1] if len(op.operands) > 1 else 0
                b = 2 * (ub or rb)
            else:
                b = rb + sum(H._shape_elems_bytes(shapes.get(nm, ""))[1] for nm in op.operands)
            brows.append((b * m, b, m, op.op, comp.name[:35], op.var[:45]))
    brows.sort(reverse=True); crows.sort(reverse=True)
    print(f"== top HBM bytes (total {sum(r[0] for r in brows)/2**40:.2f} TiB) ==")
    for r in brows[:k]:
        print(f"{r[0]/2**30:9.2f} GiB (x{r[2]:6.0f} of {r[1]/2**20:8.1f} MiB) {r[3]:20s} {r[5]} @{r[4]}")
    print(f"== top collectives (total {sum(r[0] for r in crows)/2**40:.2f} TiB) ==")
    for r in crows[:k]:
        print(f"{r[0]/2**30:9.2f} GiB (x{r[2]:6.0f}) {r[3]:16s} {r[4]}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--q-chunk", type=int, default=1024)
    ap.add_argument("--k-chunk", type=int, default=1024)
    args = ap.parse_args()
    from repro.configs import get_config
    from repro.configs.base import SHAPES
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import wire_cell
    from repro.models.lm import PerfKnobs

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    cell = wire_cell(cfg, mesh, seq_len=shape.seq_len, global_batch=shape.global_batch,
                     mode=shape.kind, knobs=PerfKnobs(q_chunk=args.q_chunk, k_chunk=args.k_chunk))
    with set_mesh_compat(mesh):
        compiled = cell.lower().compile()
    top_contributors(compiled.as_text(), args.top)


if __name__ == "__main__":
    main()
