"""Trip-count-aware cost analysis of compiled HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE — a 28-layer
``lax.scan`` therefore under-reports FLOPs by ~28x.  The roofline needs real
per-step numbers, so this module parses ``compiled.as_text()`` into a call
graph, extracts static trip counts for scan loops, and accumulates:

* **dot FLOPs**  — 2 · |result| · |contracted dims|, for every ``dot`` in
  every computation, weighted by the product of trip counts along its call
  chain (fusion-wrapped dots included);
* **HBM bytes** — Σ (result + operand bytes) over the *top-level* ops of
  control-flow computations only (ENTRY, while bodies/conds, conditional
  branches, calls).  Ops inside fusion computations never touch HBM and ops
  inside ``to_apply`` scalar appliers (reduce/sort/…) would be massively
  over-counted, so both are excluded.  Post-fusion op boundaries ≈ actual
  memory traffic (a static upper bound that ignores cache reuse);
* **collective bytes** — per-chip ICI wire traffic with ring-algorithm
  factors: all-gather (n-1)/n·R, reduce-scatter (n-1)·R_out,
  all-reduce 2·(n-1)/n·R, all-to-all (n-1)/n·R, collective-permute R.

Trip counts come from ``known_trip_count`` backend configs when present, else
from the loop-condition's comparison constant (exact for jax-emitted scans).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(?P<dt>[a-z][a-z0-9]*)\[(?P<dims>[0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^\s*(ENTRY\s+)?%?(?P<name>[\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OP_RE = re.compile(
    r"^\s*(ROOT\s+)?%?(?P<var>[\w.\-]+)\s*=\s*(?P<type>\(?[^=]*?\)?)\s*"
    r"(?P<op>[\w\-]+)\((?P<operands>.*?)\)(?P<rest>.*)$"
)
_CALL_RE = re.compile(r"(calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIPS_KNOWN_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,\s]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(?P<rows>\d+),(?P<cols>\d+)\]")

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "while", "conditional", "opt-barrier",
}

# ops that only touch a window of their operands: count 2·|window| instead of
# |operands| + |result| (else a lax.scan reading one layer's slice of the
# stacked parameters would be charged the full stack every trip)
_WINDOW_BYTES_OPS = {"dynamic-slice", "slice", "gather"}
_UPDATE_BYTES_OPS = {"dynamic-update-slice", "scatter"}

COLLECTIVE_OPS = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    """(total elements, total bytes) across every array in the type string."""
    elems = total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in m.group("dims").split(","):
            if d.strip():
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dt]
    return elems, total


@dataclasses.dataclass
class Op:
    var: str
    type_str: str
    op: str
    operands: list[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list[Op]


def _split_top_commas(s: str) -> list[str]:
    out, depth, cur = [], 0, []
    for ch in s:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


def parse_hlo(text: str) -> tuple[dict[str, Computation], str]:
    """Returns ({name: Computation}, entry_name)."""
    comps: dict[str, Computation] = {}
    entry = ""
    cur: Computation | None = None
    for line in text.splitlines():
        line = re.sub(r"/\*.*?\*/", "", line)  # strip /*index=N*/ comments
        hdr = _COMP_HDR_RE.match(line)
        if hdr:
            cur = Computation(hdr.group("name"), [])
            comps[cur.name] = cur
            if hdr.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        if line.strip().startswith("}"):
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        operands = []
        for tok in _split_top_commas(m.group("operands")):
            nm = re.search(r"%([\w.\-]+)", tok)
            if nm:
                operands.append(nm.group(1))
        cur.ops.append(
            Op(m.group("var"), m.group("type"), m.group("op"), operands, line)
        )
    return comps, entry


def while_reachable(comps: dict[str, Computation]) -> set[str]:
    """Names of computations that execute inside some ``while`` loop.

    Seeds from every while op's body/condition and follows the full call
    graph (fusions, to_apply appliers, calls, conditional branches) — the
    "decode loop interior" the static-analysis HLO rules scan for stray
    copies/reshards of loop-invariant buffers.
    """
    roots: list[str] = []
    callees: dict[str, list[str]] = defaultdict(list)
    for comp in comps.values():
        for op in comp.ops:
            targets = [t for _, t in _CALL_RE.findall(op.line)]
            m = _BRANCH_RE.search(op.line)
            if m:
                targets.extend(re.findall(r"%?([\w.\-]+)", m.group(1)))
            callees[comp.name].extend(targets)
            if op.op == "while":
                roots.extend(targets)
    reachable: set[str] = set()
    stack = roots
    while stack:
        name = stack.pop()
        if name in reachable:
            continue
        reachable.add(name)
        stack.extend(callees.get(name, []))
    return reachable


def _trip_count(cond: Computation | None, while_line: str) -> int:
    m = _TRIPS_KNOWN_RE.search(while_line)
    if m:
        return int(m.group(1))
    if cond is None:
        return 1
    consts = []
    for op in cond.ops:
        if op.op == "constant":
            c = re.search(r"constant\((-?\d+)\)", op.line)
            if c:
                consts.append(int(c.group(1)))
    return max(consts) if consts else 1


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group("cols"))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 2


def xla_cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` normalized to a flat dict.

    Depending on JAX version the method returns a dict, a single-element
    list of dicts (one per partition), or None; every consumer of compiled
    cost in this repo goes through here so the shape difference never
    leaks.
    """
    cost = compiled.cost_analysis() if hasattr(compiled, "cost_analysis") else compiled
    if isinstance(cost, list | tuple):
        cost = cost[0] if cost else {}
    return dict(cost) if cost else {}


@dataclasses.dataclass
class HloCost:
    flops: float
    hbm_bytes: float
    collective: dict
    trip_counts: dict[str, int]


def analyze(text: str, fused_scopes: tuple[str, ...] = ()) -> HloCost:
    """``fused_scopes``: jax.named_scope names whose interior ops live in
    VMEM on the TPU target (a validated Pallas kernel exists for them) —
    their HBM bytes are skipped; FLOPs are still counted.  The kernel's own
    boundary traffic is added analytically by the caller (launch/dryrun)."""
    comps, entry = parse_hlo(text)

    # ---- call graph: (caller, callee, multiplier_weight, callee_kind) ------
    edges: dict[str, list[tuple[str, float, str]]] = defaultdict(list)
    fusion_callees: set[str] = set()
    apply_callees: set[str] = set()
    for comp in comps.values():
        for op in comp.ops:
            if op.op == "while":
                body = cond = None
                for kind, target in _CALL_RE.findall(op.line):
                    if kind == "body":
                        body = target
                    elif kind == "condition":
                        cond = target
                trips = _trip_count(comps.get(cond), op.line)
                if body:
                    edges[body].append((comp.name, float(trips), "loop"))
                if cond:
                    edges[cond].append((comp.name, float(trips + 1), "loop"))
            elif op.op == "fusion":
                for kind, target in _CALL_RE.findall(op.line):
                    if kind == "calls":
                        edges[target].append((comp.name, 1.0, "fusion"))
                        fusion_callees.add(target)
            elif op.op == "conditional":
                m = _BRANCH_RE.search(op.line)
                if m:
                    for t in re.findall(r"%?([\w.\-]+)", m.group(1)):
                        edges[t].append((comp.name, 1.0, "branch"))
            elif op.op == "call":
                for kind, target in _CALL_RE.findall(op.line):
                    edges[target].append((comp.name, 1.0, "call"))
            else:
                for kind, target in _CALL_RE.findall(op.line):
                    if kind == "to_apply":
                        edges[target].append((comp.name, 1.0, "apply"))
                        apply_callees.add(target)

    mult_cache: dict[str, float] = {}

    def multiplier(name: str, _depth=0) -> float:
        if name == entry:
            return 1.0
        if name in mult_cache:
            return mult_cache[name]
        if _depth > 200 or name not in edges:
            return 1.0
        total = sum(multiplier(caller, _depth + 1) * w for caller, w, _ in edges[name])
        mult_cache[name] = total if total else 1.0
        return mult_cache[name]

    # ---- accumulate -----------------------------------------------------
    flops = 0.0
    hbm = 0.0
    coll_bytes: dict[str, float] = defaultdict(float)
    coll_count: dict[str, float] = defaultdict(float)
    trip_counts: dict[str, int] = {}

    for comp in comps.values():
        mult = multiplier(comp.name)
        # symbol table for operand shapes
        shapes = {op.var: op.type_str for op in comp.ops}
        count_bytes = comp.name not in fusion_callees and comp.name not in apply_callees

        for op in comp.ops:
            # --- dot FLOPs (everywhere) --------------------------------
            if op.op == "dot":
                _, rbytes = _shape_elems_bytes(op.type_str)
                relems, _ = _shape_elems_bytes(op.type_str)
                cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
                csize = 1
                if cdims and op.operands:
                    lhs_shape = shapes.get(op.operands[0], "")
                    dims_m = _SHAPE_RE.search(lhs_shape)
                    if dims_m:
                        dims = [int(d) for d in dims_m.group("dims").split(",") if d.strip()]
                        for ci in cdims.group(1).split(","):
                            if ci.strip() and int(ci) < len(dims):
                                csize *= dims[int(ci)]
                flops += 2.0 * relems * csize * mult
            elif op.op == "convolution":
                # rough: 2 * |out| * prod(kernel spatial+input feature)
                relems, _ = _shape_elems_bytes(op.type_str)
                kshape = shapes.get(op.operands[1], "") if len(op.operands) > 1 else ""
                kelems, _ = _shape_elems_bytes(kshape)
                kdim = _SHAPE_RE.search(kshape)
                ksz = 1
                if kdim:
                    dims = [int(d) for d in kdim.group("dims").split(",") if d.strip()]
                    if dims:
                        ksz = kelems // max(dims[-1], 1)  # all but output-feature dim
                flops += 2.0 * relems * ksz * mult

            # --- HBM bytes (control-flow computations only) --------------
            in_fused_scope = any(s in op.line for s in fused_scopes)
            if (count_bytes and not in_fused_scope
                    and op.op not in _SKIP_BYTES_OPS and not op.op.endswith("-done")):
                _, rbytes = _shape_elems_bytes(op.type_str)
                if op.op in _WINDOW_BYTES_OPS:
                    hbm += 2 * rbytes * mult
                elif op.op in _UPDATE_BYTES_OPS:
                    ubytes = 0
                    if len(op.operands) > 1:
                        _, ubytes = _shape_elems_bytes(shapes.get(op.operands[1], ""))
                    hbm += 2 * (ubytes or rbytes) * mult
                else:
                    obytes = 0
                    for nm in op.operands:
                        _, b = _shape_elems_bytes(shapes.get(nm, ""))
                        obytes += b
                    hbm += (rbytes + obytes) * mult

            # --- collectives ----------------------------------------------
            base_op = op.op.replace("-start", "")
            if base_op in ("all-reduce", "all-gather", "reduce-scatter",
                           "all-to-all", "collective-permute") and not op.op.endswith("-done"):
                _, r = _shape_elems_bytes(op.type_str)
                n = _group_size(op.line)
                if base_op == "all-gather":
                    b = (n - 1) / n * r
                elif base_op == "reduce-scatter":
                    b = (n - 1) * r
                elif base_op == "all-reduce":
                    b = 2 * (n - 1) / n * r
                elif base_op == "all-to-all":
                    b = (n - 1) / n * r
                else:
                    b = r
                coll_bytes[base_op] += b * mult
                coll_count[base_op] += mult

    return HloCost(
        flops=flops,
        hbm_bytes=hbm,
        collective={
            "bytes_by_type": dict(coll_bytes),
            "count_by_type": dict(coll_count),
            "total_bytes": float(sum(coll_bytes.values())),
        },
        trip_counts=trip_counts,
    )


def collective_stats(hlo_text: str) -> dict:
    """Back-compat shim: trip-aware collective traffic only."""
    return analyze(hlo_text).collective
