"""Per-(architecture × mode) sharding rule tables.

One place decides how every logical axis maps onto the mesh:

* ``train``   — batch over (pod, data); TP over `model` for ff / heads /
  experts / vocab / ssm; saved residual-stream activations sequence-sharded
  over `model` (Megatron-style sequence parallelism, which is what keeps the
  per-layer remat checkpoints from blowing HBM on the 123B config); FSDP
  (params' d_model dim over `data`) kicks in for models too big for pure TP.
* ``prefill`` — TP as in train, no seq-sharding (single pass), KV cache
  outputs sharded over `model` along the *sequence* axis.
* ``decode``  — weights TP over `model` where divisible; the KV cache is
  sharded over `model` along *sequence* (kv-head counts of the assigned
  archs — 2, 5, 8 — don't divide a 16-way axis, sequence does); attention
  against the seq-sharded cache becomes a partial-softmax + psum, which XLA's
  SPMD partitioner emits from the einsum + sharding constraints alone.

Divisibility is guarded downstream (sharding.spec_for_axes): an axis that
does not divide its mesh axes silently degrades to replication — e.g.
qwen2's 12 query heads on the 16-way model axis.
"""
from __future__ import annotations

import jax

from repro.configs.base import ModelConfig
from repro.parallel.sharding import Rules


FSDP_PARAM_THRESHOLD = 20e9  # params; above this, shard d_model over `data`


def rules_for(cfg: ModelConfig, mode: str, mesh: jax.sharding.Mesh) -> Rules:
    data = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    big = cfg.param_count() > FSDP_PARAM_THRESHOLD

    base = {
        "batch": data,
        "vocab": "model",
        "ff": "model",
        "expert_ff": None,  # `model` is taken by `experts` for MoE weights
        "experts": "model",
        "q_heads": "model",
        "kv_heads": "model",
        "ssm_in": "model",
        "ssm_heads": "model",
        "kv_lora": None,
        "head_dim": None,
        "ssm_state": None,
        "conv": None,
        "layers": None,
        "meta": None,
        "frames": None,
        "seq": None,
        "cache_seq": None,
        # pairing-metadata lane dims never shard by rule — the block axis of
        # a "<name>_pairing" sibling copies the *weight's* resolved spec in
        # sharding.paired_shardings_for, so metadata rides with its shard.
        "pairing_meta": None,
    }

    if mode == "train":
        # Sequence-parallel residual checkpoints (Megatron SP). Measured on
        # qwen2/train_4k/16x16: disabling it looks tempting (fewer per-layer
        # gathers) but the partitioner then replicates large bwd fragments —
        # compute 0.56s→1.9s, HBM 9.6s→35.6s, peak 7.7→21.6 GiB. Keep ON.
        base["seq"] = "model"
        if big:
            base["embed"] = "data"  # FSDP 2-D weights: fp32 state of 123B
    elif mode == "prefill":
        base["cache_seq"] = "model"  # emitted KV cache sharded along seq
        if big:
            base["embed"] = "data"
    elif mode == "decode":
        base["cache_seq"] = "model"  # KV cache sequence-sharded
        # attention weights stay on `model` where head counts divide; the
        # guard replicates them otherwise. Big models also spread d_model
        # over `data` so bf16 weights fit HBM (123B / 16 TP = 15.4 GB > HBM).
        if big:
            base["embed"] = "data"
    else:  # pragma: no cover
        raise ValueError(f"unknown mode {mode!r}")

    return Rules(table=base)
