"""Logical-axis sharding: the single place where "what shards where" lives.

Models annotate every parameter with *logical* axes ("embed", "ff",
"experts", …) and call :func:`constrain` on key activations with logical
names ("batch", "ff", "experts").  A :class:`Rules` table maps logical axes
to mesh axes per (architecture × mode); :func:`activate` installs
(mesh, rules) for a region of code, and everything else — NamedShardings for
pjit, with_sharding_constraint on activations — derives from that.

Why logical indirection (and not hard-coded PartitionSpecs): elasticity.
When the fleet loses a pod or the mesh is re-shaped, the launcher re-activates
the same rules on the new mesh and every sharding follows; nothing in the
model knows mesh sizes.  Rules also guard divisibility: a logical axis whose
dimension does not divide its mesh axes falls back to replication instead of
producing an invalid sharding (e.g. qwen2's 12 query heads on a 16-way model
axis).

One mesh axis is never used twice in a spec: axes are resolved in priority
order and later claims on an already-used mesh axis degrade to None.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from collections.abc import Mapping, Sequence
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_mesh_compat(axis_shapes, axis_names, *, explicit: bool = False) -> Mesh:
    """``jax.make_mesh`` across JAX versions.

    Newer JAX grew ``jax.sharding.AxisType`` and a ``make_mesh(...,
    axis_types=...)`` parameter; the pinned JAX here has neither.  Feature-
    detect both and fall back to plain ``Mesh`` construction so callers
    (tests, launch scripts) never touch ``jax.sharding.AxisType`` directly.
    """
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None and hasattr(jax, "make_mesh"):
        kind = axis_type.Explicit if explicit else axis_type.Auto
        # make_mesh may predate axis_types — fall through on TypeError
        with contextlib.suppress(TypeError):
            return jax.make_mesh(
                axis_shapes, axis_names, axis_types=(kind,) * len(axis_names)
            )
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(axis_shapes, axis_names)
    n = int(np.prod(axis_shapes))
    devices = np.asarray(jax.devices()[:n]).reshape(axis_shapes)
    return Mesh(devices, axis_names)


def set_mesh_compat(mesh: Mesh):
    """``jax.set_mesh(mesh)`` across JAX versions.

    Newer JAX installs the mesh via ``jax.set_mesh``; on the pinned JAX the
    ``Mesh`` object itself is the context manager with the same effect for
    everything this repo does (jit with NamedShardings + shard_map).
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


# Resolution priority: earlier names win a contested mesh axis.
PRIORITY = [
    "experts",
    "vocab",
    "ff",
    "expert_ff",
    "q_heads",
    "kv_heads",
    "ssm_heads",
    "ssm_in",
    "cache_seq",
    "batch",
    "embed",
    "kv_lora",
    "ssm_state",
    "head_dim",
    "frames",
    "meta",
    "conv",
    "layers",
    "seq",
]


@dataclasses.dataclass(frozen=True)
class Rules:
    """Mapping logical axis -> mesh axis (str), tuple of mesh axes, or None."""

    table: Mapping[str, Any]

    def mesh_axes(self, name: str | None):
        if name is None:
            return None
        return self.table.get(name)


_state = threading.local()


def current() -> tuple[Mesh | None, Rules | None]:
    return getattr(_state, "mesh", None), getattr(_state, "rules", None)


def current_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def activate(mesh: Mesh, rules: Rules):
    """Install (mesh, rules) for constrain()/spec_for_axes() in this thread."""
    prev = current()
    _state.mesh, _state.rules = mesh, rules
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def spec_for_axes(
    axes: Sequence[str | None],
    *,
    mesh: Mesh | None = None,
    rules: Rules | None = None,
    dim_sizes: Sequence[int] | None = None,
) -> P:
    """PartitionSpec for a tuple of logical axis names.

    Guards: (a) each mesh axis used at most once (priority order),
    (b) divisibility — if ``dim_sizes`` given, a dim that does not divide its
    mesh axes is replicated instead.
    """
    if mesh is None or rules is None:
        m, r = current()
        mesh = mesh or m
        rules = rules or r
    if mesh is None or rules is None:
        return P(*([None] * len(axes)))

    order = sorted(
        range(len(axes)),
        key=lambda i: PRIORITY.index(axes[i]) if axes[i] in PRIORITY else len(PRIORITY),
    )
    used: set[str] = set()
    out: list[Any] = [None] * len(axes)
    for i in order:
        cand = rules.mesh_axes(axes[i])
        if cand is None:
            continue
        cand_t = (cand,) if isinstance(cand, str) else tuple(cand)
        if any(c in used for c in cand_t):
            continue
        if dim_sizes is not None:
            size = dim_sizes[i]
            if size % _axis_size(mesh, cand_t) != 0:
                continue
        used.update(cand_t)
        out[i] = cand if isinstance(cand, str) else tuple(cand_t)
    return P(*out)


def constrain(x: jax.Array, *axes: str | None) -> jax.Array:
    """with_sharding_constraint by logical names; no-op outside activate()."""
    mesh, rules = current()
    if mesh is None or rules is None:
        return x
    spec = spec_for_axes(axes, mesh=mesh, rules=rules, dim_sizes=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shardings_for(axes_tree: Any, mesh: Mesh, rules: Rules, shapes_tree: Any = None) -> Any:
    """NamedSharding tree for a tree of logical-axes tuples (see param.unzip).

    ``shapes_tree``: matching tree of arrays/ShapeDtypeStructs for
    divisibility guards (recommended).
    """

    def one(axes, shaped=None):
        dims = tuple(shaped.shape) if shaped is not None else None
        spec = spec_for_axes(axes, mesh=mesh, rules=rules, dim_sizes=dims)
        return NamedSharding(mesh, spec)

    is_axes = lambda a: isinstance(a, tuple) and all(isinstance(x, str | None) for x in a)
    if shapes_tree is None:
        return jax.tree.map(one, axes_tree, is_leaf=is_axes)
    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=is_axes)
