"""Logical-axis sharding: the single place where "what shards where" lives.

Models annotate every parameter with *logical* axes ("embed", "ff",
"experts", …) and call :func:`constrain` on key activations with logical
names ("batch", "ff", "experts").  A :class:`Rules` table maps logical axes
to mesh axes per (architecture × mode); :func:`activate` installs
(mesh, rules) for a region of code, and everything else — NamedShardings for
pjit, with_sharding_constraint on activations — derives from that.

Why logical indirection (and not hard-coded PartitionSpecs): elasticity.
When the fleet loses a pod or the mesh is re-shaped, the launcher re-activates
the same rules on the new mesh and every sharding follows; nothing in the
model knows mesh sizes.  Rules also guard divisibility: a logical axis whose
dimension does not divide its mesh axes falls back to replication instead of
producing an invalid sharding (e.g. qwen2's 12 query heads on a 16-way model
axis).

One mesh axis is never used twice in a spec: axes are resolved in priority
order and later claims on an already-used mesh axis degrade to None.
"""
from __future__ import annotations

import contextlib
import dataclasses
import logging
import threading
from collections.abc import Callable, Mapping, Sequence
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_mesh_compat(axis_shapes, axis_names, *, explicit: bool = False) -> Mesh:
    """``jax.make_mesh`` across JAX versions.

    Newer JAX grew ``jax.sharding.AxisType`` and a ``make_mesh(...,
    axis_types=...)`` parameter; the pinned JAX here has neither.  Feature-
    detect both and fall back to plain ``Mesh`` construction so callers
    (tests, launch scripts) never touch ``jax.sharding.AxisType`` directly.
    """
    axis_shapes = tuple(axis_shapes)
    axis_names = tuple(axis_names)
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None and hasattr(jax, "make_mesh"):
        kind = axis_type.Explicit if explicit else axis_type.Auto
        # make_mesh may predate axis_types — fall through on TypeError
        with contextlib.suppress(TypeError):
            return jax.make_mesh(
                axis_shapes, axis_names, axis_types=(kind,) * len(axis_names)
            )
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(axis_shapes, axis_names)
    n = int(np.prod(axis_shapes))
    devices = np.asarray(jax.devices()[:n]).reshape(axis_shapes)
    return Mesh(devices, axis_names)


def set_mesh_compat(mesh: Mesh):
    """``jax.set_mesh(mesh)`` across JAX versions.

    Newer JAX installs the mesh via ``jax.set_mesh``; on the pinned JAX the
    ``Mesh`` object itself is the context manager with the same effect for
    everything this repo does (jit with NamedShardings + shard_map).
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


# Resolution priority: earlier names win a contested mesh axis.
PRIORITY = [
    "experts",
    "vocab",
    "ff",
    "expert_ff",
    "q_heads",
    "kv_heads",
    "ssm_heads",
    "ssm_in",
    "cache_seq",
    "batch",
    "embed",
    "kv_lora",
    "ssm_state",
    "head_dim",
    "frames",
    "meta",
    "conv",
    "layers",
    "seq",
    "pairing_meta",
]


@dataclasses.dataclass(frozen=True)
class Rules:
    """Mapping logical axis -> mesh axis (str), tuple of mesh axes, or None."""

    table: Mapping[str, Any]

    def mesh_axes(self, name: str | None):
        if name is None:
            return None
        return self.table.get(name)


_state = threading.local()


def current() -> tuple[Mesh | None, Rules | None]:
    return getattr(_state, "mesh", None), getattr(_state, "rules", None)


def current_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def activate(mesh: Mesh, rules: Rules):
    """Install (mesh, rules) for constrain()/spec_for_axes() in this thread."""
    prev = current()
    _state.mesh, _state.rules = mesh, rules
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


_log = logging.getLogger(__name__)
_fallback_state = threading.local()
_logged_fallbacks: set[tuple[str, str]] = set()


@contextlib.contextmanager
def record_spec_fallbacks():
    """Collect every replication fallback :func:`spec_for_axes` takes inside
    the with-body.

    Yields an insertion-ordered ``dict[(logical_axis, reason), count]`` —
    read it after the block.  Nested recorders shadow outer ones (each dryrun
    cell gets its own ledger)."""
    prev = getattr(_fallback_state, "sink", None)
    sink: dict[tuple[str, str], int] = {}
    _fallback_state.sink = sink
    try:
        yield sink
    finally:
        _fallback_state.sink = prev


def _note_fallback(
    explain: Callable[[str, str], None] | None, axis: str, reason: str
) -> None:
    """Route a replication fallback to the explain hook, the active
    :func:`record_spec_fallbacks` sink, and (once per distinct pair) the log,
    so silent degradation is auditable without spamming per-leaf calls."""
    if explain is not None:
        explain(axis, reason)
    sink = getattr(_fallback_state, "sink", None)
    if sink is not None:
        sink[(axis, reason)] = sink.get((axis, reason), 0) + 1
    if (axis, reason) not in _logged_fallbacks:
        _logged_fallbacks.add((axis, reason))
        _log.info("sharding fallback: axis %r replicated — %s", axis, reason)


def spec_for_axes(
    axes: Sequence[str | None],
    *,
    mesh: Mesh | None = None,
    rules: Rules | None = None,
    dim_sizes: Sequence[int] | None = None,
    explain: Callable[[str, str], None] | None = None,
) -> P:
    """PartitionSpec for a tuple of logical axis names.

    Guards: (a) each mesh axis used at most once (priority order),
    (b) divisibility — if ``dim_sizes`` given, a dim that does not divide its
    mesh axes is replicated instead.  Each guard that fires reports
    ``(logical_axis, reason)`` through ``explain=`` (if given), the active
    :func:`record_spec_fallbacks` context, and a once-per-distinct-pair log
    line — a dropped candidate is a deliberate replication, not a silent one.
    """
    if mesh is None or rules is None:
        m, r = current()
        mesh = mesh or m
        rules = rules or r
    if mesh is None or rules is None:
        return P(*([None] * len(axes)))

    order = sorted(
        range(len(axes)),
        key=lambda i: PRIORITY.index(axes[i]) if axes[i] in PRIORITY else len(PRIORITY),
    )
    used: set[str] = set()
    out: list[Any] = [None] * len(axes)
    for i in order:
        cand = rules.mesh_axes(axes[i])
        if cand is None:
            continue
        cand_t = (cand,) if isinstance(cand, str) else tuple(cand)
        if any(c in used for c in cand_t):
            taken = sorted(c for c in cand_t if c in used)
            _note_fallback(
                explain, axes[i],
                f"mesh axes {taken} already claimed by a higher-priority "
                "logical axis",
            )
            continue
        if dim_sizes is not None:
            size = dim_sizes[i]
            if size % _axis_size(mesh, cand_t) != 0:
                _note_fallback(
                    explain, axes[i],
                    f"dim {size} not divisible by mesh axes "
                    f"{list(cand_t)} (size {_axis_size(mesh, cand_t)})",
                )
                continue
        used.update(cand_t)
        out[i] = cand if isinstance(cand, str) else tuple(cand_t)
    return P(*out)


def constrain(x: jax.Array, *axes: str | None) -> jax.Array:
    """with_sharding_constraint by logical names; no-op outside activate()."""
    mesh, rules = current()
    if mesh is None or rules is None:
        return x
    spec = spec_for_axes(axes, mesh=mesh, rules=rules, dim_sizes=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shardings_for(axes_tree: Any, mesh: Mesh, rules: Rules, shapes_tree: Any = None) -> Any:
    """NamedSharding tree for a tree of logical-axes tuples (see param.unzip).

    ``shapes_tree``: matching tree of arrays/ShapeDtypeStructs for
    divisibility guards (recommended).
    """

    def one(axes, shaped=None):
        dims = tuple(shaped.shape) if shaped is not None else None
        spec = spec_for_axes(axes, mesh=mesh, rules=rules, dim_sizes=dims)
        return NamedSharding(mesh, spec)

    is_axes = lambda a: isinstance(a, tuple) and all(isinstance(x, str | None) for x in a)
    if shapes_tree is None:
        return jax.tree.map(one, axes_tree, is_leaf=is_axes)
    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=is_axes)


def _pairing_meta_spec(
    w_name: str,
    w_axes: tuple[str | None, ...],
    w_spec: P,
    w_shape: tuple[int, ...],
    meta_shape: tuple[int, ...],
    mesh: Mesh,
) -> P:
    """PartitionSpec of one pairing-metadata leaf, derived from its sibling
    weight's *resolved* spec (never from a fresh rule resolution — the weight
    may have been replicated by a guard the metadata dims wouldn't trip, and
    diverging placements would force a reshard inside the decode loop).

    Column-blocked metadata ``(L[, E], B, lanes)``: the block axis B shards
    exactly like the weight's leading output-column dim when (a) that dim is
    the only sharded column dim, (b) blocks are uniform (``N % B == 0``), and
    (c) block count divides the shard count's worth (``B % shards == 0``) so
    shard boundaries land on block boundaries.  Expert metadata copies the
    weight's expert-axis spec.  Everything else — layers, lanes, structured
    metadata — is replicated (always correct, and per-shard builds guarantee
    each device only *reads* its own rows anyway).
    """
    nd_m = len(meta_shape)
    out: list[Any] = [None] * nd_m
    if nd_m == 0:
        return P()
    expert = "experts" in w_axes and len(w_shape) == 4
    mat0 = 2 if expert else 1
    nd_w = len(w_shape)
    if expert and nd_m >= 2 and meta_shape[1] == w_shape[1]:
        out[1] = w_spec[1]
    block_dim = 2 if expert else 1
    # blocked metadata carries (block, lane) behind the stack dims;
    # structured carries a single lane dim — nothing to place there.
    if nd_m == block_dim + 2 and nd_w > mat0:
        if w_name == "wo":
            col_dims = [nd_w - 1]
        else:
            col_dims = list(range(mat0 + 1, nd_w))
        lead = w_spec[col_dims[0]]
        aligned = lead is not None and all(
            w_spec[d] is None for d in col_dims[1:]
        )
        if aligned:
            n_cols = 1
            for d in col_dims:
                n_cols *= w_shape[d]
            n_blocks = meta_shape[block_dim]
            shards = _axis_size(mesh, lead)
            if n_cols % n_blocks == 0 and n_blocks % shards == 0:
                out[block_dim] = lead
    return P(*out)


def paired_shardings_for(
    axes_tree: Any, mesh: Mesh, rules: Rules, shapes_tree: Any
) -> Any:
    """:func:`shardings_for` for a *paired* param tree.

    Weights and every other leaf resolve through the rule table exactly as
    :func:`shardings_for` does; ``"<name>_pairing"`` sibling dicts instead
    derive their placement from the sibling weight's resolved spec via
    :func:`_pairing_meta_spec`, so metadata always lands on the device that
    holds the weight shard it indexes.  ``shapes_tree`` is required — the
    alignment guards need concrete dims.
    """

    def is_axes(a):
        return isinstance(a, tuple) and all(isinstance(x, str | None) for x in a)

    def one(axes, shaped):
        dims = tuple(shaped.shape) if shaped is not None else None
        spec = spec_for_axes(axes, mesh=mesh, rules=rules, dim_sizes=dims)
        return NamedSharding(mesh, spec)

    def walk(axes, shapes):
        if isinstance(axes, dict):
            out = {}
            for k, a in axes.items():
                w = k[: -len("_pairing")] if k.endswith("_pairing") else None
                if (
                    w is not None
                    and isinstance(a, dict)
                    and w in axes
                    and is_axes(axes[w])
                ):
                    w_axes = axes[w]
                    w_shape = tuple(shapes[w].shape)
                    w_spec = spec_for_axes(
                        w_axes, mesh=mesh, rules=rules, dim_sizes=w_shape
                    )
                    out[k] = {
                        mk: NamedSharding(
                            mesh,
                            _pairing_meta_spec(
                                w, w_axes, w_spec, w_shape,
                                tuple(shapes[k][mk].shape), mesh,
                            ),
                        )
                        for mk in a
                    }
                else:
                    out[k] = walk(a, shapes[k])
            return out
        if is_axes(axes):
            return one(axes, shapes)
        if isinstance(axes, list | tuple):
            return type(axes)(
                walk(a, s) for a, s in zip(axes, shapes, strict=True)
            )
        return one(axes, shapes)

    return walk(axes_tree, shapes_tree)
